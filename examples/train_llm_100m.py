"""End-to-end driver (deliverable b): train a llama-style model for a few
hundred steps on the synthetic induction-head stream and watch the loss fall.

  PYTHONPATH=src python examples/train_llm_100m.py --steps 300               # 40M, CPU-budget default
  PYTHONPATH=src python examples/train_llm_100m.py --preset 100m --steps 300 # full ~108M preset

The checked-in run (experiments/train_llm_100m.log) uses the 40M preset —
the honest trade for a single-CPU container; on real hardware use --preset
100m (same code path, larger dims).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import synthetic_token_stream
from repro.launch.train import default_optimizer, init_train_state, make_train_step
from repro.utils import get_logger, human_count, tree_num_params

log = get_logger("examples.llm100m")

PRESETS = {
    "40m": ModelConfig(
        name="llama-40m", family="dense", source="scaled-down llama3 family",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=8192, rope_theta=5e5, remat_policy="none"),
    "100m": ModelConfig(
        name="llama-100m", family="dense", source="scaled-down llama3 family",
        num_layers=10, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
        d_ff=2560, vocab_size=16384, rope_theta=5e5, remat_policy="none"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--preset", default="40m", choices=list(PRESETS))
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    opt = default_optimizer(cfg, base_lr=args.lr, warmup=20, total=args.steps)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    n = tree_num_params(state["params"])
    log.info("params: %s", human_count(n))
    step = jax.jit(make_train_step(cfg, opt))
    stream = synthetic_token_stream(cfg.vocab_size, args.batch, args.seq, seed=0)
    t0 = time.time()
    first = None
    for i in range(args.steps):
        state, m = step(state, next(stream))
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = (i + 1) * args.batch * args.seq / (time.time() - t0)
            log.info("step %4d loss %.4f (%.0f tok/s)", i, loss, tok_s)
    log.info("loss %.4f -> %.4f (%.1f%% drop)", first, loss,
             100 * (1 - loss / first))
    assert loss < first * 0.95, "training did not learn"


if __name__ == "__main__":
    main()
