"""Ablation (survey §7.2, Table 3): convergence vs staleness bound vs
communication, across the three staleness models — the survey's central
accuracy/efficiency trade-off, reproduced end to end.

  PYTHONPATH=src python examples/staleness_ablation.py
"""
from repro.core import full_graph_train, sbm_graph


def main():
    g = sbm_graph(300, num_blocks=4, p_in=0.08, p_out=0.004, seed=0)
    print(f"{'protocol':28s} {'test_acc':>8s} {'final_loss':>10s} {'MB pushed':>10s}")
    sync = full_graph_train(g, epochs=60)
    print(f"{'sync (baseline)':28s} {sync.test_acc:8.3f} {sync.losses[-1]:10.4f} {'n/a':>10s}")
    for proto, kw in (
        ("epoch_fixed", dict(staleness=1)),
        ("epoch_fixed", dict(staleness=2)),
        ("epoch_fixed", dict(staleness=4)),
        ("epoch_fixed", dict(staleness=8)),
        ("epoch_adaptive", dict(staleness=4)),
        ("variation", dict(eps_v=0.01)),
        ("variation", dict(eps_v=0.1)),
    ):
        r = full_graph_train(g, protocol=proto, epochs=60, **kw)
        name = f"{proto}({kw})"
        print(f"{name:28s} {r.test_acc:8.3f} {r.losses[-1]:10.4f} "
              f"{r.bytes_pushed / 1e6:10.2f}")
    print("\nexpected pattern (the survey's claim): small bounds track sync "
          "accuracy with fewer bytes; large bounds degrade accuracy.")


if __name__ == "__main__":
    main()
