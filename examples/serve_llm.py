"""Batched serving example: prefill a batch of prompts through serve_step and
greedy-decode continuations with the KV cache (deliverable b, serving kind).

  PYTHONPATH=src python examples/serve_llm.py --arch llama3.2-1b --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import greedy_decode
from repro.models import transformer as T
from repro.utils import get_logger

log = get_logger("examples.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
                          jnp.int32)
    t0 = time.time()
    out = greedy_decode(cfg, params, prompts, args.max_new)
    dt = time.time() - t0
    n_tok = args.batch * (args.prompt_len + args.max_new)
    log.info("decoded %s in %.2fs (%.1f tok/s, batch=%d)", out.shape, dt,
             n_tok / dt, args.batch)
    log.info("sample continuation ids: %s", np.asarray(out)[0, :12])
    # determinism check: same prompts -> same tokens
    out2 = greedy_decode(cfg, params, prompts, args.max_new)
    assert (np.asarray(out) == np.asarray(out2)).all(), "non-deterministic decode"
    log.info("determinism check passed")
    # continuous batching: staggered arrivals share decode waves
    from repro.launch.batching import ContinuousBatchingEngine, Request

    eng = ContinuousBatchingEngine(cfg, params, slots=args.batch, max_len=64)
    rng2 = np.random.default_rng(1)
    for uid in range(args.batch * 2):
        eng.submit(Request(uid=uid,
                           prompt=rng2.integers(1, cfg.vocab_size, 8).astype(np.int32),
                           max_new=8))
    stats = eng.run_until_drained()
    log.info("continuous batching: %d reqs, %d tokens, %d ticks, occupancy %.2f",
             stats.requests_completed, stats.tokens_generated, stats.ticks,
             stats.mean_occupancy)


if __name__ == "__main__":
    main()
