"""Quickstart: the survey's pipeline end-to-end on one machine in ~a minute.

1. Build a synthetic community graph.
2. Partition it with the GNN-aware streaming partitioner (survey §4.2).
3. Train a GCN full-graph with the sync protocol, then with bounded-staleness
   historical embeddings (§7.2), and compare accuracy + bytes pushed.
4. Train a transformer smoke config for a few steps with the same framework.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import full_graph_train, sbm_graph
from repro.core.partition import PARTITIONERS


def main():
    print("== 1. data ==")
    g = sbm_graph(300, num_blocks=4, p_in=0.08, p_out=0.004, seed=0)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges")

    print("== 2. partition (survey §4.2) ==")
    for name in ("hash", "ldg", "metis_like"):
        part = PARTITIONERS[name](g, 4)
        print(f"  {name:12s} edge-cut={part.edge_cut_fraction(g):.3f} "
              f"balance={part.vertex_balance():.2f}")

    print("== 3. full-graph GNN training: sync vs bounded staleness (§6/§7) ==")
    sync = full_graph_train(g, epochs=60)
    print(f"  sync         test_acc={sync.test_acc:.3f}")
    for proto, kw in (("epoch_fixed", dict(staleness=2)),
                      ("variation", dict(eps_v=0.05))):
        r = full_graph_train(g, protocol=proto, epochs=60, **kw)
        print(f"  {proto:12s} test_acc={r.test_acc:.3f} "
              f"bytes_pushed={r.bytes_pushed / 1e6:.2f}MB")

    print("== 4. transformer smoke training (shared substrate) ==")
    from repro.launch.train import run_training

    losses = run_training("llama3.2-1b", steps=20, batch=4, seq=64, log_every=10)
    print(f"  llama3.2-1b smoke: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("done.")


if __name__ == "__main__":
    main()
