"""End-to-end distributed GNN training driver (the paper's workload).

Two modes:

* ``--engine`` (default): the DistGNNEngine — edge-cut partition plan +
  Pallas-ELL local multiply + selectable exchange execution model
  (broadcast | ring | p2p halo exchange) + sync/async-historical protocol,
  all inside ONE jitted shard_map train step.  Reports loss/accuracy, the
  collective bytes of the chosen model, and the oracle gap vs the
  single-device reference.  ``--batching node_wise|layer_wise|subgraph``
  switches to sampled mini-batches (survey §5): per-device targets from the
  owned partition block, statically padded sampled blocks, a device-resident
  feature cache (``--cache`` / ``--cache-capacity``), and the §6.1 stage
  schedules (``--schedule``); reports feature-fetch bytes + cache hits.
  With ``--schedule pipelined``, ``--prefetch-mode process`` moves the
  sampler into a GIL-free pool of ``--num-sample-workers`` worker processes
  over a shared-memory batch ring (bitwise-identical epochs, survey §6.1):
  process mode pays a one-time pool start-up, then wins whenever the
  thread sampler would fight XLA's dispatch for the GIL (no spare core) or
  epochs repeat — deterministic batches are served from the pool's LRU
  without resampling.  Thread mode remains the zero-setup default.
  ``--partition-family vertex_cut --vertex-cut random|cartesian2d|libra``
  switches the §4 partition family: edges are partitioned, vertices
  replicate, and the exchange becomes the replica-sync combine (partial
  aggregations over owned edges, master-masked loss); reports the
  replication factor and replica-sync bytes.
  ``--partition-family hybrid`` is the PowerLyra-style degree-threshold
  cut: low-degree vertices stay edge-cut-local behind the halo exchange
  while hubs (in-degree >= ``--hub-threshold``, default auto p95)
  replicate through the replica-sync combine; reports the threshold, hub
  count, and both wire legs.
* ``--no-engine``: the legacy dense-block SpMM execution models (survey
  Table 2) over a device mesh, kept as the survey-taxonomy reference.

Run with forced host devices to see real collectives on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_gnn_distributed.py --exec p2p --protocol epoch_adaptive

Reading a trace (``--trace-out t.json``, engine path):

Pass ``--trace-out t.json`` to record run-wide telemetry and write a Chrome
trace-event file — open it in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  What you see:

* one **row per lane** (thread): with ``--schedule pipelined`` the prefetch
  thread's ``sample``/``extract`` spans overlap the trainer lane's ``train``
  spans — the §6.1 overlap is directly visible as stacked rows;
* per-device ``sample_device`` child spans under each ``sample`` span, so a
  straggler partition shows up as one long bar (the workload-imbalance
  challenge, survey §2);
* zero-duration ``exchange`` instants carrying the wire-byte delta of each
  CommStats mutation in their args — their summed ``bytes`` equal
  ``CommStats.total()`` exactly;
* click any span: ``args`` holds step / device / bytes labels.

A step log (one JSON line per step: loss, cumulative comm bytes) is written
next to the trace as ``<trace-out>.steps.jsonl``, and a run summary —
per-stage seconds, per-device imbalance ratios (max/mean), metric totals,
and the compiled step's static collective bytes + peak memory from
``hlo_analysis.executable_summary`` — prints at exit.  Telemetry is
off-by-default and adds <5% overhead when on (asserted by
``benchmarks/bench_gnn.py --telemetry``).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    BATCHING_MODES,
    ENGINE_CACHE_POLICIES,
    EXECUTION_MODELS,
    GNN_MODELS,
    PROTOCOLS,
    DistGNNEngine,
    EngineConfig,
)
from repro.core.execution.spmm_models import SPMM_MODELS
from repro.core.graph import sbm_graph
from repro.core.models.gnn import accuracy, full_graph_forward, init_gnn_params, softmax_xent
from repro.core.partition import PARTITIONERS
from repro.launch.hlo_analysis import collective_bytes, executable_summary


def run_engine(args, g):
    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    layer_sizes = tuple(int(x) for x in args.layer_sizes.split(","))
    cfg = EngineConfig(execution=args.exec, protocol=args.protocol,
                       model=args.model,
                       partition_family=args.partition_family,
                       partitioner=args.partition,
                       vertex_cut=args.vertex_cut,
                       hub_threshold=args.hub_threshold, lr=args.lr,
                       batching=args.batching, batch_size=args.batch_size,
                       fanouts=fanouts, layer_sizes=layer_sizes,
                       walk_length=args.walk_length,
                       cache_policy=args.cache,
                       cache_capacity=args.cache_capacity,
                       exchange_chunks=args.exchange_chunks,
                       p2p_buckets=args.p2p_buckets,
                       prefetch_depth=args.prefetch_depth,
                       prefetch_mode=args.prefetch_mode,
                       num_sample_workers=args.num_sample_workers,
                       trainable_features=args.trainable_features,
                       embed_lr=args.embed_lr)
    n_dev = len(jax.devices())
    k = args.parts or n_dev
    assert k <= n_dev, f"need {k} devices, have {n_dev} (set XLA_FLAGS)"
    mesh = jax.make_mesh((k,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=cfg)
    tel = eng.enable_telemetry() if args.trace_out else eng.telemetry
    minibatch = args.batching != "full_graph"
    lowered = eng.lower_minibatch_step() if minibatch else eng.lower_step()
    compiled = lowered.compile()
    coll, kinds = collective_bytes(compiled.as_text())
    tel.attach_executable("minibatch_train_step" if minibatch else
                          "train_step", executable_summary(compiled))
    if args.partition_family == "vertex_cut":
        cut = (f"vertex_cut={args.vertex_cut} "
               f"(replication={eng.layout.replication_factor():.2f}, "
               f"nv={eng.nv})")
    elif args.partition_family == "hybrid":
        lay = eng.playout
        cut = (f"hybrid thr={lay.cut.threshold:g} "
               f"({int(lay.cut.hub.sum())} hubs, "
               f"replication={lay.layout.replication_factor():.2f})")
    else:
        cut = f"partition={args.partition}"
    print(f"engine: model={args.model} exec={args.exec} "
          f"protocol={args.protocol} "
          f"batching={args.batching} {cut} k={k} "
          f"(nb={eng.nb}, halo cap={getattr(eng, 'cap', '-')}"
          + (f", frontier caps={eng.caps} fcap={eng.fcap}" if minibatch else "")
          + f") collective bytes/step = {coll / 1e6:.2f} MB  {kinds}")
    if minibatch:
        state, losses, times = eng.run_epoch_minibatch(
            args.epochs, schedule=args.schedule)
        eng.close_prefetch_pool()  # no-op unless --prefetch-mode process ran
        s = eng.comm_stats
        print(f"schedule={args.schedule}: wall={times.wall:.3f}s "
              f"(sample={times.sample:.3f} extract={times.extract:.3f} "
              f"train={times.train:.3f})")
        print(f"feature fetch: {s.pull_bytes / 1e6:.3f} MB pulled, "
              f"{s.cache_hit_bytes / 1e6:.3f} MB served by the "
              f"{args.cache!r} cache "
              f"({s.cache_hit_bytes / max(s.requested(), 1):.1%} hit bytes)")
        if args.trainable_features:
            print(f"trainable embeddings: {s.embed_grad_bytes / 1e6:.3f} MB "
                  f"gradient rows routed to owners (+ overlay refresh) over "
                  f"{args.epochs} steps")
        batch = eng.sample_minibatch(args.epochs - 1)
        _, _, logits = eng.make_minibatch_step()(state, batch)
        acc = eng.minibatch_accuracy(logits, batch)
        for e in range(0, args.epochs, max(args.epochs // 4, 1)):
            print(f"epoch {e:3d} loss {losses[e]:.4f}")
        print(f"final: batch train_acc={acc:.3f}")
    else:
        losses, logits = eng.train(args.epochs)
        for e in range(0, args.epochs, max(args.epochs // 4, 1)):
            print(f"epoch {e:3d} loss {losses[e]:.4f}")
        if args.partition_family == "vertex_cut":
            s = eng.comm_stats
            print(f"replica sync: {s.replica_sync_bytes / 1e6:.3f} MB over "
                  f"{args.epochs} steps ({args.exec} combine)")
        elif args.partition_family == "hybrid":
            s = eng.comm_stats
            print(f"hybrid wire: {s.halo_bytes / 1e6:.3f} MB halo (low-degree"
                  f" srcs) + {s.replica_sync_bytes / 1e6:.3f} MB replica sync"
                  f" (hubs) over {args.epochs} steps ({args.exec})")
        if args.trainable_features:
            print(f"trainable embeddings: "
                  f"{eng.comm_stats.embed_grad_bytes / 1e6:.3f} MB gradient "
                  f"rows routed to owners over {args.epochs} steps")
        print(f"final: train_acc={eng.accuracy(logits, 'train'):.3f} "
              f"test_acc={eng.accuracy(logits, 'test'):.3f}")
    if args.oracle_check:
        ref_losses, _ = eng.train(args.epochs, reference=True)
        gap = max(abs(a - b) for a, b in zip(losses, ref_losses))
        print(f"oracle gap (max |loss_dist - loss_ref|) = {gap:.2e}")
    if args.infer:
        if minibatch:
            infer_state = state
        else:  # train() keeps its state internal: replay the same stream
            step = eng.make_step()
            infer_state = eng.init_state()
            for _ in range(args.epochs):
                infer_state, _, _ = step(infer_state)
        emb = eng.global_embeddings(eng.infer_full_graph(infer_state))
        ref = eng.global_embeddings(
            eng.infer_full_graph(infer_state, reference=True))
        err = float(np.max(np.abs(emb - ref)))
        print(f"layer-wise inference sweep: embeddings {emb.shape}, "
              f"{eng.inference_bytes_per_sweep() / 1e6:.3f} MB/sweep "
              f"({eng.comm_stats.inference_bytes / 1e6:.3f} MB accounted), "
              f"oracle gap {err:.2e}")
    if args.trace_out:
        tel.write_chrome_trace(args.trace_out)
        tel.write_step_log(args.trace_out + ".steps.jsonl")
        summary = tel.run_summary()
        secs = summary["spans"]["seconds_by_name"]
        print("telemetry: "
              + " ".join(f"{n}={s:.3f}s" for n, s in sorted(secs.items())))
        for name, rec in sorted(summary["imbalance"]["metrics"].items()):
            print(f"  imbalance {name}: max/mean={rec['max_over_mean']:.2f}")
        print(f"  trace -> {args.trace_out} "
              f"({summary['spans']['count']} spans), "
              f"step log -> {args.trace_out}.steps.jsonl")


def run_legacy(args, g):
    n_dev = len(jax.devices())
    k = args.parts or n_dev
    assert k <= n_dev, f"need {k} devices, have {n_dev} (set XLA_FLAGS)"

    # partition + relabel so device row-blocks align with partitions
    part = PARTITIONERS[args.partition](g, k)
    order = np.argsort(part.assignment, kind="stable")
    A = jnp.asarray(g.to_dense_adj()[np.ix_(order, order)])
    X = jnp.asarray(g.features[order])
    y = jnp.asarray(g.labels[order].astype(np.int32))
    train_m = jnp.asarray(g.train_mask[order].astype(np.float32))
    test_m = jnp.asarray(g.test_mask[order].astype(np.float32))

    if args.exec in ("spmm_2d", "spmm_15d"):
        r = int(np.sqrt(k))
        while k % r:
            r -= 1
        mesh = jax.make_mesh((r, k // r), ("r", "c"))
    else:
        mesh = jax.make_mesh((k,), ("w",))
    spmm = SPMM_MODELS[args.exec]

    def aggregate(A_, H_):
        return spmm(mesh, A_, H_)

    dims = [g.features.shape[1], 32, int(g.labels.max()) + 1]
    params = init_gnn_params("gcn", dims, jax.random.PRNGKey(0))

    def loss_fn(p):
        logits = full_graph_forward("gcn", p, A, X, aggregate=aggregate)
        return softmax_xent(logits, y, train_m), logits

    @jax.jit
    def step(p):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p = jax.tree_util.tree_map(lambda a, g_: a - 0.5 * g_, p, grads)
        return p, loss, logits

    comp = step.lower(params).compile()
    coll, kinds = collective_bytes(comp.as_text())
    print(f"execution model {args.exec} on {mesh.devices.shape} mesh: "
          f"collective bytes/step = {coll / 1e6:.2f} MB  {kinds}")

    logits = None
    for e in range(args.epochs):
        params, loss, logits = step(params)
        if e % 10 == 0:
            print(f"epoch {e:3d} loss {float(loss):.4f}")
    print(f"final: train_acc={float(accuracy(logits, y, train_m)):.3f} "
          f"test_acc={float(accuracy(logits, y, test_m)):.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the DistGNNEngine (ELL + halo exchange); "
                    "--no-engine runs the legacy dense-block SpMM models")
    ap.add_argument("--exec", default=None,
                    help=f"engine: {EXECUTION_MODELS} (default p2p); "
                    f"legacy: {list(SPMM_MODELS)} (default spmm_1d)")
    ap.add_argument("--protocol", default="sync", choices=list(PROTOCOLS))
    ap.add_argument("--model", default="gcn", choices=list(GNN_MODELS),
                    help="engine GNN layer program (§3 model axis): gcn | "
                    "sage | gat | gin — gat runs distributed edge-wise "
                    "attention (SDDMM logits + masked segment-softmax; "
                    "two-pass replica sync under vertex_cut)")
    ap.add_argument("--batching", default="full_graph",
                    choices=list(BATCHING_MODES),
                    help="engine §5 batch generation: full_graph partition "
                    "batches or sampled mini-batches")
    ap.add_argument("--batch-size", type=int, default=16,
                    help="per-device mini-batch targets / walk roots")
    ap.add_argument("--fanouts", default="4,4",
                    help="node_wise: comma-separated per-layer fanouts")
    ap.add_argument("--layer-sizes", default="32,32",
                    help="layer_wise: comma-separated per-layer sample sizes")
    ap.add_argument("--walk-length", type=int, default=4,
                    help="subgraph: random-walk length")
    ap.add_argument("--cache", default="none",
                    choices=list(ENGINE_CACHE_POLICIES),
                    help="device-resident feature cache policy")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="remote feature rows cached per device")
    ap.add_argument("--schedule", default="conventional",
                    choices=["conventional", "factored", "operator_parallel",
                             "pipelined"],
                    help="mini-batch stage schedule (survey §6.1); "
                    "'pipelined' runs the REAL double-buffered sampler "
                    "(prefetch thread + async step dispatch)")
    ap.add_argument("--trainable-features",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="layer-0 rows are learnable embedding-store rows "
                    "updated by row-sparse AdamW (requires protocol=sync)")
    ap.add_argument("--embed-lr", type=float, default=0.1,
                    help="sparse-AdamW learning rate for the embedding rows")
    ap.add_argument("--prefetch-mode", default="thread",
                    choices=["thread", "process"],
                    help="pipelined schedule's producer: 'thread' shares "
                    "the trainer's GIL (wins only with a spare core); "
                    "'process' runs sampling in a GIL-free worker-process "
                    "pool over a shared-memory batch ring — pays a "
                    "process-start + pickle cost up front, wins whenever "
                    "host sampling competes with XLA for the GIL or "
                    "epochs repeat (deterministic batches are LRU-cached)")
    ap.add_argument("--num-sample-workers", type=int, default=2,
                    help="worker processes for --prefetch-mode process")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="pipelined schedule: batches sampled ahead of the "
                    "device step (bounded queue depth)")
    ap.add_argument("--exchange-chunks", type=int, default=1,
                    help="feature-dim chunks overlapping the broadcast/p2p "
                    "collectives with the ELL multiply (1 = monolithic)")
    ap.add_argument("--p2p-buckets", type=int, default=1,
                    help="power-of-two installments splitting the p2p "
                    "all_to_all send caps (smaller lowered buffers)")
    ap.add_argument("--parts", type=int, default=0, help="0 = all devices")
    ap.add_argument("--partition", default="metis_like")
    ap.add_argument("--partition-family", default="edge_cut",
                    choices=["edge_cut", "vertex_cut", "hybrid"],
                    help="engine §4 partition family: edge-cut halo exchange, "
                    "vertex-cut replica sync (replicated vertices, "
                    "master-masked loss), or the PowerLyra-style hybrid "
                    "degree-threshold cut (hubs replicate, the rest stay "
                    "edge-cut-local)")
    ap.add_argument("--vertex-cut", default="cartesian2d",
                    choices=["random", "cartesian2d", "libra"],
                    help="vertex-cut partitioner (with "
                    "--partition-family vertex_cut)")
    ap.add_argument("--hub-threshold", type=float, default=None,
                    help="hybrid: in-degree at/above which a vertex is a "
                    "replicated hub (default: auto 95th percentile; inf -> "
                    "pure edge-cut dataflow, 0 -> pure vertex-cut)")
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--vertices", type=int, default=512)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--trace-out", default=None, metavar="t.json",
                    help="engine: enable run-wide telemetry and write a "
                    "Chrome trace-event file here (open in Perfetto / "
                    "chrome://tracing; see the module docstring for how to "
                    "read it) plus a <path>.steps.jsonl step log; prints "
                    "per-stage seconds and per-device imbalance ratios")
    ap.add_argument("--oracle-check", action="store_true",
                    help="engine: also run the single-device reference and "
                    "report the max loss gap")
    ap.add_argument("--infer", action="store_true",
                    help="engine: after training, run the layer-wise "
                    "full-graph inference sweep (embeddings for every "
                    "vertex in O(L) exchanges) and report its oracle gap; "
                    "K-target query serving lives in "
                    "`python -m repro.launch.serve_gnn`")
    args = ap.parse_args()

    if args.exec is None:
        args.exec = "p2p" if args.engine else "spmm_1d"
    elif args.exec not in set(EXECUTION_MODELS) | set(SPMM_MODELS):
        ap.error(f"--exec must be one of {EXECUTION_MODELS} (engine) or "
                 f"{list(SPMM_MODELS)} (legacy), got {args.exec!r}")
    if args.engine and args.exec in SPMM_MODELS:
        args.engine = False  # legacy exec name given: run the legacy path
    if not args.engine and args.exec not in SPMM_MODELS:
        ap.error(f"--no-engine requires a legacy exec name {list(SPMM_MODELS)}, "
                 f"got {args.exec!r}")
    if args.batching != "full_graph" and not args.engine:
        ap.error("mini-batch --batching modes run on the engine path only")
    if args.trace_out and not args.engine:
        ap.error("--trace-out instruments the engine path only")
    if args.partition_family != "edge_cut":
        if not args.engine:
            ap.error(f"--partition-family {args.partition_family} runs on "
                     "the engine path only")
        if args.batching != "full_graph":
            ap.error(f"{args.partition_family} supports --batching "
                     "full_graph only (replica-family mini-batch sampling "
                     "is a ROADMAP follow-up)")
    g = sbm_graph(args.vertices, num_blocks=8, p_in=0.05, p_out=0.003, seed=0)
    if args.engine:
        run_engine(args, g)
    else:
        run_legacy(args, g)


if __name__ == "__main__":
    main()
