"""End-to-end distributed GNN training driver (the paper's workload):

- partitions a power-law graph with a selectable partitioner,
- runs full-graph training whose aggregation executes under a selectable
  distributed-SpMM execution model (survey Table 2) over a real device mesh,
- reports loss/accuracy and the collective bytes of the chosen model.

Run with forced host devices to see real collectives on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_gnn_distributed.py --exec spmm_1d --parts 8
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execution.spmm_models import SPMM_MODELS
from repro.core.graph import sbm_graph
from repro.core.models.gnn import accuracy, full_graph_forward, init_gnn_params, softmax_xent
from repro.core.partition import PARTITIONERS
from repro.launch.hlo_analysis import collective_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", default="spmm_1d", choices=list(SPMM_MODELS))
    ap.add_argument("--parts", type=int, default=0, help="0 = all devices")
    ap.add_argument("--partition", default="metis_like")
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--vertices", type=int, default=512)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    k = args.parts or n_dev
    assert k <= n_dev, f"need {k} devices, have {n_dev} (set XLA_FLAGS)"
    g = sbm_graph(args.vertices, num_blocks=8, p_in=0.05, p_out=0.003, seed=0)

    # partition + relabel so device row-blocks align with partitions
    part = PARTITIONERS[args.partition](g, k)
    order = np.argsort(part.assignment, kind="stable")
    A = jnp.asarray(g.to_dense_adj()[np.ix_(order, order)])
    X = jnp.asarray(g.features[order])
    y = jnp.asarray(g.labels[order].astype(np.int32))
    train_m = jnp.asarray(g.train_mask[order].astype(np.float32))
    test_m = jnp.asarray(g.test_mask[order].astype(np.float32))

    if args.exec in ("spmm_2d", "spmm_15d"):
        r = int(np.sqrt(k))
        while k % r:
            r -= 1
        mesh = jax.make_mesh((r, k // r), ("r", "c"))
    else:
        mesh = jax.make_mesh((k,), ("w",))
    spmm = SPMM_MODELS[args.exec]

    def aggregate(A_, H_):
        return spmm(mesh, A_, H_)

    dims = [g.features.shape[1], 32, int(g.labels.max()) + 1]
    params = init_gnn_params("gcn", dims, jax.random.PRNGKey(0))

    def loss_fn(p):
        logits = full_graph_forward("gcn", p, A, X, aggregate=aggregate)
        return softmax_xent(logits, y, train_m), logits

    @jax.jit
    def step(p):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p = jax.tree_util.tree_map(lambda a, g_: a - 0.5 * g_, p, grads)
        return p, loss, logits

    comp = step.lower(params).compile()
    coll, kinds = collective_bytes(comp.as_text())
    print(f"execution model {args.exec} on {mesh.devices.shape} mesh: "
          f"collective bytes/step = {coll / 1e6:.2f} MB  {kinds}")

    logits = None
    for e in range(args.epochs):
        params, loss, logits = step(params)
        if e % 10 == 0:
            print(f"epoch {e:3d} loss {float(loss):.4f}")
    print(f"final: train_acc={float(accuracy(logits, y, train_m)):.3f} "
          f"test_acc={float(accuracy(logits, y, test_m)):.3f}")


if __name__ == "__main__":
    main()
