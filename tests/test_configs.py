"""Assignment-table fidelity: every production config matches the assigned
numbers exactly; every smoke config respects the reduction contract."""
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_smoke_config

EXPECTED = {
    "qwen2-vl-72b": dict(family="vlm", num_layers=80, d_model=8192, num_heads=64,
                         num_kv_heads=8, d_ff=29568, vocab_size=152064),
    "kimi-k2-1t-a32b": dict(family="moe", num_layers=61, d_model=7168, num_heads=64,
                            num_kv_heads=8, d_ff=2048, vocab_size=163840,
                            num_experts=384, moe_top_k=8),
    "chatglm3-6b": dict(family="dense", num_layers=28, d_model=4096, num_heads=32,
                        num_kv_heads=2, d_ff=13696, vocab_size=65024),
    "seamless-m4t-large-v2": dict(family="audio", num_layers=24, d_model=1024,
                                  num_heads=16, num_kv_heads=16, d_ff=8192,
                                  vocab_size=256206, is_encoder_decoder=True),
    "deepseek-v2-236b": dict(family="moe", num_layers=60, d_model=5120,
                             num_heads=128, num_kv_heads=128, d_ff=1536,
                             vocab_size=102400, num_experts=160, moe_top_k=6,
                             use_mla=True, kv_lora_rank=512),
    "qwen1.5-32b": dict(family="dense", num_layers=64, d_model=5120, num_heads=40,
                        num_kv_heads=40, d_ff=27392, vocab_size=152064,
                        qkv_bias=True),
    "llama3.2-1b": dict(family="dense", num_layers=16, d_model=2048, num_heads=32,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "rwkv6-3b": dict(family="ssm", num_layers=32, d_model=2560, d_ff=8960,
                     vocab_size=65536, ssm_kind="rwkv6"),
    "llama3.2-3b": dict(family="dense", num_layers=28, d_model=3072, num_heads=24,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "zamba2-1.2b": dict(family="hybrid", num_layers=38, d_model=2048, num_heads=32,
                        num_kv_heads=32, d_ff=8192, vocab_size=32000,
                        ssm_state=64, ssm_kind="mamba2"),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_production_config_matches_assignment(arch):
    cfg = get_config(arch)
    for key, val in EXPECTED[arch].items():
        assert getattr(cfg, key) == val, (arch, key, getattr(cfg, key), val)
    assert cfg.source  # citation present


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_config_reduction_contract(arch):
    cfg = get_smoke_config(arch)
    full = get_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == full.family
    assert cfg.ssm_kind == full.ssm_kind
    assert cfg.use_mla == full.use_mla
    assert cfg.is_encoder_decoder == full.is_encoder_decoder


def test_shapes_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_param_counts_in_expected_range():
    # analytic totals should land near the model names
    assert 60e9 < get_config("qwen2-vl-72b").num_params() < 85e9
    assert 0.8e12 < get_config("kimi-k2-1t-a32b").num_params() < 1.3e12
    assert 25e9 < get_config("kimi-k2-1t-a32b").num_active_params() < 40e9
    assert 180e9 < get_config("deepseek-v2-236b").num_params() < 280e9
    assert 1.0e9 < get_config("llama3.2-1b").num_params() < 1.7e9
    assert 2.4e9 < get_config("rwkv6-3b").num_params() < 4.5e9
