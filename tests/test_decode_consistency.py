"""Decode == full forward: run the prompt token-by-token through serve_step and
compare final-position logits against the full-sequence forward. This is the
strongest end-to-end correctness check for KV caches, MLA absorption, SSM
recurrences, and the hybrid shared-attention cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.kvcache import init_cache

ARCHS = ["llama3.2-1b", "chatglm3-6b", "deepseek-v2-236b", "kimi-k2-1t-a32b",
         "rwkv6-3b", "zamba2-1.2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        # MoE capacity dropping is batch-size dependent (decode routes one
        # token, forward routes twelve) — use drop-free capacity so the
        # consistency check isolates the cache/recurrence math.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    tokens = jnp.asarray(np.random.default_rng(2).integers(1, cfg.vocab_size, (B, S)),
                         jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    h, _, _ = T.forward(cfg, params, {"tokens": tokens, "positions": positions})
    from repro.models.transformer import lm_head, rmsnorm  # noqa

    logits_full = jnp.einsum("bd,dv->bv", h[:, -1],
                             T.lm_head(cfg, params).astype(h.dtype))
    cache = init_cache(cfg, B, S + 4)
    step = jax.jit(lambda p, c, t, i: T.serve_step(cfg, p, c, t, i))
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=0.12, rtol=0.12)  # bf16 accumulation paths differ


def test_sampled_decode_keeps_int32_token_contract():
    """ISSUE 7 bugfix: greedy_decode's SAMPLED branch must cast the
    categorical draw to int32 like the greedy branch does.  Under x64 (where
    jax.random.categorical returns int64 by default) the pre-fix code fed
    int64 tokens back into the jitted step — a silent dtype change that
    retriggers compilation every decode step.  Runs in a subprocess so
    JAX_ENABLE_X64 can't leak into other tests."""
    import os
    import subprocess
    import sys
    import textwrap

    from conftest import SRC

    code = """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.serve import greedy_decode
    from repro.models import transformer as T

    assert jax.config.read("jax_enable_x64"), "x64 mode not active"
    cfg = get_smoke_config("llama3.2-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 4), jnp.int32)

    # the sampled branch draws through jax.random.categorical — int64 here
    # without the explicit cast
    sampled = jax.random.categorical(jax.random.PRNGKey(1),
                                     jnp.zeros((2, 8)))
    assert sampled.dtype == jnp.int64, sampled.dtype  # x64 default

    compiles = []
    step = jax.jit(lambda p, c, t, i: T.serve_step(cfg, p, c, t, i))
    toks = greedy_decode(cfg, params, prompt, max_new=3, temperature=0.7,
                         key=jax.random.PRNGKey(2))
    assert toks.dtype == jnp.int32, f"sampled decode emitted {toks.dtype}"

    # feeding the decode's own output tokens back into a fresh jitted step
    # must not retrace: one compile for the whole token stream
    from repro.models.kvcache import init_cache
    cache = init_cache(cfg, 2, 16)
    logits, cache = step(params, cache, toks[:, :1], jnp.int32(0))
    for i in range(1, toks.shape[1]):
        logits, cache = step(params, cache, toks[:, i:i+1], jnp.int32(i))
    assert step._cache_size() == 1, step._cache_size()
    print("SAMPLED_DECODE_OK")
    """
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=420,
                          env=env)
    assert proc.returncode == 0, (proc.stdout, proc.stderr[-4000:])
    assert "SAMPLED_DECODE_OK" in proc.stdout
