"""Decode == full forward: run the prompt token-by-token through serve_step and
compare final-position logits against the full-sequence forward. This is the
strongest end-to-end correctness check for KV caches, MLA absorption, SSM
recurrences, and the hybrid shared-attention cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.kvcache import init_cache

ARCHS = ["llama3.2-1b", "chatglm3-6b", "deepseek-v2-236b", "kimi-k2-1t-a32b",
         "rwkv6-3b", "zamba2-1.2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        # MoE capacity dropping is batch-size dependent (decode routes one
        # token, forward routes twelve) — use drop-free capacity so the
        # consistency check isolates the cache/recurrence math.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    tokens = jnp.asarray(np.random.default_rng(2).integers(1, cfg.vocab_size, (B, S)),
                         jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    h, _, _ = T.forward(cfg, params, {"tokens": tokens, "positions": positions})
    from repro.models.transformer import lm_head, rmsnorm  # noqa

    logits_full = jnp.einsum("bd,dv->bv", h[:, -1],
                             T.lm_head(cfg, params).astype(h.dtype))
    cache = init_cache(cfg, B, S + 4)
    step = jax.jit(lambda p, c, t, i: T.serve_step(cfg, p, c, t, i))
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=0.12, rtol=0.12)  # bf16 accumulation paths differ
