"""Prefill correctness: prefill(prompt) logits must equal the last step of
token-by-token decode, and the returned cache must continue correctly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.kvcache import init_cache

ARCHS = ["llama3.2-1b", "rwkv6-3b", "deepseek-v2-236b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_logits_match_decode(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    tokens = jnp.asarray(np.random.default_rng(3).integers(1, cfg.vocab_size, (B, S)),
                         jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    logits_pf, cache_pf = T.prefill(cfg, params, {"tokens": tokens,
                                                  "positions": positions})
    # step-by-step decode
    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: T.serve_step(cfg, p, c, t, i))
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits, np.float32), atol=0.1, rtol=0.1)


def test_prefill_cache_continues_decoding():
    """Dense arch: decode from the prefill cache must match decode from a
    step-by-step-built cache."""
    cfg = get_smoke_config("llama3.2-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, Tmax = 2, 8, 12
    tokens = jnp.asarray(np.random.default_rng(4).integers(1, cfg.vocab_size, (B, S)),
                         jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, cache_pf = T.prefill(cfg, params, {"tokens": tokens, "positions": positions})
    # pad prefill cache (T=S) out to Tmax
    cache_pad = {k: jnp.pad(v, ((0, 0), (0, 0), (0, Tmax - S)) + ((0, 0),) * (v.ndim - 3))
                 for k, v in cache_pf.items()}
    nxt = tokens[:, -1:]
    logits_a, _ = T.serve_step(cfg, params, cache_pad, nxt, jnp.int32(S))
    cache_b = init_cache(cfg, B, Tmax)
    for i in range(S):
        _, cache_b = T.serve_step(cfg, params, cache_b, tokens[:, i : i + 1], jnp.int32(i))
    logits_b, _ = T.serve_step(cfg, params, cache_b, nxt, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_a, np.float32),
                               np.asarray(logits_b, np.float32), atol=0.1, rtol=0.1)
