"""PrefetchWorker failure-mode tier (sampling/prefetch.py).

The double-buffered sampler lane must never hang or orphan its thread, no
matter which lane dies or where: producer exceptions (first item, mid-epoch,
last item, BaseException) relay to the consumer at the position they
occurred with the thread already stopped; the consumer abandoning mid-epoch
— including while the producer is blocked on a FULL queue — always joins on
close(); and the tightest legal pipeline (depth=1) completes in order under
backpressure from either side."""
import threading
import time

import pytest

from repro.core.execution.minibatch_pipeline import run_pipelined
from repro.core.sampling.prefetch import PrefetchWorker


def _thread_count():
    return sum(t.name == "prefetch-sampler" and t.is_alive()
               for t in threading.enumerate())


@pytest.mark.parametrize("fail_at,n", [(0, 5), (4, 5)])
def test_exception_relay_positions(fail_at, n):
    """A producer exception on the FIRST or the LAST item surfaces in the
    consumer exactly after the preceding results, and the thread is gone."""
    def produce(i):
        if i == fail_at:
            raise ValueError(f"boom at {i}")
        return i

    w = PrefetchWorker(range(n), produce, depth=2)
    got = []
    with pytest.raises(ValueError, match=f"boom at {fail_at}"):
        for item in w:
            got.append(item)
    assert got == list(range(fail_at))
    assert not w.alive
    w.close()  # close after a relayed failure is a no-op, not an error
    assert not w.alive


def test_base_exception_relays():
    """KeyboardInterrupt in the sampler lane must not vanish into the
    daemon thread — the consumer sees it."""
    def produce(i):
        if i == 1:
            raise KeyboardInterrupt
        return i

    w = PrefetchWorker(range(3), produce, depth=1)
    it = iter(w)
    assert next(it) == 0
    with pytest.raises(KeyboardInterrupt):
        next(it)
    assert not w.alive


def test_close_unblocks_producer_stuck_on_full_queue():
    """Consumer dies mid-epoch at depth=1 with the producer mid-put: close()
    must drain, signal, and join — bounded time, idempotent."""
    started = threading.Event()

    def produce(i):
        started.set()
        return i

    w = PrefetchWorker(range(10_000), produce, depth=1)
    assert started.wait(5.0)
    assert next(iter(w)) == 0  # consume one, then abandon
    t0 = time.monotonic()
    w.close()
    w.close()  # idempotent
    assert time.monotonic() - t0 < 5.0
    assert not w.alive
    assert _thread_count() == 0


def test_close_before_first_next_joins():
    """Abandoning before consuming anything still shuts the lane down."""
    w = PrefetchWorker(range(100), lambda i: i, depth=1)
    w.close()
    assert not w.alive


def test_depth1_no_deadlock_slow_consumer_and_producer():
    """The tightest pipeline, both lanes alternately slow: every item
    arrives, strictly in order, no deadlock."""
    def produce(i):
        if i % 3 == 0:
            time.sleep(0.002)
        return i * 2

    w = PrefetchWorker(range(40), produce, depth=1)
    got = []
    for item in w:
        if len(got) % 4 == 0:
            time.sleep(0.002)
        got.append(item)
    assert got == [i * 2 for i in range(40)]
    w.close()
    assert not w.alive


def test_stall_counters_one_event_per_contiguous_stall():
    """Stall counters measure the PIPELINE, not the poll loop: one event per
    contiguous stall (a poll-proportional count would scale with the 0.05s/
    0.1s timeouts), with the duration on the *_seconds companion."""
    from repro.core.telemetry import Telemetry

    # producer side: instant producer vs a consumer that holds the depth-1
    # queue full across two long pauses -> exactly 2 contiguous stalls
    tel = Telemetry(enabled=True)
    w = PrefetchWorker(range(2), lambda i: i, depth=1, telemetry=tel)
    it = iter(w)
    time.sleep(0.35)  # item0 queued instantly; producer stalls on item1
    assert next(it) == 0
    time.sleep(0.35)  # producer stalls again on the _DONE sentinel
    assert next(it) == 1
    with pytest.raises(StopIteration):
        next(it)
    w.close()
    events = tel.metrics.counter("prefetch.producer_stall").value
    secs = tel.metrics.counter("prefetch.producer_stall_seconds").value
    assert events == 2, events  # ~14 under per-poll counting
    assert 0.5 <= secs < 5.0, secs

    # consumer side: slow producer starves the trainer once per batch ->
    # exactly 2 contiguous stalls (the _DONE sentinel follows the last item
    # immediately, so it adds none)
    tel2 = Telemetry(enabled=True)
    w2 = PrefetchWorker(range(2), lambda i: time.sleep(0.3) or i, depth=2,
                        telemetry=tel2)
    assert list(w2) == [0, 1]
    w2.close()
    events = tel2.metrics.counter("prefetch.consumer_stall").value
    secs = tel2.metrics.counter("prefetch.consumer_stall_seconds").value
    assert events == 2, events  # ~6 under per-poll counting
    # each contiguous stall is timed from its FIRST empty poll (one 0.1s
    # timeout late), so two 0.3s waits record >= ~0.4s
    assert 0.3 <= secs < 5.0, secs


def test_run_pipelined_depth1_failure_joins_worker():
    """The engine's pipelined epoch driver at depth=1: a device-lane death
    mid-epoch propagates and leaves no live sampler thread behind."""
    calls = []

    def train(mb, feats):
        calls.append(feats)
        if len(calls) == 3:
            raise RuntimeError("device lane died")

    with pytest.raises(RuntimeError, match="device lane died"):
        run_pipelined(list(range(200)), lambda i: i, lambda mb: mb + 1,
                      train, prefetch_depth=1)
    assert calls == [1, 2, 3]
    deadline = time.monotonic() + 5.0
    while _thread_count() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert _thread_count() == 0
