"""Hypothesis property tests on the §5 sampler invariants the engine's
mini-batch path relies on: sampled blocks only reference in-frontier
vertices, fanout / layer-size bounds hold (so the static padding caps are
true upper bounds), and MiniBatch relabeling round-trips to global ids.

Requires the optional ``hypothesis`` dependency (the ``property`` test extra);
without it the whole module degrades to a skip instead of a collection error.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.graph import er_graph, powerlaw_graph
from repro.core.sampling import (
    frontier_caps,
    layer_wise_sample,
    node_wise_sample,
    pad_minibatch,
    subgraph_sample,
)

SETTINGS = dict(max_examples=20, deadline=None)


def _check_blocks_in_frontier(g, mb):
    """Every nonzero block entry must be a real edge (or the self loop), with
    both endpoints inside the declared frontiers."""
    for l, A in enumerate(mb.layer_adj):
        rows = mb.layer_vertices[l + 1]
        cols = mb.layer_vertices[l]
        assert A.shape == (len(rows), len(cols))
        for i, j in zip(*np.nonzero(A)):
            src, dst = int(cols[j]), int(rows[i])
            assert src == dst or src in set(g.neighbors(dst).tolist()), (
                f"layer {l}: block references non-edge {src}->{dst}")


@given(st.integers(40, 120), st.integers(1, 8), st.integers(1, 4),
       st.integers(1, 4), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_node_wise_in_frontier_and_fanout_bounds(n, B, f1, f2, seed):
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    rng = np.random.default_rng(seed)
    targets = rng.choice(n, size=min(B, n), replace=False)
    mb = node_wise_sample(g, targets, (f1, f2), rng)
    _check_blocks_in_frontier(g, mb)
    # per-row sampled degree bounded by fanout (+1 self loop)
    fanouts = (f1, f2)
    for l, A in enumerate(mb.layer_adj):
        # layer_adj[0] is the INPUT-side block, built with the LAST fanout
        fan = fanouts[len(fanouts) - 1 - l]
        assert (np.count_nonzero(A, axis=1) <= fan + 1).all()
    # frontier sizes bounded by the static padding caps
    caps = frontier_caps("node_wise", 2, len(targets), fanouts=fanouts,
                         num_vertices=n)
    for l, lv in enumerate(mb.layer_vertices):
        assert len(lv) <= caps[l], (l, len(lv), caps)


@given(st.integers(40, 120), st.integers(1, 8), st.integers(4, 32),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_layer_wise_sizes_respected(n, B, size, seed):
    g = powerlaw_graph(n, avg_degree=6, seed=seed % 11)
    rng = np.random.default_rng(seed)
    targets = rng.choice(n, size=min(B, n), replace=False)
    sizes = (size, size)
    mb = layer_wise_sample(g, targets, sizes, rng)
    _check_blocks_in_frontier(g, mb)
    # each expansion adds at most `size` new vertices to the frontier
    L = len(sizes)
    for j, s in enumerate(sizes, start=1):
        grown, prev = mb.layer_vertices[L - j], mb.layer_vertices[L - j + 1]
        assert len(grown) <= len(prev) + s
        assert set(prev.tolist()) <= set(grown.tolist())  # nested frontiers
    caps = frontier_caps("layer_wise", L, len(targets), layer_sizes=sizes,
                         num_vertices=n)
    for l, lv in enumerate(mb.layer_vertices):
        assert len(lv) <= caps[l]


@given(st.integers(40, 100), st.integers(1, 6), st.integers(0, 8),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_subgraph_walk_bounded(n, roots, walk, seed):
    g = er_graph(n, avg_degree=5, seed=seed % 7)
    rng = np.random.default_rng(seed)
    r = rng.choice(n, size=min(roots, n), replace=False)
    mb = subgraph_sample(g, r, walk_length=walk, rng=rng)
    caps = frontier_caps("subgraph", 2, len(r), walk_length=walk,
                         num_vertices=n)
    for l, lv in enumerate(mb.layer_vertices):
        assert len(lv) <= caps[l]
    # induced subgraph: square blocks over one vertex set
    assert mb.layer_adj[0].shape[0] == mb.layer_adj[0].shape[1]


@given(st.integers(40, 120), st.integers(1, 8), st.integers(1, 4),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_minibatch_relabel_round_trips(n, B, fan, seed):
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    rng = np.random.default_rng(seed)
    targets = rng.choice(n, size=min(B, n), replace=False)
    mb = node_wise_sample(g, targets, (fan, fan), rng)
    local = mb.relabel()
    lv0 = mb.layer_vertices[0]
    # batch-local ids -> global ids round-trips every frontier and the targets
    for l in range(len(mb.layer_vertices)):
        np.testing.assert_array_equal(
            lv0[local.layer_vertices[l]], mb.layer_vertices[l])
    np.testing.assert_array_equal(lv0[local.targets], mb.targets)
    # self_indices: positions of layer l+1 vertices inside layer l
    for l, idx in enumerate(mb.self_indices()):
        np.testing.assert_array_equal(
            mb.layer_vertices[l][idx], mb.layer_vertices[l + 1])


@given(st.integers(40, 100), st.integers(1, 6), st.integers(1, 3),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_pad_minibatch_is_inert(n, B, fan, seed):
    """Padding never drops data: real entries survive verbatim, pad slots are
    zero-masked, and padded block rows/cols beyond the real shape are zero."""
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    rng = np.random.default_rng(seed)
    targets = rng.choice(n, size=min(B, n), replace=False)
    mb = node_wise_sample(g, targets, (fan, fan), rng)
    caps = frontier_caps("node_wise", 2, len(targets), fanouts=(fan, fan),
                         num_vertices=n)
    padded = pad_minibatch(mb, caps)
    nin = mb.num_input_vertices
    np.testing.assert_array_equal(padded["frontier"][:nin],
                                  mb.layer_vertices[0])
    assert (padded["frontier"][nin:] == -1).all()
    assert padded["fmask"].sum() == nin
    assert padded["tmask"].sum() == len(mb.targets)
    for l, A in enumerate(mb.layer_adj):
        P = padded["adj"][l]
        assert P.shape == (caps[l + 1], caps[l])
        np.testing.assert_array_equal(P[: A.shape[0], : A.shape[1]], A)
        assert P[A.shape[0]:, :].sum() == 0 and P[:, A.shape[1]:].sum() == 0
