"""Pipelined hot-path tier (ISSUE 4): the double-buffered sampler epoch and
the chunked/bucketed exchange may not change ANY math.

Locked down here:

* ``run_epoch_minibatch(schedule="pipelined")`` is bitwise-identical to the
  blocking schedules — losses, final params, and CommStats — across
  batching x execution, with the one-compile-per-config guard intact;
* feature-chunked + bucketed exchanges match the single-device oracle for
  BOTH partition families and all three execution models, and the chunked
  full-graph step reproduces the monolithic one;
* the `PrefetchWorker` shuts down cleanly when either lane dies mid-epoch;
* the overlap-aware cost models (bucketed cap widths, gathered-table peak,
  overlapped step time, pipelined wall) hold their structural invariants,
  and the pipelined wall model is cross-checked against MEASURED lanes.
"""
import time

import numpy as np
import pytest

from conftest import run_with_devices


def test_pipelined_equals_blocking_4dev():
    """Pipelined epoch == blocking epoch bitwise (losses, params, CommStats)
    for every sampler x execution model, with chunked exchange + bucketed
    p2p caps on, and exactly ONE compile per config."""
    out = run_with_devices("""
        import itertools
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        for batching, exe in itertools.product(
                ("node_wise", "layer_wise", "subgraph"),
                ("broadcast", "ring", "p2p")):
            cfg = EngineConfig(
                execution=exe, batching=batching, batch_size=8,
                fanouts=(3, 3), layer_sizes=(16, 16), walk_length=3,
                hidden=16, lr=0.3, cache_policy="static_degree",
                cache_capacity=12, exchange_chunks=2, p2p_buckets=2,
                prefetch_depth=2)
            eng = DistGNNEngine(g, cfg=cfg)
            s1, l1, t1 = eng.run_epoch_minibatch(4, schedule="conventional")
            stats1 = eng.comm_stats
            s2, l2, t2 = eng.run_epoch_minibatch(4, schedule="pipelined")
            tag = f"{batching}/{exe}"
            assert l1 == l2, (tag, l1, l2)
            eq = jax.tree_util.tree_map(lambda a, b: bool((a == b).all()),
                                        s1["params"], s2["params"])
            assert all(jax.tree_util.tree_leaves(eq)), (tag, eq)
            assert eng.comm_stats == stats1, (tag, eng.comm_stats, stats1)
            assert eng._jit_mb_step._cache_size() == 1, (
                tag, eng._jit_mb_step._cache_size())
            print(f"{tag}: pipelined == blocking bitwise, 1 compile")
        print("PIPE_EQ_OK")
    """, n_devices=4, timeout=600)
    assert "PIPE_EQ_OK" in out


def test_process_pipelined_equals_blocking_4dev():
    """The GIL-free data plane may not change ANY math: a process-pool
    pipelined epoch (shared-memory graph + batch ring, forkserver workers)
    is bitwise-identical to the blocking epoch — losses, params, CommStats —
    across samplers and execution models, the pool is REUSED across epochs,
    and close_prefetch_pool() leaves /dev/shm empty."""
    out = run_with_devices("""
        import os
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        for batching, exe in (("node_wise", "p2p"), ("layer_wise", "ring"),
                              ("subgraph", "broadcast")):
            cfg = EngineConfig(
                execution=exe, batching=batching, batch_size=8,
                fanouts=(3, 3), layer_sizes=(16, 16), walk_length=3,
                hidden=16, lr=0.3, cache_policy="static_degree",
                cache_capacity=12, exchange_chunks=2, p2p_buckets=2,
                prefetch_depth=2, prefetch_mode="process",
                num_sample_workers=2)
            eng = DistGNNEngine(g, cfg=cfg)
            s1, l1, t1 = eng.run_epoch_minibatch(4, schedule="conventional")
            stats1 = eng.comm_stats
            s2, l2, t2 = eng.run_epoch_minibatch(4, schedule="pipelined")
            tag = f"{batching}/{exe}"
            assert l1 == l2, (tag, l1, l2)
            eq = jax.tree_util.tree_map(lambda a, b: bool((a == b).all()),
                                        s1["params"], s2["params"])
            assert all(jax.tree_util.tree_leaves(eq)), (tag, eq)
            assert eng.comm_stats == stats1, (tag, eng.comm_stats, stats1)
            assert eng._jit_mb_step._cache_size() == 1, (
                tag, eng._jit_mb_step._cache_size())
            # epoch 2 on the SAME pool: workers + shm ring reused
            pool = eng._proc_pool
            s3, l3, t3 = eng.run_epoch_minibatch(4, schedule="pipelined")
            assert l3 == l2, (tag, l3, l2)
            assert eng._proc_pool is pool and pool.alive
            eng.close_prefetch_pool()
            litter = [f for f in os.listdir('/dev/shm')
                      if f.startswith('repro-')]
            assert not litter, (tag, litter)
            print(f"{tag}: process-pipelined == blocking bitwise, "
                  "pool reused, shm clean")
        print("PROC_PIPE_EQ_OK")
    """, n_devices=4, timeout=600)
    assert "PROC_PIPE_EQ_OK" in out


def test_chunked_bucketed_matches_oracle_4dev():
    """Feature-chunked exchange + bucketed p2p installments across BOTH
    partition families and all execution models: the full-graph step must
    match the single-device oracle (<=1e-4) and the chunked losses must
    reproduce the monolithic ones."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        for family, vc in (("edge_cut", None), ("vertex_cut", "cartesian2d")):
            for exe in ("broadcast", "ring", "p2p"):
                kw = dict(partition_family=family, execution=exe,
                          hidden=16, lr=0.3)
                if vc:
                    kw["vertex_cut"] = vc
                eng = DistGNNEngine(g, cfg=EngineConfig(
                    exchange_chunks=3, p2p_buckets=2, **kw))
                ld, _ = eng.train(3)
                lr_, _ = eng.train(3, reference=True)
                err = max(abs(a - b) for a, b in zip(ld, lr_))
                assert err <= 1e-4, (family, exe, err)
                mono = DistGNNEngine(g, cfg=EngineConfig(**kw))
                lm, _ = mono.train(3)
                merr = max(abs(a - b) for a, b in zip(ld, lm))
                assert merr <= 1e-6, (family, exe, merr)
                print(f"{family}/{exe}: oracle={err:.2e} "
                      f"chunked-vs-monolithic={merr:.2e}")
        print("CHUNK_ORACLE_OK")
    """, n_devices=4, timeout=600)
    assert "CHUNK_ORACLE_OK" in out


def test_prefetch_worker_exception_shutdown():
    """Either lane dying mid-epoch must stop and join the worker thread —
    no hang, no orphaned producer."""
    from repro.core.execution.minibatch_pipeline import run_pipelined
    from repro.core.sampling.prefetch import PrefetchWorker

    # producer raises at item 2: the error surfaces at its position and the
    # thread has exited by the time the consumer sees it
    def bad_produce(i):
        if i == 2:
            raise ValueError("sampler died")
        return i * 10

    w = PrefetchWorker(range(5), bad_produce, depth=2)
    got = []
    with pytest.raises(ValueError, match="sampler died"):
        for item in w:
            got.append(item)
    assert got == [0, 10]
    w.close()
    assert not w.alive

    # consumer abandons mid-iteration while the queue is full: close() must
    # unblock the producer's pending put and join
    w = PrefetchWorker(range(100), lambda i: i, depth=1)
    assert next(iter(w)) == 0
    w.close()
    assert not w.alive

    # train_fn raising propagates out of run_pipelined with the worker closed
    def bad_train(mb, feats):
        raise RuntimeError("device step died")

    with pytest.raises(RuntimeError, match="device step died"):
        run_pipelined(list(range(50)), lambda i: i, lambda mb: mb, bad_train)
    # results arrive strictly in order under a slow consumer
    seen = []
    run_pipelined(list(range(20)), lambda i: i, lambda mb: mb,
                  lambda mb, feats: (time.sleep(0.001), seen.append(feats)))
    assert seen == list(range(20))


def test_prefetch_depth_validation():
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import er_graph
    from repro.core.sampling.prefetch import PrefetchWorker

    with pytest.raises(ValueError):
        PrefetchWorker([1], lambda i: i, depth=0)
    g = er_graph(32, avg_degree=4, seed=0)
    for kw in (dict(exchange_chunks=0), dict(p2p_buckets=0),
               dict(prefetch_depth=0)):
        with pytest.raises(ValueError):
            DistGNNEngine(g, cfg=EngineConfig(**kw))


def test_chunked_overlap_unit():
    """chunked_overlap == monolithic for any chunk count, including uneven
    feature widths (pure consumer math, no devices)."""
    import jax.numpy as jnp

    from repro.core.execution.pipeline_exchange import chunked_overlap

    h = jnp.arange(5 * 7, dtype=jnp.float32).reshape(5, 7)
    exchange = lambda hc: hc * 2.0  # noqa: E731
    consume = lambda gc: gc + 1.0  # noqa: E731
    ref = consume(exchange(h))
    for C in (1, 2, 3, 5, 7, 16):
        out = chunked_overlap(h, C, exchange, consume)
        assert out.shape == ref.shape
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref)), C


def test_ell_spmm_block_kwargs():
    """The chunk-friendly kernel call path: explicit row/feat block sizes
    (as a chunked caller with a narrow table would pick) reproduce the
    default grid bit for bit, forward AND backward."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ell_spmm import ell_spmm

    rng = np.random.default_rng(0)
    V, K, N, D = 20, 4, 24, 9  # D narrow, like one feature chunk
    ids = jnp.asarray(rng.integers(0, N, (V, K)), jnp.int32)
    mask = jnp.asarray((rng.random((V, K)) < 0.7).astype(np.float32))
    H = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))

    def run(**kw):
        def loss(h):
            out = ell_spmm(ids, mask, h, normalize=False, interpret=True, **kw)
            return (out * out).sum(), out

        (_, out), grad = jax.value_and_grad(loss, has_aux=True)(H)
        return np.asarray(out), np.asarray(grad)

    ref_out, ref_grad = run()
    for kw in (dict(row_block=8, feat_block=4), dict(row_block=16),
               dict(feat_block=3)):
        out, grad = run(**kw)
        np.testing.assert_array_equal(out, ref_out, err_msg=str(kw))
        np.testing.assert_array_equal(grad, ref_grad, err_msg=str(kw))


def test_bucketed_cap_widths_invariants():
    from repro.core.execution.pipeline_exchange import (
        bucketed_cap_widths,
        halo_slot,
    )

    for cap in (1, 2, 5, 6, 17, 100, 1000):
        for buckets in (1, 2, 4, 8):
            widths = bucketed_cap_widths(cap, buckets)
            assert sum(widths) >= cap, (cap, buckets, widths)
            assert len(widths) <= max(buckets, 1), (cap, buckets, widths)
            assert len(set(widths)) == 1  # equal installments
            if len(widths) > 1:
                w = widths[0]
                assert w & (w - 1) == 0  # power of two
                # the point: each installment buffer is smaller than the cap
                assert w < cap
    # the slot layout is a bijection into [base, base + B*k*w)
    cap, buckets, k, base = 11, 4, 3, 7
    widths = bucketed_cap_widths(cap, buckets)
    B, w = len(widths), widths[0]
    slots = set()
    for s in range(k):
        for t in range(cap):
            slot = int(halo_slot(t, s, w, k, base))
            assert base <= slot < base + B * k * w
            slots.add(slot)
    assert len(slots) == k * cap
    # single bucket reproduces the classic base + s*cap + t layout
    assert halo_slot(3, 2, cap, k, base) == base + 2 * cap + 3


def test_overlap_step_time_model():
    from repro.core.partition.cost_models import overlapped_step_time

    comm, comp = 8.0, 5.0
    assert overlapped_step_time(comm, comp, 1) == comm + comp
    prev = comm + comp
    for C in (2, 4, 8, 64):
        t = overlapped_step_time(comm, comp, C)
        assert max(comm, comp) <= t <= prev + 1e-12  # monotone toward max
        prev = t
    assert abs(overlapped_step_time(comm, comp, 10**6) - comm) < 1e-3


def test_pipelined_wall_model_crosscheck_measured_lanes():
    """The overlap-aware wall model against MEASURED lanes: with sleepy
    (GIL-releasing) stages the pipelined executor must land between the
    model's two-lane bound and the blocking serial sum."""
    from repro.core.execution.minibatch_pipeline import (
        pipelined_wall_model,
        run_conventional,
        run_pipelined,
    )

    ids = list(range(6))
    sample = lambda i: time.sleep(0.008) or i  # noqa: E731
    extract = lambda mb: time.sleep(0.002) or mb  # noqa: E731
    train = lambda mb, f: time.sleep(0.012)  # noqa: E731
    blocking = run_conventional(ids, sample, extract, train)
    piped = run_pipelined(ids, sample, extract, train, prefetch_depth=2)
    model = pipelined_wall_model(piped, len(ids))
    # real overlap: below the serial sum, above the slower measured lane
    assert piped.wall < 0.9 * blocking.wall, (piped.wall, blocking.wall)
    assert piped.wall >= 0.8 * model, (piped.wall, model)
    assert piped.busy() > piped.wall  # lanes genuinely ran concurrently
