"""Hypothesis property tests on the vertex-cut partitioner invariants the
engine's replica layout relies on: the Libra owned-edge balance bound, the
2D-Cartesian per-vertex replication bound (<= rows + cols - 1, masters
included), determinism in seed, and layout well-formedness (every vertex
present exactly once per holding device, always on its master).

Requires the optional ``hypothesis`` dependency (the ``property`` test
extra); without it the module degrades to a skip instead of a collection
error — same gating as test_sampling_property.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.graph import er_graph, powerlaw_graph
from repro.core.partition.vertex_cut import (
    VERTEX_CUTS,
    cartesian_2d_vertex_cut,
    libra_vertex_cut,
)
from repro.core.partition.vertex_layout import build_vertex_layout

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(20, 120), st.integers(2, 8), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_libra_balance_invariant(n, k, seed):
    """max owned-edge load <= slack * E / k + 1, on arbitrary graphs."""
    g = powerlaw_graph(n, avg_degree=6, seed=seed % 17)
    vc = libra_vertex_cut(g, k, seed=seed)
    loads = np.bincount(vc.edge_owner, minlength=k)
    assert loads.sum() == g.num_edges
    assert loads.max() <= 1.15 * g.num_edges / k + 1


@given(st.integers(20, 100), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_cartesian_2d_replication_bound(n, rows, cols, seed):
    """Per-VERTEX replication <= rows + cols - 1: v's edges live only in
    grid row row(v) (as source) and grid column col(v) (as destination), and
    the master block (row(v), col(v)) sits in that cross."""
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    vc = cartesian_2d_vertex_cut(g, rows, cols, seed=seed)
    counts = vc.replica_counts(g, include_masters=True)
    assert counts.max() <= rows + cols - 1
    assert (counts >= 1).all()  # the forced master covers isolated vertices


@given(st.integers(20, 100), st.integers(2, 8), st.integers(0, 10_000),
       st.sampled_from(sorted(VERTEX_CUTS)))
@settings(**SETTINGS)
def test_vertex_cut_deterministic_in_seed(n, k, seed, name):
    """Same (graph, k, seed) -> identical cut; the engine's bitwise
    determinism contract starts here."""
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    a = VERTEX_CUTS[name](g, k, seed=seed)
    b = VERTEX_CUTS[name](g, k, seed=seed)
    np.testing.assert_array_equal(a.edge_owner, b.edge_owner)
    np.testing.assert_array_equal(a.masters, b.masters)


@given(st.integers(20, 80), st.integers(2, 6), st.integers(0, 10_000),
       st.sampled_from(sorted(VERTEX_CUTS)))
@settings(**SETTINGS)
def test_vertex_layout_well_formed(n, k, seed, name):
    """The static layout invariants the replica-sync plans assume: slot
    tables consistent, every vertex present on its master, owned-edge ELL
    masks match the cut's per-partition edge counts, and pad slots inert."""
    g = powerlaw_graph(n, avg_degree=6, seed=seed % 17)
    vc = VERTEX_CUTS[name](g, k, seed=seed)
    lay = build_vertex_layout(g, vc, k)
    V = g.num_vertices
    for d in range(k):
        vs = lay.vert_ids[d][lay.vert_ids[d] < V]
        assert len(np.unique(vs)) == len(vs)  # one slot per vertex
        np.testing.assert_array_equal(
            lay.slot_of[d, vs], np.flatnonzero(lay.vert_ids[d] < V))
    # every vertex present on its master, exactly one master slot
    assert (lay.slot_of[vc.masters, np.arange(V)] >= 0).all()
    assert lay.master_mask.sum() == V
    # owned-edge ELL rows sum to the cut's edge loads; pad slots carry none
    loads = np.bincount(vc.edge_owner, minlength=k)
    np.testing.assert_array_equal(lay.mask_owned.sum((1, 2)), loads)
    pad = lay.vert_ids == V
    assert lay.mask_owned[pad].sum() == 0
    assert lay.train_w[pad].sum() == 0 and lay.X[pad].sum() == 0
    # replica counts consistent with presence
    np.testing.assert_array_equal(
        lay.rep_count, (lay.slot_of >= 0).sum(0))
