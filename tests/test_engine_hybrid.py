"""DistGNNEngine hybrid-cut tier (subprocess, forced host devices): the
PowerLyra-style degree-threshold family (partition/hybrid_cut.py behind the
layout/exchange interface) must match the single-device oracle to <=1e-4
across the full {broadcast, ring, p2p} x {gcn, sage, gat, gin} matrix on 4
AND 8 devices — low-degree vertices flow edge-cut-local through the halo
exchange while hub replicas combine through the replica-sync GAS, and the
composition may not change the math.

Also locked down here: the degenerate thresholds inside the ENGINE
(threshold=inf runs halo-only with byte accounting equal to the edge-cut
p2p halo model; threshold=0 runs sync-only), bitwise determinism and the
one-compile guard, CommStats exactly == the standalone
`hybrid_bytes_per_step` cost model, the family anchor against the edge-cut
oracle, config validation, and the single-device degeneration.
"""
import pytest

from conftest import run_with_devices

_MATRIX_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for i, (model, exe) in enumerate(
            itertools.product({models}, {execs})):
        cfg = EngineConfig(partition_family="hybrid", model=model,
                           execution=exe, hub_threshold={threshold},
                           hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        tag = f"{{model}}/{{exe}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}}")
        if not (err <= 1e-4 and lerr <= 1e-4
                and np.isfinite(losses_d[-1])):
            fails.append((tag, err, lerr))
    assert not fails, fails
    print("HY_MATRIX_OK")
"""


@pytest.mark.parametrize("model", ["gcn", "sage", "gat", "gin"])
def test_hybrid_matrix_4dev(model):
    """One model x ALL execution models per subprocess at the default (95th
    percentile) hub threshold — together the four parametrizations cover the
    full 4 x 3 matrix on 4 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=80, epochs=3, threshold="None",
        models=(model,), execs=("broadcast", "ring", "p2p"),
    ), n_devices=4, timeout=600)
    assert "HY_MATRIX_OK" in out


@pytest.mark.parametrize("models", [("gcn", "gat"), ("sage", "gin")])
def test_hybrid_matrix_8dev(models):
    """The model matrix on 8 devices (two models x all executions per
    subprocess), with a hand-picked threshold so both vertex classes are
    populated."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=128, epochs=3, threshold=6.0,
        models=models, execs=("broadcast", "ring", "p2p"),
    ), n_devices=8, timeout=600)
    assert "HY_MATRIX_OK" in out


def test_hybrid_degenerate_thresholds_4dev():
    """threshold=inf (halo-only: sync inactive, bytes == the edge-cut p2p
    halo device model) and threshold=0 (sync-only: halo inactive) both match
    the oracle inside the engine."""
    out = run_with_devices("""
        import numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(80, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        for thr in (np.inf, 0.0):
            for exe in ("broadcast", "ring", "p2p"):
                cfg = EngineConfig(partition_family="hybrid",
                                   hub_threshold=thr, execution=exe,
                                   hidden=16, lr=0.3)
                eng = DistGNNEngine(g, cfg=cfg)
                ld, _ = eng.train(3)
                lr_, _ = eng.train(3, reference=True)
                err = max(abs(a - b) for a, b in zip(ld, lr_))
                assert err <= 1e-4, (thr, exe, err)
                lay = eng.playout
                if np.isinf(thr):
                    assert not lay.sync_active and lay.halo_active
                else:
                    assert lay.sync_active and not lay.halo_active
        print("HY_DEGEN_OK")
    """, n_devices=4, timeout=600)
    assert "HY_DEGEN_OK" in out


def test_hybrid_determinism_and_recompile_4dev():
    """Same seed -> bitwise-identical losses across runs AND engines, and
    the jitted step compiles EXACTLY once per config."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        cfg = EngineConfig(partition_family="hybrid", execution="p2p",
                           hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        l1, _ = eng.train(5)
        n = eng._jit_step._cache_size()
        assert n == 1, f"expected 1 compile, got {n}"
        l2, _ = eng.train(5)
        assert l1 == l2, (l1, l2)
        assert eng._jit_step._cache_size() == 1
        eng2 = DistGNNEngine(g, cfg=cfg)
        l3, _ = eng2.train(5)
        assert l1 == l3, (l1, l3)
        print("HY_DET_OK", l1[-1])
    """, n_devices=4)
    assert "HY_DET_OK" in out


def test_hybrid_comm_stats_cross_check_4dev():
    """Engine-reported halo_bytes + replica_sync_bytes exactly == the
    standalone `hybrid_bytes_per_step` cost model over the engine's layout,
    per execution model and for gcn AND gat widths; both fields count as
    wire bytes in total()."""
    out = run_with_devices("""
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.cost_models import hybrid_bytes_per_step

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        for model in ("gcn", "gat"):
            for exe in ("broadcast", "ring", "p2p"):
                cfg = EngineConfig(partition_family="hybrid", model=model,
                                   execution=exe, hidden=16, lr=0.3)
                eng = DistGNNEngine(g, cfg=cfg)
                eng.train(4)
                lay = eng.playout
                expected = 4 * hybrid_bytes_per_step(
                    lay.halo_rows_exec if lay.halo_active else 0,
                    lay._vc_rows_per_layer if lay.sync_active else 0,
                    eng.dims, model=model)
                got = (eng.comm_stats.halo_bytes
                       + eng.comm_stats.replica_sync_bytes)
                assert got == expected and got > 0, (model, exe, got,
                                                     expected)
                assert eng.comm_stats.total() == got
        print("HY_BYTES_OK")
    """, n_devices=4, timeout=600)
    assert "HY_BYTES_OK" in out


def test_hybrid_anchors_to_edge_cut_oracle_4dev():
    """Family anchor: under sync the hybrid family computes the same global
    GCN as the edge-cut oracle from the same param init — the hybrid
    dataflow is pinned to the real graph math, not just to itself."""
    out = run_with_devices("""
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        cfgh = EngineConfig(partition_family="hybrid", execution="p2p",
                            hidden=16, lr=0.3)
        cfge = EngineConfig(execution="p2p", hidden=16, lr=0.3)
        engh = DistGNNEngine(g, cfg=cfgh)
        lh_dist, _ = engh.train(4)
        le_ref, _ = DistGNNEngine(g, cfg=cfge).train(4, reference=True)
        gap = max(abs(a - b) for a, b in zip(lh_dist, le_ref))
        assert gap <= 1e-4, gap
        print("HY_ANCHOR_OK", gap)
    """, n_devices=4)
    assert "HY_ANCHOR_OK" in out


def test_hybrid_rejects_bad_config():
    import numpy as np

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import er_graph

    g = er_graph(32, avg_degree=4, seed=0)
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="hybrid",
                                          hub_threshold=-1.0))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="hybrid",
                                          hub_threshold=np.nan))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="hybrid",
                                          batching="node_wise"))


def test_hybrid_single_device_paths_agree():
    """On one device the distributed hybrid step IS the oracle (halo and
    sync tables degenerate) and still learns."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.01, seed=1)
    mesh = jax.make_mesh((1,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
        partition_family="hybrid", execution="p2p", hidden=16, lr=0.3))
    ld, _ = eng.train(8)
    lr_, _ = eng.train(8, reference=True)
    assert max(abs(a - b) for a, b in zip(ld, lr_)) < 1e-4
    assert ld[-1] < ld[0]
