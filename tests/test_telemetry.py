"""Telemetry tier (ISSUE 8): tracer core, metric registry, exporters, and
the engine integration contract.

In-process tests cover the stdlib-only `core.telemetry` module: span
nesting/ordering, thread-interleaved lanes landing on distinct trace rows,
exact histogram percentiles (bit-identical to numpy), the disabled-mode
no-op identity + bounded overhead, and the Chrome trace-event JSON schema
round-trip.

The subprocess test (4 forced-host devices) locks the run-wide contract: a
traced mini-batch pipelined epoch + serving flush where the summed
exchange-span bytes equal ``CommStats.total()`` EXACTLY, every CommStats
field is mirrored into ``comm.*`` counters, spans cover every configured
step, the prefetch and trainer threads appear as distinct lanes, and —
satellite 1's regression — a held ``CommStats`` reference keeps observing
traffic across the in-place ``reset()`` the engine now performs instead of
re-instantiating.
"""
import json
import threading
import time

import numpy as np
import pytest

from conftest import run_with_devices
from repro.core.sampling.distributed import CommStats
from repro.core.telemetry import (
    DEFAULT_LATENCY_BUCKETS,
    NULL_METRIC,
    NULL_SPAN,
    NULL_TELEMETRY,
    MetricRegistry,
    Telemetry,
    Tracer,
    exact_percentile,
)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

class FakeClock:
    """Deterministic clock: each call advances by `dt`."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def test_span_nesting_and_ordering():
    tr = Tracer(clock=FakeClock())
    with tr.span("outer", step=0):
        with tr.span("inner_a", device=1):
            pass
        with tr.span("inner_b", device=2):
            pass
    spans = tr.spans()  # ordered by start time
    assert [s.name for s in spans] == ["outer", "inner_a", "inner_b"]
    outer, a, b = spans
    assert outer.depth == 0 and a.depth == 1 and b.depth == 1
    # children start after the parent and fit inside its interval
    assert outer.t0 < a.t0 < b.t0
    assert a.t0 + a.dur <= outer.t0 + outer.dur
    assert b.t0 + b.dur <= outer.t0 + outer.dur
    assert a.labels == {"device": 1}
    # set() attaches labels mid-span
    with tr.span("late") as sp:
        sp.set(rows=7)
    assert tr.spans()[-1].labels["rows"] == 7


def test_instant_spans_are_zero_duration():
    tr = Tracer(clock=FakeClock())
    tr.instant("exchange", bytes=128, device=3)
    (sp,) = tr.spans()
    assert sp.dur == 0.0 and sp.labels["bytes"] == 128


def test_thread_interleaved_spans_get_distinct_lanes():
    tel = Telemetry()
    barrier = threading.Barrier(2)

    def work(tag):
        barrier.wait()
        for i in range(5):
            with tel.span("stage", lane=tag, step=i):
                time.sleep(0.001)

    threads = [threading.Thread(target=work, args=(t,)) for t in ("a", "b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tids = {s.tid for s in tel.trace.spans()}
    assert len(tids) == 2  # two OS threads -> two lanes
    trace = tel.chrome_trace()
    xev = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {e["tid"] for e in xev} == {0, 1}  # renumbered in appearance order
    lanes_by_tid = {e["tid"]: set() for e in xev}
    for e in xev:
        lanes_by_tid[e["tid"]].add(e["args"]["lane"])
    # each trace row carries exactly one producer thread's spans
    assert all(len(v) == 1 for v in lanes_by_tid.values())


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_percentiles_exact_vs_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 100, 1001):
        draws = rng.lognormal(mean=-5.0, sigma=2.0, size=n)
        reg = MetricRegistry()
        h = reg.histogram("lat")
        for d in draws:
            h.record(d)
        for q in (0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0):
            assert h.percentile(q) == float(np.percentile(draws, q)), (n, q)
            assert exact_percentile(draws, q) == float(np.percentile(draws, q))
    assert exact_percentile([], 50.0) == 0.0


def test_histogram_bucket_counts():
    reg = MetricRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.7, 3.0, 100.0):
        h.record(v)
    assert h.counts == [1, 2, 1, 1]  # last bucket is the +inf overflow
    assert h.count == 5 and h.total == pytest.approx(106.7)
    assert DEFAULT_LATENCY_BUCKETS[0] == 1e-4


def test_registry_get_or_create_and_aggregation():
    reg = MetricRegistry()
    c0 = reg.counter("comm.pull_bytes", device=0)
    assert reg.counter("comm.pull_bytes", device=0) is c0  # same label set
    assert reg.counter("comm.pull_bytes", device=1) is not c0
    c0.add(10).add(5)
    reg.counter("comm.pull_bytes", device=1).add(3)
    reg.counter("comm.pull_bytes").add(2)  # unlabeled variant
    assert reg.counter_total("comm.pull_bytes") == 20
    assert reg.per_device("comm.pull_bytes") == {0: 15, 1: 3}
    reg.gauge("occ", device=2).set(7.5)
    d = reg.as_dict()
    assert d["counters"]["comm.pull_bytes"]["device=0"] == 15
    assert d["gauges"]["occ"]["device=2"] == 7.5


def test_imbalance_report_ratios():
    tel = Telemetry()
    for dev, v in ((0, 30), (1, 10), (2, 10), (3, 10)):
        tel.counter("comm.pull_bytes", device=dev).add(v)
    rec = tel.imbalance_report()["metrics"]["comm.pull_bytes"]
    assert rec["max"] == 30 and rec["mean"] == pytest.approx(15.0)
    assert rec["max_over_mean"] == pytest.approx(2.0)
    assert rec["per_device"] == {"0": 30, "1": 10, "2": 10, "3": 10}


# ---------------------------------------------------------------------------
# disabled mode
# ---------------------------------------------------------------------------

def test_disabled_mode_is_noop_identity():
    tel = Telemetry(enabled=False)
    # identity-stable singletons: the disabled path allocates nothing per call
    assert tel.span("x", step=1) is NULL_SPAN
    assert tel.counter("c") is NULL_METRIC
    assert tel.gauge("g") is NULL_METRIC
    assert tel.histogram("h") is NULL_METRIC
    with tel.span("x") as sp:
        sp.set(bytes=1)  # chainable no-op
    tel.instant("x", bytes=1)
    tel.log_step(step=0)
    tel.attach_executable("e", {"a": 1})
    assert tel.trace.spans() == []
    assert tel.run_summary()["spans"]["count"] == 0
    assert tel.chrome_trace()["traceEvents"] == []
    assert tel.imbalance_report() == {"spans": {}, "metrics": {}}
    assert NULL_TELEMETRY.span("y") is NULL_SPAN


def test_disabled_mode_overhead_bounded():
    tel = Telemetry(enabled=False)
    n = 10000
    t0 = time.perf_counter()
    for i in range(n):
        with tel.span("s", step=i):
            pass
        tel.counter("c", device=0).add(1)
    per_call = (time.perf_counter() - t0) / n
    # generous absolute bound (~50x the measured cost) so loaded CI passes:
    # the point is "no hidden allocation/locking", not a microbench race
    assert per_call < 50e-6, f"disabled telemetry costs {per_call*1e6:.1f}us"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_roundtrip(tmp_path):
    tel = Telemetry()
    with tel.span("sample", step=0, device=1):
        with tel.span("extract", step=0, device=1):
            pass
    tel.instant("exchange", stage="extract", bytes=64, device=2)
    path = tmp_path / "trace.json"
    tel.write_chrome_trace(str(path))
    trace = json.loads(path.read_text())  # round-trip through real JSON
    assert trace == tel.chrome_trace()
    xev = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(xev) == 3
    for e in xev:
        assert set(("name", "ph", "ts", "dur", "pid", "tid")) <= set(e)
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
    assert {e["pid"] for e in xev} == {1, 2}  # pid = device label
    exch = next(e for e in xev if e["name"] == "exchange")
    assert exch["args"]["bytes"] == 64 and exch["dur"] == 0.0
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} >= {"device 1", "device 2",
                                                "lane 0"}


def test_step_log_jsonl(tmp_path):
    tel = Telemetry()
    tel.log_step(step=0, loss=0.5, comm_total_bytes=128)
    tel.log_step(step=1, loss=0.25, comm_total_bytes=256)
    path = tmp_path / "steps.jsonl"
    tel.write_step_log(str(path))
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert recs == [{"step": 0, "loss": 0.5, "comm_total_bytes": 128},
                    {"step": 1, "loss": 0.25, "comm_total_bytes": 256}]
    summary = tel.run_summary()
    assert summary["steps"] == recs


# ---------------------------------------------------------------------------
# satellite 1: CommStats.reset() keeps held references live
# ---------------------------------------------------------------------------

def test_commstats_reset_in_place():
    stats = CommStats()
    held = stats  # e.g. a bench accumulating per-epoch deltas
    stats.pull_bytes += 100
    stats.cache_hit_bytes += 40
    assert stats.reset() is stats
    assert held.total() == 0 and held.requested() == 0
    stats.push_bytes += 7  # post-reset traffic still visible through `held`
    assert held.total() == 7


# ---------------------------------------------------------------------------
# engine integration: the run-wide contract on 4 forced-host devices
# ---------------------------------------------------------------------------

ENGINE_TRACE_CODE = r"""
import dataclasses, json
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine

g = sbm_graph(96, num_blocks=4, p_in=0.2, p_out=0.05, feature_dim=8,
              num_classes=4, seed=0)
cfg = EngineConfig(batching="node_wise", execution="p2p", batch_size=4,
                   fanouts=(3, 3), cache_policy="static_degree",
                   cache_capacity=8, seed=0)
eng = DistGNNEngine(g, cfg=cfg)
held = eng.comm_stats  # satellite 1: must survive the engine's resets
tel = eng.enable_telemetry()
NB = 4
state, losses, times = eng.run_epoch_minibatch(NB, schedule="pipelined")
assert held is eng.comm_stats, "engine re-instantiated CommStats"
assert held.total() > 0, "held CommStats reference detached from traffic"

qe = GNNQueryEngine(eng, state["params"])
qe.submit([1, 2, 3]); qe.submit([3, 4])
qe.flush()

# exchange accounting: summed exchange-span bytes == CommStats.total()
spans = tel.trace.spans()
exch = sum(s.labels["bytes"] for s in spans if s.name == "exchange")
assert exch == eng.comm_stats.total(), (exch, eng.comm_stats.total())

# every CommStats field mirrors into a comm.* counter, exactly
for f in dataclasses.fields(eng.comm_stats):
    mirrored = tel.metrics.counter_total("comm." + f.name)
    assert mirrored == getattr(eng.comm_stats, f.name), (f.name, mirrored)

# spans cover every configured step in every pipeline stage
for stage in ("sample", "extract", "train"):
    steps = {s.labels.get("step") for s in spans if s.name == stage}
    assert set(range(NB)) <= steps, (stage, steps)

# prefetch producer and trainer threads are distinct trace lanes
xev = [e for e in tel.chrome_trace()["traceEvents"] if e["ph"] == "X"]
assert len({e["tid"] for e in xev}) >= 2, "expected >= 2 lanes"

# imbalance report sees per-device bytes, layout gauges, occupancy
rep = tel.imbalance_report()["metrics"]
for name in ("comm.pull_bytes", "layout.owned_vertices",
             "frontier_occupancy", "store.overlay_hit"):
    assert name in rep and len(rep[name]["per_device"]) == 4, name
    assert rep[name]["max_over_mean"] >= 1.0

# serving instrumented: flush latency histogram + coalescing counters
assert tel.histogram("serve.flush_latency_s").count == 1
assert tel.metrics.counter_total("serve.queries") == 2
assert tel.metrics.counter_total("serve.targets_requested") == 5

# run summary is JSON-serializable end to end
json.dumps(tel.run_summary())
print("TRACED_ENGINE_OK")
"""


def test_traced_engine_contract_4dev():
    out = run_with_devices(ENGINE_TRACE_CODE, n_devices=4)
    assert "TRACED_ENGINE_OK" in out
