"""Cost models (survey §4.1, Eq. 3-11)."""
import numpy as np
import pytest

from repro.core.graph import powerlaw_graph
from repro.core.partition.cost_models import (
    OperatorCostModel,
    RocCostModel,
    bgl_score,
    bytegnn_score,
    flexgraph_cost,
    pagraph_score,
)


@pytest.fixture(scope="module")
def g():
    return powerlaw_graph(150, avg_degree=6, seed=0)


def test_pagraph_score_prefers_neighbor_partition():
    train_sets = [set(range(10)), set()]
    sizes = np.array([10.0, 10.0])
    nbrs = np.arange(5)
    s = pagraph_score(nbrs, train_sets, sizes, avg_train=20)
    assert s[0] > s[1]


def test_pagraph_score_balances():
    """A full partition (train count above average) scores negative."""
    train_sets = [set(range(30)), set()]
    sizes = np.array([30.0, 30.0])
    nbrs = np.arange(5)
    s = pagraph_score(nbrs, train_sets, sizes, avg_train=10)
    assert s[0] < 0


def test_bgl_and_bytegnn_scores_finite():
    s1 = bgl_score(np.arange(4), [set([1, 2]), set()], np.array([5.0, 2.0]),
                   np.array([1.0, 0.0]), 4.0, 2.0)
    s2 = bytegnn_score(np.array([3.0, 1.0]), np.array([5.0, 2.0]),
                       np.array([1.0, 0.0]), np.array([0.0, 0.0]),
                       np.array([0.0, 1.0]), (1.0, 1.0, 1.0))
    assert np.isfinite(s1).all() and np.isfinite(s2).all()


def test_roc_cost_model_fits_measurements(g):
    m = RocCostModel().fit_from_measurements(g, hidden_dim=16, n_chunks=8, repeats=1)
    assert m.weights is not None and m.weights.shape == (5,)
    # prediction should be positive and monotone in subgraph size
    small = m.predict_subgraph(g, np.arange(10), 16)
    large = m.predict_subgraph(g, np.arange(100), 16)
    assert large > small > 0 or large > small  # monotone


def test_operator_cost_model_eq9_11(g):
    m = OperatorCostModel()
    # forward cost grows with degree and dims
    assert m.forward_cost(10, 16, 16) > m.forward_cost(2, 16, 16)
    batch = np.arange(8)
    c1 = m.batch_cost(g, batch, [16, 16, 8])
    c2 = m.batch_cost(g, batch, [32, 32, 8])
    assert c2 > c1 > 0


def test_operator_cost_submodular_direction(g):
    """Eq. 11 is submodular: marginal cost of adding vertices shrinks as the
    batch grows (shared L-hop neighborhoods)."""
    m = OperatorCostModel()
    dims = [16, 16, 8]
    c_a = m.batch_cost(g, np.arange(0, 8), dims)
    c_ab = m.batch_cost(g, np.arange(0, 16), dims)
    c_b_alone = m.batch_cost(g, np.arange(8, 16), dims)
    assert c_ab <= c_a + c_b_alone + 1e-9


def test_flexgraph_cost():
    assert flexgraph_cost(np.array([3, 5]), np.array([16, 8])) == 3 * 16 + 5 * 8
