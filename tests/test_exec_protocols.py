"""Execution models (survey §6) + protocol state machines (§7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.execution import (
    one_shot_aggregate,
    p3_plan,
    parallel_chunk_aggregate,
    run_conventional,
    run_factored,
    run_operator_parallel,
    sequential_chunk_aggregate,
)
from repro.core.graph import er_graph, powerlaw_graph
from repro.core.partition import PARTITIONERS
from repro.core.protocols import (
    PROTOCOL_COSTS,
    HistoricalState,
    epoch_adaptive_refresh,
    epoch_fixed_refresh,
    variation_refresh,
)
from repro.core.training import boundary_mask_for


def test_chunk_execution_equals_one_shot():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    H = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    ref = one_shot_aggregate(A, H)
    for n in (2, 4, 8):
        np.testing.assert_allclose(np.asarray(sequential_chunk_aggregate(A, H, n)),
                                   np.asarray(ref), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(parallel_chunk_aggregate(A, H, n)),
                                   np.asarray(ref), atol=1e-5, rtol=1e-4)


def test_minibatch_execution_models_accounting():
    import time

    ids = [np.arange(4)] * 6

    def sample(x):
        time.sleep(0.002)
        return x

    def extract(mb):
        time.sleep(0.002)
        return mb

    def train(mb, f):
        time.sleep(0.002)

    conv = run_conventional(ids, sample, extract, train)
    fact = run_factored(ids, sample, extract, train)
    op = run_operator_parallel(ids, sample, extract, train, lanes=3)
    assert conv.wall >= conv.busy() * 0.9
    assert fact.wall <= conv.wall * 1.05  # overlap can only help
    assert op.wall <= conv.wall


def test_p3_plan_saves_when_features_wide():
    plan = p3_plan(num_batch_vertices=1000, num_batch_edges=5000,
                   feature_dim=1024, hidden_dim=32, num_workers=8)
    assert plan.saving > 0.5  # the P3 regime: D >> H
    plan2 = p3_plan(1000, 5000, feature_dim=16, hidden_dim=64, num_workers=8)
    assert plan2.saving < plan.saving  # narrow features: pull-push loses edge


def test_protocol_costs_ordering():
    g = powerlaw_graph(200, avg_degree=8, seed=1)
    part = PARTITIONERS["metis_like"](g, 4)
    b = PROTOCOL_COSTS["broadcast"](g, part, 32)
    p = PROTOCOL_COSTS["p2p"](g, part, 32)
    r = PROTOCOL_COSTS["remote_partial_agg"](g, part, 32)
    assert p.bytes_per_layer <= b.bytes_per_layer  # P2P ships only boundaries
    assert r.bytes_per_layer <= p.bytes_per_layer + 1  # partial agg <= raw rows


@pytest.mark.parametrize("fn,kw", [
    (epoch_fixed_refresh, {"staleness": 3}),
    (epoch_adaptive_refresh, {"staleness": 3}),
    (variation_refresh, {"eps": 1e9, "hard_bound": 3}),  # never drifts -> bound forces
])
def test_staleness_bound_invariant(fn, kw):
    """Each model must keep per-partition age <= its bound — the survey's
    convergence-critical property (Table 3)."""
    V, D, K = 40, 8, 4
    rng = np.random.default_rng(0)
    assignment = jnp.asarray(rng.integers(0, K, V), jnp.int32)
    bmask = jnp.asarray(rng.random(V) < 0.5)
    state = HistoricalState.create(V, D, K)
    bound = kw.get("staleness", kw.get("hard_bound"))
    for step in range(12):
        h = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
        _, state = fn(state, h, jnp.asarray(step), assignment, bmask, **kw)
        assert int(state.age.max()) <= bound, (fn.__name__, step, state.age)


def test_variation_refresh_reacts_to_drift():
    V, D, K = 24, 4, 2
    assignment = jnp.asarray(np.arange(V) % K, jnp.int32)
    bmask = jnp.ones(V, bool)
    state = HistoricalState.create(V, D, K)
    h0 = jnp.ones((V, D))
    _, state = variation_refresh(state, h0, jnp.asarray(0), assignment, bmask, eps=0.01)
    bytes_after_first = float(state.bytes_pushed)
    # no drift -> no new push
    _, state = variation_refresh(state, h0, jnp.asarray(1), assignment, bmask, eps=0.01)
    assert float(state.bytes_pushed) == bytes_after_first
    # big drift -> push
    _, state = variation_refresh(state, h0 * 10, jnp.asarray(2), assignment, bmask, eps=0.01)
    assert float(state.bytes_pushed) > bytes_after_first
