"""Partitioners (survey §4.2): validity, balance, and quality ordering."""
import numpy as np
import pytest

from repro.core.graph import powerlaw_graph, sbm_graph
from repro.core.partition import PARTITIONERS, cartesian_2d_vertex_cut, libra_vertex_cut, random_vertex_cut


@pytest.fixture(scope="module")
def sbm():
    return sbm_graph(240, num_blocks=4, p_in=0.08, p_out=0.004, seed=1)


@pytest.fixture(scope="module")
def plaw():
    return powerlaw_graph(200, avg_degree=8, seed=2)


@pytest.mark.parametrize("name", list(PARTITIONERS))
def test_partition_valid_and_balanced(sbm, name):
    part = PARTITIONERS[name](sbm, 4)
    assert part.assignment.shape == (sbm.num_vertices,)
    assert part.assignment.min() >= 0 and part.assignment.max() < 4
    assert part.vertex_balance() < 2.0  # no pathological imbalance


def test_locality_aware_beats_hash_on_communities(sbm):
    """The survey's core partition claim: graph-aware partitioners cut fewer
    edges than hash on community-structured graphs."""
    cut_hash = PARTITIONERS["hash"](sbm, 4).edge_cut_fraction(sbm)
    cut_ldg = PARTITIONERS["ldg"](sbm, 4).edge_cut_fraction(sbm)
    cut_metis = PARTITIONERS["metis_like"](sbm, 4).edge_cut_fraction(sbm)
    assert cut_ldg < cut_hash
    assert cut_metis < cut_hash


def test_train_balance_objective(plaw):
    """PaGraph's Eq. 3 balances TRAIN vertices, not just vertices."""
    part = PARTITIONERS["pagraph"](plaw, 4)
    assert part.train_balance(plaw) < 2.0


def test_communication_volume_consistency(sbm):
    part = PARTITIONERS["metis_like"](sbm, 4)
    vol = part.communication_volume(sbm)
    assert 0 < vol < sbm.num_edges


def test_vertex_cut_replication(plaw):
    rc = random_vertex_cut(plaw, 4)
    vc2d = cartesian_2d_vertex_cut(plaw, 2, 2)
    lib = libra_vertex_cut(plaw, 4)
    r_rand = rc.replication_factor(plaw)
    r_2d = vc2d.replication_factor(plaw)
    r_lib = lib.replication_factor(plaw)
    assert 1.0 <= r_lib <= r_rand + 1e-9  # greedy should not be worse
    assert 1.0 <= r_2d <= 3.0  # bounded by rows+cols-1


def test_replication_factor_vectorized_matches_loop(plaw, sbm):
    """The numpy replication factor must equal the O(V*deg) Python-loop
    oracle it replaced."""
    from repro.core.partition.vertex_cut import _replication_factor_loop

    for g in (plaw, sbm):
        for vc in (random_vertex_cut(g, 4), cartesian_2d_vertex_cut(g, 2, 2),
                   libra_vertex_cut(g, 4)):
            assert vc.replication_factor(g) == pytest.approx(
                _replication_factor_loop(vc, g), abs=1e-12)


def test_libra_owned_edge_balance(plaw, sbm):
    """Libra's balance cap bounds the owned-edge load:
    max_load <= slack * E / k + 1 (the greedy only considers candidates
    below the cap; the fallback is the globally least-loaded partition)."""
    slack = 1.15
    for g, k in ((plaw, 4), (plaw, 8), (sbm, 8)):
        vc = libra_vertex_cut(g, k, slack=slack)
        loads = np.bincount(vc.edge_owner, minlength=k)
        assert loads.sum() == g.num_edges
        assert loads.max() <= slack * g.num_edges / k + 1, (k, loads)


def test_vertex_cut_masters_hold_their_vertices(plaw):
    """Libra masters must be partitions that actually hold the vertex (the
    layout forces master presence, so a foreign master would silently add
    replicas); master load is spread, not first-holder-concentrated."""
    vc = libra_vertex_cut(plaw, 4)
    counts = vc.replica_counts(plaw)
    present = np.zeros((4, plaw.num_vertices), bool)
    e = 0
    for v in range(plaw.num_vertices):
        for u in plaw.neighbors(v):
            present[vc.edge_owner[e], v] = True
            present[vc.edge_owner[e], u] = True
            e += 1
    held = present[vc.masters, np.arange(plaw.num_vertices)]
    assert held[counts > 0].all()


def test_range_partition_contiguous(sbm):
    part = PARTITIONERS["range"](sbm, 4)
    # contiguity: assignment must be non-decreasing
    assert (np.diff(part.assignment) >= 0).all()
