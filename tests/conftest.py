import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# NOTE: no XLA_FLAGS here — smoke tests must see the real single device.
# Multi-device tests go through run_with_devices (fresh subprocess).


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run `code` in a subprocess with n forced host devices. The code should
    print results; raises on nonzero exit. Returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
