"""Autotuner tier (partition/autotune.py): the planner must score every
candidate with the SAME exact cost models the engine accounts with, pick the
argmin (so it can never choose a plan >=1.5x worse in predicted
critical-path bytes than the best candidate), and hold its choice to account
against a traced dryrun — measured comm.* counter totals within the drift
bound of the prediction (exactly 1.0 for an honest plan, because the oracle
tiers lock the engine accounting to the layouts' cost models), measured
layout-imbalance gauges matching the balance claim, and `PlanRejected` for
plans whose claims drift.
"""
import dataclasses

import numpy as np
import pytest

from conftest import run_with_devices


def _dims(g, hidden=16):
    return [g.features.shape[1], hidden, int(g.labels.max()) + 1]


def test_enumerate_covers_all_families_and_executions():
    from repro.core.graph import powerlaw_graph
    from repro.core.partition.autotune import enumerate_plans

    g = powerlaw_graph(80, avg_degree=6, seed=0)
    plans = enumerate_plans(g, 4, _dims(g), "gcn")
    fams = {p.family for p in plans}
    execs = {p.execution for p in plans}
    assert fams == {"edge_cut", "vertex_cut", "hybrid"}
    assert execs == {"broadcast", "ring", "p2p"}
    # hybrid candidates sweep the degree-percentile thresholds + inf
    thrs = {p.hub_threshold for p in plans if p.family == "hybrid"}
    assert float("inf") in thrs and len(thrs) >= 2
    # vertex-cut candidates opt into the sorted-master layout
    assert all(p.sorted_masters for p in plans if p.family == "vertex_cut")
    for p in plans:
        assert p.predicted_step_bytes > 0
        assert p.predicted_bottleneck_bytes > 0
        assert p.balance_claim  # at least one layout gauge claimed


def test_choose_plan_is_argmin_never_150pct_worse():
    """The acceptance contract: the chosen plan's predicted critical-path
    bytes can never be >= 1.5x the best candidate's — structurally true
    (argmin), asserted over several graphs and both objectives."""
    from repro.core.graph import powerlaw_graph, sbm_graph
    from repro.core.partition.autotune import choose_plan, enumerate_plans

    graphs = [powerlaw_graph(100, avg_degree=8, seed=1),
              sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)]
    for g in graphs:
        plans = enumerate_plans(g, 4, _dims(g), "gcn")
        best = choose_plan(plans, objective="bottleneck")
        floor = min(p.predicted_bottleneck_bytes for p in plans)
        assert best.predicted_bottleneck_bytes == floor
        assert best.predicted_bottleneck_bytes < 1.5 * max(floor, 1)
        best_t = choose_plan(plans, objective="total")
        assert best_t.predicted_step_bytes == min(
            p.predicted_step_bytes for p in plans)
    with pytest.raises(ValueError):
        choose_plan(plans, objective="nope")
    with pytest.raises(ValueError):
        choose_plan([])


def test_choose_plan_deterministic():
    from repro.core.graph import powerlaw_graph
    from repro.core.partition.autotune import choose_plan, enumerate_plans

    g = powerlaw_graph(90, avg_degree=7, seed=3)
    a = choose_plan(enumerate_plans(g, 4, _dims(g), "gat"))
    b = choose_plan(enumerate_plans(g, 4, _dims(g), "gat"))
    assert a == b


def test_validate_plan_measured_matches_predicted_4dev():
    """The traced dryrun's comm.* counters must equal steps * prediction
    EXACTLY (ratio 1.0) for honest plans of every family, and the measured
    layout gauges must reproduce the balance claim."""
    out = run_with_devices("""
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.autotune import (
            choose_plan, enumerate_plans, validate_plan)

        g = powerlaw_graph(100, avg_degree=8, seed=1)
        dims = [g.features.shape[1], 16, int(g.labels.max()) + 1]
        plans = enumerate_plans(g, 4, dims, "gcn")
        for fam in ("edge_cut", "vertex_cut", "hybrid"):
            plan = choose_plan([p for p in plans if p.family == fam])
            rep = validate_plan(g, plan, steps=2)
            assert rep["ratio"] == 1.0, (fam, rep)
            for name, b in rep["balance"].items():
                assert abs(b["measured"] - b["claimed"]) < 1e-9, (fam, name,
                                                                  b)
        print("AT_VALIDATE_OK")
    """, n_devices=4, timeout=600)
    assert "AT_VALIDATE_OK" in out


def test_validate_plan_rejects_drifting_claims_4dev():
    out = run_with_devices("""
        import dataclasses
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.autotune import (
            PlanRejected, choose_plan, enumerate_plans, validate_plan)

        g = powerlaw_graph(100, avg_degree=8, seed=1)
        dims = [g.features.shape[1], 16, int(g.labels.max()) + 1]
        best = choose_plan(enumerate_plans(g, 4, dims, "gcn"))
        bad = dataclasses.replace(
            best, predicted_step_bytes=best.predicted_step_bytes * 10)
        try:
            validate_plan(g, bad, steps=2)
            raise AssertionError("byte drift not rejected")
        except PlanRejected:
            pass
        bad2 = dataclasses.replace(best, balance_claim={
            k: v * 10 for k, v in best.balance_claim.items()})
        try:
            validate_plan(g, bad2, steps=2)
            raise AssertionError("balance drift not rejected")
        except PlanRejected:
            pass
        # a plan scored for a different chip count cannot be validated here
        wrong_k = dataclasses.replace(best, k=64)
        try:
            validate_plan(g, wrong_k, steps=2)
            raise AssertionError("k mismatch not rejected")
        except PlanRejected:
            pass
        print("AT_REJECT_OK")
    """, n_devices=4, timeout=600)
    assert "AT_REJECT_OK" in out


def test_autotune_end_to_end_4dev():
    """enumerate -> choose -> validate in one call; the report carries the
    graph stats and every scored candidate."""
    out = run_with_devices("""
        from repro.core.graph import sbm_graph
        from repro.core.partition.autotune import autotune

        g = sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        dims = [g.features.shape[1], 16, int(g.labels.max()) + 1]
        plan, report = autotune(g, 4, dims, "gcn")
        assert report["chosen"] == plan.label()
        assert report["validation"]["ratio"] == 1.0
        assert len(report["candidates"]) >= 12
        assert report["graph"]["num_vertices"] == 96
        eng_cfg = plan.engine_config()
        assert eng_cfg.partition_family == plan.family
        print("AT_E2E_OK", plan.label())
    """, n_devices=4, timeout=600)
    assert "AT_E2E_OK" in out


def test_graph_stats_degree_profile():
    from repro.core.graph import powerlaw_graph
    from repro.core.partition.autotune import graph_stats

    g = powerlaw_graph(80, avg_degree=6, seed=0)
    s = graph_stats(g)
    deg = g.degree().astype(np.float64)
    assert s["num_vertices"] == 80
    assert s["p95"] == float(np.percentile(deg, 95))
    assert s["max_degree"] == float(deg.max())
    assert s["p90"] <= s["p95"] <= s["p99"] <= s["max_degree"]
