"""Substrate: optimizers, checkpointing, data pipeline, HLO analysis, flops."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, restore_latest, save_checkpoint
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import batch_logical_axes, input_specs, make_batch
from repro.launch import flops as flops_lib
from repro.launch.hlo_analysis import collective_bytes, parse_collectives, roofline_terms
from repro.optim import (
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
    sgdm,
    sparse_adamw,
)


# --- optimizers -------------------------------------------------------------


@pytest.mark.parametrize("make", [
    lambda: adamw(lambda s: 0.1),
    lambda: adafactor(lambda s: 0.5, min_dim_factored=4),
    lambda: sgdm(lambda s: 0.05),
    lambda: sparse_adamw(lambda s: 0.1),
])
def test_optimizer_descends_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                               jnp.float32)}
    state = opt.init(params)
    target = jnp.ones((8, 8))

    def loss(p):
        return jnp.mean(jnp.square(p["w"] - target))

    l0 = float(loss(params))
    for step in range(80):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params, jnp.asarray(step))
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
    assert float(loss(params)) < l0 * 0.3


def test_make_optimizer_registry_and_unknown_name():
    """Every registered name builds an Optimizer (sparse_adamw included);
    an unknown name fails with an actionable error listing the valid ones."""
    for name in ("adamw", "adafactor", "sgdm", "sparse_adamw"):
        opt = make_optimizer(name, lambda s: 0.1)
        assert callable(opt.init) and callable(opt.update)
    with pytest.raises(ValueError) as ei:
        make_optimizer("adam", lambda s: 0.1)
    msg = str(ei.value)
    assert "'adam'" in msg
    for name in ("adamw", "adafactor", "sgdm", "sparse_adamw"):
        assert name in msg, f"error message must list {name}: {msg}"


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 0.1, min_dim_factored=8)
    params = {"big": jnp.zeros((16, 32)), "small": jnp.zeros((4,))}
    st = opt.init(params)
    assert set(st["big"]) == {"vr", "vc"}
    assert st["big"]["vr"].shape == (16,) and st["big"]["vc"].shape == (32,)
    assert set(st["small"]) == {"v"}
    axes = opt.state_logical_axes({"big": ("a", "b"), "small": ("c",)},
                                  {"big": jax.ShapeDtypeStruct((16, 32), jnp.float32),
                                   "small": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert axes["big"]["vr"] == ("a",) and axes["big"]["vc"] == ("b",)


def test_clip_and_schedule():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) <= 1.0 + 1e-5
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-2)


# --- checkpoint -------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(7, jnp.int32)}
    path = save_checkpoint(str(tmp_path), 7, state)
    assert os.path.exists(path)
    restored = load_checkpoint(path, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    latest, step = restore_latest(str(tmp_path), state)
    assert step == 7


def test_checkpoint_gc(tmp_path):
    state = {"x": jnp.zeros(2)}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(ckpts) == 2


# --- data pipeline ----------------------------------------------------------


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_structurally_match_concrete(arch, shape_name):
    """input_specs (dry-run) and make_batch (real data) must agree exactly."""
    cfg = get_smoke_config(arch)
    shape = ShapeConfig(shape_name, 64, 4, INPUT_SHAPES[shape_name].kind)
    specs = input_specs(cfg, shape)
    concrete = make_batch(cfg, shape)
    s_flat, s_def = jax.tree_util.tree_flatten(specs)
    c_flat, c_def = jax.tree_util.tree_flatten(concrete)
    assert s_def == c_def
    for s, c in zip(s_flat, c_flat):
        assert tuple(s.shape) == tuple(c.shape), (arch, shape_name)
        assert s.dtype == c.dtype
    axes = batch_logical_axes(cfg, shape)
    a_def = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda t: 0, axes, is_leaf=lambda t: isinstance(t, tuple)))
    assert a_def == s_def


# --- HLO analysis -----------------------------------------------------------


def test_collective_parser_counts_scan_trips():
    import subprocess
    import sys

    from conftest import SRC

    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((4,), ("x",))
def f(h):
    def body(c, x):
        return c + jax.lax.psum(x, "x"), None
    out, _ = jax.lax.scan(body, h[0], h)
    return out
from repro.compat import shard_map
fn = shard_map(f, mesh=mesh, in_specs=P(None, "x"), out_specs=P("x"), check_vma=False)
comp = jax.jit(fn).lower(jax.ShapeDtypeStruct((6, 64), jnp.float32)).compile()
print("<<<HLO>>>")
print(comp.as_text())
"""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    hlo = proc.stdout.split("<<<HLO>>>")[1]
    recs = parse_collectives(hlo)
    ar = [r for r in recs if r.kind == "all-reduce"]
    assert ar, "no all-reduce found"
    assert max(r.executions for r in ar) == 6  # scan length propagated


def test_roofline_terms_dominance():
    rl = roofline_terms(analytic_flops=1e18, chips=256, hbm_bytes_per_chip=1e9,
                        collective_bytes_per_chip=1e8, model_flops=8e17,
                        hlo_flops_raw=1e13)
    assert rl.dominant == "compute"
    assert 0 < rl.useful_ratio < 1


# --- analytic flops ---------------------------------------------------------


def test_analytic_flops_vs_cost_analysis_single_layer():
    """On a 1-layer config the scan body is counted once by XLA too, so
    cost_analysis must bracket the analytic forward count."""
    import dataclasses

    from repro.models import transformer as T

    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), num_layers=1,
                              remat_policy="none", tie_embeddings=True)
    B, S = 2, 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    from repro.compat import cost_analysis

    comp = jax.jit(lambda p, b: T.loss_fn(cfg, p, b)).lower(params, batch).compile()
    hlo_flops = cost_analysis(comp)["flops"]
    analytic = flops_lib.forward_flops(cfg, B, S).total
    # forward-only analytic should be within ~2.5x of XLA's forward count
    # (XLA counts masks/softmax/etc., we count matmuls+attention)
    assert analytic < hlo_flops * 1.6
    assert hlo_flops < analytic * 3.0, (hlo_flops, analytic)


def test_step_flops_shapes():
    cfg = get_config("llama3.2-1b")
    tr = flops_lib.step_flops(cfg, INPUT_SHAPES["train_4k"]).total
    pf = flops_lib.step_flops(cfg, INPUT_SHAPES["prefill_32k"]).total
    dc = flops_lib.step_flops(cfg, INPUT_SHAPES["decode_32k"]).total
    assert tr > pf > dc > 0
    mf = flops_lib.model_flops_6nd(cfg, INPUT_SHAPES["train_4k"])
    assert 0.3 < mf / tr < 1.2  # 6ND ~ analytic for a dense model


def test_moe_active_flops_much_smaller_than_total():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.num_active_params() < cfg.num_params() / 15
