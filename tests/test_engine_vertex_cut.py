"""DistGNNEngine vertex-cut tier (subprocess, forced host devices): the full
{vertex-cut partitioner} x {broadcast, ring, p2p} x {sync, epoch_fixed,
epoch_adaptive, variation} matrix must match the single-device oracle to
<=1e-4 — the replica layout, the owned-edge partial aggregation, the
replica-sync combine (all_gather / ring ppermute / master-based two-phase
all_to_all GAS) and the master-masked loss may not change the math.

Also locked down here: bitwise determinism across runs and engines, the
one-compile-per-config contract, the agreement between engine-reported
CommStats.replica_sync_bytes and the standalone replication-aware cost model,
and the family anchor: under protocol='sync' the vertex-cut oracle computes
the SAME global GCN as the edge-cut oracle (same params init), so the two
families' reference losses must agree — the whole vertex-cut dataflow is
pinned to the real graph math, not just to itself.
"""
import pytest

from conftest import run_with_devices

_MATRIX_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for i, (vcut, exe, proto) in enumerate(
            itertools.product({vcuts}, {execs}, {protocols})):
        cfg = EngineConfig(partition_family="vertex_cut", vertex_cut=vcut,
                           execution=exe, protocol=proto, hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        tag = f"{{vcut}}/{{exe}}/{{proto}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}}")
        if not (err <= 1e-4 and np.isfinite(losses_d[-1])):
            fails.append((tag, err))
    assert not fails, fails
    print("VC_MATRIX_OK")
"""


@pytest.mark.parametrize("vcut", ["random", "cartesian2d", "libra"])
def test_vertex_cut_matrix_4dev(vcut):
    """One vertex-cut partitioner x ALL execution models x ALL protocols per
    subprocess — together the three parametrizations cover the full
    3 x 3 x 4 matrix on 4 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=80, epochs=3,
        vcuts=(vcut,),
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync", "epoch_fixed", "epoch_adaptive", "variation"),
    ), n_devices=4, timeout=600)
    assert "VC_MATRIX_OK" in out


def test_vertex_cut_matrix_8dev():
    """All vertex cuts x all execution models x {sync, epoch_adaptive} on 8
    devices (2x4 cartesian grid)."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=128, epochs=3,
        vcuts=("random", "cartesian2d", "libra"),
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync", "epoch_adaptive"),
    ), n_devices=8, timeout=600)
    assert "VC_MATRIX_OK" in out


def test_vertex_cut_determinism_and_recompile_4dev():
    """Same seed -> bitwise-identical losses across runs AND engines, and the
    jitted step compiles EXACTLY once per config."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        cfg = EngineConfig(partition_family="vertex_cut", vertex_cut="libra",
                           execution="p2p", protocol="epoch_adaptive",
                           hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        l1, _ = eng.train(5)
        n = eng._jit_step._cache_size()
        assert n == 1, f"expected 1 compile, got {n}"
        l2, _ = eng.train(5)
        assert l1 == l2, (l1, l2)
        assert eng._jit_step._cache_size() == 1
        eng2 = DistGNNEngine(g, cfg=cfg)
        l3, _ = eng2.train(5)
        assert l1 == l3, (l1, l3)
        print("VC_DET_OK", l1[-1])
    """, n_devices=4)
    assert "VC_DET_OK" in out


def test_vertex_cut_comm_stats_cross_check_4dev():
    """Engine-reported CommStats.replica_sync_bytes == the standalone
    replication-aware cost model over a layout rebuilt from scratch, for
    every execution model; p2p (master-based GAS) must move fewer bytes than
    broadcast/ring (full partial-block exchange)."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.cost_models import replica_sync_bytes_per_step
        from repro.core.partition.vertex_cut import VERTEX_CUTS
        from repro.core.partition.vertex_layout import build_vertex_layout

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        seen = {}
        for exe in ("broadcast", "ring", "p2p"):
            cfg = EngineConfig(partition_family="vertex_cut",
                               vertex_cut="libra", execution=exe,
                               hidden=16, lr=0.3)
            eng = DistGNNEngine(g, cfg=cfg)
            eng.train(4)
            lay = build_vertex_layout(g, VERTEX_CUTS["libra"](g, 4, seed=0), 4)
            expected = 4 * replica_sync_bytes_per_step(
                lay.rep_count, 4, lay.nv, exe, eng.dims)
            got = eng.comm_stats.replica_sync_bytes
            assert got == expected and got > 0, (exe, got, expected)
            assert eng.comm_stats.total() == got  # counted as wire bytes
            seen[exe] = got
        assert seen["p2p"] < seen["broadcast"] == seen["ring"], seen
        print("VC_BYTES_OK", seen)
    """, n_devices=4)
    assert "VC_BYTES_OK" in out


def test_vertex_cut_anchors_to_edge_cut_oracle_4dev():
    """Family anchor: under sync the two families compute the same global
    GCN from the same param init, so their single-device references must
    produce the same losses — and the vertex-cut DISTRIBUTED run matches
    both."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        cfgv = EngineConfig(partition_family="vertex_cut",
                            vertex_cut="cartesian2d", execution="p2p",
                            hidden=16, lr=0.3)
        cfge = EngineConfig(execution="p2p", hidden=16, lr=0.3)
        engv = DistGNNEngine(g, cfg=cfgv)
        lv_dist, _ = engv.train(4)
        lv_ref, _ = engv.train(4, reference=True)
        le_ref, _ = DistGNNEngine(g, cfg=cfge).train(4, reference=True)
        gap_fam = max(abs(a - b) for a, b in zip(lv_ref, le_ref))
        gap_dist = max(abs(a - b) for a, b in zip(lv_dist, le_ref))
        assert gap_fam <= 1e-4, gap_fam
        assert gap_dist <= 1e-4, gap_dist
        print("VC_ANCHOR_OK", gap_fam, gap_dist)
    """, n_devices=4)
    assert "VC_ANCHOR_OK" in out


def test_vertex_cut_rejects_bad_config():
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import er_graph
    from repro.core.partition.edge_cut import hash_partition

    g = er_graph(32, avg_degree=4, seed=0)
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="nope"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="vertex_cut",
                                          vertex_cut="nope"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="vertex_cut",
                                          batching="node_wise"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(partition_family="vertex_cut"),
                      partition=hash_partition(g, 1))


def test_vertex_cut_single_device_paths_agree():
    """On one device the distributed vertex-cut step IS the oracle (every
    replica table degenerate) and still learns."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.01, seed=1)
    mesh = jax.make_mesh((1,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
        partition_family="vertex_cut", vertex_cut="libra", execution="p2p",
        hidden=16, lr=0.3))
    ld, _ = eng.train(8)
    lr_, _ = eng.train(8, reference=True)
    assert max(abs(a - b) for a, b in zip(ld, lr_)) < 1e-4
    assert ld[-1] < ld[0]


def test_sorted_masters_layout_equivalent_4dev():
    """``sorted_masters=True`` reorders each device's replica slots
    master-first (the contiguous-prefix layout the autotuner weighs) — a
    pure relabeling: training must still match the oracle, and the
    de-layouted global embeddings must equal the default layout's (the
    prefix-slice read path agrees with the boolean-mask read path)."""
    out = run_with_devices("""
        import numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(100, avg_degree=8, seed=2)
        embs = {}
        for sm in (False, True):
            cfg = EngineConfig(partition_family="vertex_cut",
                               vertex_cut="libra", execution="p2p",
                               sorted_masters=sm, hidden=16, lr=0.3)
            eng = DistGNNEngine(g, cfg=cfg)
            ld, _ = eng.train(4)
            lr_, _ = eng.train(4, reference=True)
            err = max(abs(a - b) for a, b in zip(ld, lr_))
            assert err <= 1e-4, (sm, err)
            lay = eng.playout.layout
            if sm:
                # masters ARE the per-device slot prefix
                for d in range(eng.k):
                    n = int(lay.master_counts[d])
                    mm = lay.master_mask[d] > 0.5
                    assert mm[:n].all() and not mm[n:].any(), d
            state = eng.init_state()
            embs[sm] = eng.global_embeddings(
                eng.infer_full_graph(state))
        np.testing.assert_array_equal(embs[False], embs[True])
        print("VC_SORTED_OK")
    """, n_devices=4, timeout=600)
    assert "VC_SORTED_OK" in out
