"""Hypothesis property tests on the PowerLyra-style hybrid degree-threshold
cut (partition/hybrid_cut.py) — the invariants the engine's hybrid layout
relies on: every vertex in exactly ONE class (hub xor low-degree), the hub
set exactly == {v : degree(v) >= threshold}, the degenerate thresholds
(threshold=inf -> pure edge-cut dataflow with no replicas; threshold=0 ->
pure src-replicating vertex-cut with no halo), layout well-formedness (every
vertex present on its master, every owned edge resolvable to local slots,
low-degree vertices never replicated), and bitwise determinism in seed.

Requires the optional ``hypothesis`` dependency (the ``property`` test
extra); without it the module degrades to a skip instead of a collection
error — same gating as test_vertex_cut_property.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.graph import er_graph, powerlaw_graph
from repro.core.partition.hybrid_cut import (
    HybridLayout,
    auto_hub_threshold,
    build_hybrid_cut,
)
from repro.core.partition.vertex_cut import edge_endpoints

SETTINGS = dict(max_examples=20, deadline=None)


def _layout(g, k, threshold, seed=0, execution="p2p"):
    from repro.core.engine import EngineConfig
    cfg = EngineConfig(partition_family="hybrid", hub_threshold=threshold,
                       execution=execution, seed=seed)
    return HybridLayout(g, k, cfg)


@given(st.integers(20, 100), st.integers(2, 8), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_vertex_classes_partition_the_graph(n, k, seed):
    """Hub xor low-degree: the two classes cover every vertex exactly once,
    and the hub set is EXACTLY the degree-threshold upcrossing."""
    g = powerlaw_graph(n, avg_degree=6, seed=seed % 17)
    thr = auto_hub_threshold(g)
    cut = build_hybrid_cut(g, k, threshold=thr)
    deg = g.degree().astype(np.float64)
    assert cut.hub.shape == (g.num_vertices,)
    np.testing.assert_array_equal(cut.hub, deg >= thr)
    # one class per vertex is structural for a boolean mask; the owner rule
    # must route every edge to a real partition
    assert len(cut.edge_owner) == len(g.indices)
    assert ((cut.edge_owner >= 0) & (cut.edge_owner < k)).all()
    src, dst = edge_endpoints(g)
    want = np.where(cut.hub[dst], cut.masters[src], cut.masters[dst])
    np.testing.assert_array_equal(cut.edge_owner, want)


@given(st.integers(20, 90), st.integers(2, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_threshold_inf_is_pure_edge_cut(n, k, seed):
    """threshold=inf: no hubs, every edge owned by its DESTINATION's master,
    no vertex replicated (rep_count == 1 everywhere), and the layout runs
    halo-only (sync inactive)."""
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    cut = build_hybrid_cut(g, k, threshold=np.inf)
    assert not cut.hub.any()
    src, dst = edge_endpoints(g)
    np.testing.assert_array_equal(cut.edge_owner, cut.masters[dst])
    lay = _layout(g, k, np.inf, seed=seed % 7)
    assert (lay.layout.rep_count == 1).all()
    assert not lay.sync_active and not lay.has_replicas


@given(st.integers(20, 90), st.integers(2, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_threshold_zero_is_pure_vertex_cut(n, k, seed):
    """threshold=0: every vertex is a hub, every edge owned by its SOURCE's
    master (src-replicating vertex cut), and no halo exchange remains."""
    g = er_graph(n, avg_degree=5, seed=seed % 13)
    cut = build_hybrid_cut(g, k, threshold=0.0)
    assert cut.hub.all()
    src, dst = edge_endpoints(g)
    np.testing.assert_array_equal(cut.edge_owner, cut.masters[src])
    lay = _layout(g, k, 0.0, seed=seed % 7)
    assert not lay.halo_active and lay.halo_rows == 0


@given(st.integers(20, 80), st.integers(2, 6), st.integers(0, 10_000),
       st.sampled_from(["auto", "p90", "zero", "inf"]))
@settings(**SETTINGS)
def test_hybrid_layout_well_formed(n, k, seed, which):
    """Layout invariants for arbitrary thresholds: every vertex present on
    its master exactly once across its replicas' master flags, every slot's
    global id valid, every owned edge resolvable (mask rows sum to the
    owned in-degree), and LOW-DEGREE vertices never replicated."""
    g = powerlaw_graph(n, avg_degree=5, seed=seed % 11)
    deg = g.degree().astype(np.float64)
    thr = {"auto": None, "p90": float(np.percentile(deg, 90)),
           "zero": 0.0, "inf": np.inf}[which]
    lay = _layout(g, k, thr, seed=seed % 5)
    inner, cut = lay.layout, lay.cut
    V = g.num_vertices
    # every vertex on its master, and master flagged exactly once
    master_count = np.zeros(V, np.int64)
    for d in range(k):
        vids = inner.vert_ids[d]
        real = vids < V
        assert len(np.unique(vids[real])) == real.sum()  # no dup slots
        flagged = inner.master_mask[d] > 0.5
        assert (cut.masters[vids[flagged]] == d).all()
        np.add.at(master_count, vids[flagged], 1)
    np.testing.assert_array_equal(master_count, np.ones(V, np.int64))
    # owned-edge mass conservation: each device's ELL mask rows sum to the
    # number of edges the cut assigned it
    owned = np.bincount(cut.edge_owner, minlength=k)
    got = inner.mask_owned.reshape(k, -1).sum(1)
    np.testing.assert_allclose(got, owned)
    # low-degree vertices stay single-copy (the PowerLyra contract)
    low = ~cut.hub
    assert (inner.rep_count[low] <= 1).all()


@given(st.integers(20, 80), st.integers(2, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_hybrid_deterministic_in_seed(n, k, seed):
    """Same (graph, k, threshold, seed) -> bitwise-identical cut and layout;
    the engine's determinism contract starts here."""
    g = powerlaw_graph(n, avg_degree=5, seed=seed % 11)
    a = build_hybrid_cut(g, k)
    b = build_hybrid_cut(g, k)
    assert a.threshold == b.threshold
    np.testing.assert_array_equal(a.hub, b.hub)
    np.testing.assert_array_equal(a.masters, b.masters)
    np.testing.assert_array_equal(a.edge_owner, b.edge_owner)
    la, lb = _layout(g, k, None, seed=3), _layout(g, k, None, seed=3)
    np.testing.assert_array_equal(la.layout.vert_ids, lb.layout.vert_ids)
    np.testing.assert_array_equal(la.layout.ids_owned, lb.layout.ids_owned)
    np.testing.assert_array_equal(np.asarray(la.ids_global),
                                  np.asarray(lb.ids_global))
