"""Hypothesis property tests on system invariants.

Requires the optional ``hypothesis`` dependency (the ``property`` test extra);
without it the whole module degrades to a skip instead of a collection error.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.execution import parallel_chunk_aggregate, sequential_chunk_aggregate
from repro.core.graph import er_graph
from repro.core.partition.edge_cut import hash_partition, ldg_partition
from repro.core.protocols.async_hist import HistoricalState, epoch_adaptive_refresh
from repro.kernels import ref
from repro.models.layers import chunked_attention
from repro.utils import cdiv, round_up

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(1, 1000), st.integers(1, 64))
@settings(**SETTINGS)
def test_cdiv_round_up(a, b):
    assert cdiv(a, b) * b >= a
    assert round_up(a, b) % b == 0
    assert 0 <= round_up(a, b) - a < b


@given(st.integers(20, 120), st.integers(2, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_partition_invariants(n, k, seed):
    g = er_graph(n, avg_degree=4, seed=seed % 17)
    for part in (hash_partition(g, k, seed=seed), ldg_partition(g, k, seed=seed)):
        assert part.assignment.shape == (n,)
        assert set(np.unique(part.assignment)) <= set(range(k))
        sizes = np.bincount(part.assignment, minlength=k)
        assert sizes.max() <= np.ceil(1.5 * n / k) + 1  # slack bound


@given(st.integers(1, 4), st.integers(2, 5), st.integers(1, 3), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_chunk_aggregation_equivalence(nc_pow, rows_pow, d_pow, seed):
    rng = np.random.default_rng(seed)
    n_chunks = 2 ** nc_pow
    rows = 2 ** rows_pow
    cols = n_chunks * (seed % 3 + 1)
    A = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    H = jnp.asarray(rng.standard_normal((cols, 2 ** d_pow)), jnp.float32)
    ref_out = np.asarray(A @ H)
    np.testing.assert_allclose(np.asarray(sequential_chunk_aggregate(A, H, n_chunks)),
                               ref_out, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(parallel_chunk_aggregate(A, H, n_chunks)),
                               ref_out, atol=1e-4, rtol=1e-3)


@given(st.integers(1, 2), st.integers(1, 3), st.sampled_from([16, 32, 64]),
       st.sampled_from([8, 16]), st.integers(0, 1000))
@settings(**SETTINGS)
def test_chunked_attention_softmax_rows(B, H, S, D, seed):
    """Output rows are convex combinations of V rows: max(|out|) <= max(|v|)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    out = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


@given(st.integers(8, 40), st.integers(2, 5), st.integers(2, 5), st.integers(0, 100))
@settings(**SETTINGS)
def test_staleness_age_never_exceeds_bound(V, K, bound, seed):
    rng = np.random.default_rng(seed)
    assignment = jnp.asarray(rng.integers(0, K, V), jnp.int32)
    bmask = jnp.asarray(rng.random(V) < 0.7)
    state = HistoricalState.create(V, 4, K)
    for step in range(2 * bound + 3):
        h = jnp.asarray(rng.standard_normal((V, 4)), jnp.float32)
        _, state = epoch_adaptive_refresh(state, h, jnp.asarray(step), assignment,
                                          bmask, staleness=bound)
        assert int(state.age.max()) <= bound


@given(st.integers(8, 64), st.integers(2, 8), st.integers(8, 32), st.integers(0, 500))
@settings(**SETTINGS)
def test_ell_spmm_oracle_matches_dense(V, K, D, seed):
    """ELL aggregation == dense adjacency product (the format invariant)."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, V, (V, K)).astype(np.int32)
    mask = (rng.random((V, K)) < 0.5).astype(np.float32)
    H = rng.standard_normal((V, D)).astype(np.float32)
    y = np.asarray(ref.ell_spmm_ref(jnp.asarray(ids), jnp.asarray(mask),
                                    jnp.asarray(H), normalize=False))
    A = np.zeros((V, V), np.float32)
    for v in range(V):
        for j in range(K):
            if mask[v, j]:
                A[v, ids[v, j]] += 1.0
    np.testing.assert_allclose(y, A @ H, atol=1e-4, rtol=1e-3)


@given(st.integers(2, 16), st.integers(1, 8))
@settings(**SETTINGS)
def test_router_weights_normalized(T, seed):
    """MoE router top-k weights are a convex combination (sum to 1)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.layers import ParamBuilder
    from repro.models.moe import _router, moe_params

    cfg = get_smoke_config("kimi-k2-1t-a32b")
    p = moe_params(ParamBuilder("init", jax.random.PRNGKey(seed)), cfg)
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((T, cfg.d_model)),
                    jnp.float32)
    w, ids, aux = _router(p, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(T), atol=1e-3)
    assert int(ids.max()) < cfg.num_experts
    # E * sum(f*p) ~ 1 for balanced routing; >= 1 only in expectation, so
    # allow small-T fluctuation below it
    assert float(aux) >= 0.9
