"""Continuous-batching engine: interleaved requests must produce EXACTLY the
tokens each request gets when decoded alone (slot isolation + per-slot
positions), with occupancy > single-request batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.batching import ContinuousBatchingEngine, Request
from repro.launch.serve import greedy_decode
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _solo(cfg, params, prompt, n):
    return np.asarray(greedy_decode(cfg, params, jnp.asarray(prompt)[None], n,
                                    max_len=32))[0].tolist()


def test_interleaved_requests_match_solo(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9, 4)]
    refs = [_solo(cfg, params, p, 5) for p in prompts]
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=5))
    outs = {i: [] for i in range(3)}
    for t in range(80):
        if t == 2:
            eng.submit(Request(uid=1, prompt=prompts[1], max_new=5))
        if t == 5:
            eng.submit(Request(uid=2, prompt=prompts[2], max_new=5))
        for uid, tok in eng.tick():
            outs[uid].append(tok)
        if t > 5 and not eng.queue and all(a is None for a in eng.active):
            break
    for i in range(3):
        assert outs[i] == refs[i], (i, outs[i], refs[i])
    assert eng.stats.requests_completed == 3
    assert eng.stats.mean_occupancy > 0.5


def test_slot_reuse_does_not_leak_state(setup):
    """A slot reused by a second request must not see the first request's
    cache (positions reset; masking hides stale rows)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    pa = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
    pb = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(uid=0, prompt=pa, max_new=4))
    eng.submit(Request(uid=1, prompt=pb, max_new=4))
    outs = {0: [], 1: []}
    for _ in range(40):
        for uid, tok in eng.tick():
            outs[uid].append(tok)
        if not eng.queue and all(a is None for a in eng.active):
            break
    assert outs[0] == _solo(cfg, params, pa, 4)
    assert outs[1] == _solo(cfg, params, pb, 4)  # unpolluted by request 0
