"""DistGNNEngine MODEL-AXIS tier (subprocess, forced host devices): the
survey's §3 models {sage, gat, gin} through every jitted path — full-graph
edge-cut and vertex-cut (all execution models) and sampled mini-batches —
must match the extended single-device oracle to <=1e-4 (gcn is pinned by the
older tiers).  The model may not change where the math runs: sage/gin's self
features stay resident, gat's edge-wise attention rides the SDDMM logits +
masked segment-softmax (two-pass max/sum replica sync under vertex_cut), and
pad slots stay inert everywhere.

Also locked down here: bitwise determinism and the one-compile guard on the
hairiest path (gat x vertex_cut x p2p), CommStats == the model-aware
replica-sync cost model (gat pays the attention-coefficient bytes; sage/gin
pay exactly gcn's), and the bucketed mini-batch frontier fetch (satellite:
power-of-two installments replace the monolithic fcap send buffer,
loss-identical to the monolithic plan).
"""
import pytest

from conftest import run_with_devices

_FULL_GRAPH_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for i, (model, exe) in enumerate(
            itertools.product({models}, {execs})):
        proto = {protocols}[i % len({protocols})]
        cfg = EngineConfig(model=model, execution=exe, protocol=proto,
                           partition_family={family!r},
                           vertex_cut="cartesian2d", hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        tag = f"{{model}}/{{exe}}/{{proto}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}}")
        if not (err <= 1e-4 and np.isfinite(losses_d[-1])):
            fails.append((tag, err))
    assert not fails, fails
    print("MODEL_MATRIX_OK")
"""


def test_model_matrix_edge_cut_4dev():
    """models x execution models on the edge-cut full-graph path, cycling
    the protocols so async history rides every model."""
    out = run_with_devices(_FULL_GRAPH_CODE.format(
        V=96, epochs=3, family="edge_cut",
        models=("sage", "gat", "gin"),
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync", "epoch_adaptive", "variation"),
    ), n_devices=4, timeout=600)
    assert "MODEL_MATRIX_OK" in out


def test_model_matrix_vertex_cut_4dev():
    """models x replica-sync execution models on the vertex-cut path — the
    gat combination exercises the two-pass (max, then sum) replica sync."""
    out = run_with_devices(_FULL_GRAPH_CODE.format(
        V=80, epochs=3, family="vertex_cut",
        models=("sage", "gat", "gin"),
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync",),
    ), n_devices=4, timeout=600)
    assert "MODEL_MATRIX_OK" in out


def test_model_matrix_8dev():
    """Both partition families x all models on 8 devices (p2p exchange)."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(128, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        for model in ("sage", "gat", "gin"):
            for family in ("edge_cut", "vertex_cut"):
                cfg = EngineConfig(model=model, execution="p2p",
                                   partition_family=family,
                                   vertex_cut="cartesian2d",
                                   hidden=16, lr=0.3)
                eng = DistGNNEngine(g, cfg=cfg)
                ld, _ = eng.train(3)
                lr_, _ = eng.train(3, reference=True)
                err = max(abs(a - b) for a, b in zip(ld, lr_))
                assert err <= 1e-4 and np.isfinite(ld[-1]), (
                    model, family, err)
                print(f"{model}/{family}: err={err:.2e}")
        print("MODEL_8DEV_OK")
    """, n_devices=8, timeout=600)
    assert "MODEL_8DEV_OK" in out


def test_model_matrix_minibatch_4dev():
    """models x execution models on sampled mini-batches: the padded dense
    blocks + resident self_idx tables vs the vmapped oracle; gat's
    attention runs over the folded self-loop blocks."""
    out = run_with_devices("""
        import itertools
        import jax, numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        batchings = ("node_wise", "layer_wise", "subgraph")
        for i, (model, exe) in enumerate(
                itertools.product(("sage", "gat", "gin"),
                                  ("broadcast", "ring", "p2p"))):
            cfg = EngineConfig(model=model, execution=exe,
                               batching=batchings[i % 3], batch_size=8,
                               fanouts=(3, 3), layer_sizes=(16, 16),
                               walk_length=3, hidden=16, lr=0.3,
                               cache_policy="static_degree",
                               cache_capacity=12)
            eng = DistGNNEngine(g, cfg=cfg)
            ld, logits_d = eng.train(3)
            lr_, logits_r = eng.train(3, reference=True)
            err = max(abs(a - b) for a, b in zip(ld, lr_))
            lerr = float(abs(logits_d - logits_r).max())
            tag = f"{model}/{exe}/{cfg.batching}"
            assert err <= 1e-4 and lerr <= 1e-4, (tag, err, lerr)
            print(f"{tag}: err={err:.2e} lerr={lerr:.2e}")
        print("MODEL_MB_OK")
    """, n_devices=4, timeout=600)
    assert "MODEL_MB_OK" in out


def test_model_determinism_and_recompile_4dev():
    """gat x vertex_cut x p2p (the most plan-heavy path): bitwise-identical
    losses across runs AND engines, exactly one compile per config."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        cfg = EngineConfig(model="gat", partition_family="vertex_cut",
                           vertex_cut="libra", execution="p2p",
                           protocol="epoch_adaptive", hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        l1, _ = eng.train(5)
        n = eng._jit_step._cache_size()
        assert n == 1, f"expected 1 compile, got {n}"
        l2, _ = eng.train(5)
        assert l1 == l2, (l1, l2)
        assert eng._jit_step._cache_size() == 1
        eng2 = DistGNNEngine(g, cfg=cfg)
        l3, _ = eng2.train(5)
        assert l1 == l3, (l1, l3)
        # mini-batch gat: one compile too (self_idx tables are static)
        cfgm = EngineConfig(model="gat", execution="p2p",
                            batching="node_wise", batch_size=8,
                            fanouts=(3, 3), hidden=16, lr=0.3)
        engm = DistGNNEngine(g, cfg=cfgm)
        m1, _ = engm.train(4)
        assert engm._jit_mb_step._cache_size() == 1
        m2, _ = engm.train(4)
        assert m1 == m2, (m1, m2)
        print("MODEL_DET_OK", l1[-1], m1[-1])
    """, n_devices=4)
    assert "MODEL_DET_OK" in out


def test_model_comm_stats_cross_check_4dev():
    """Engine-reported replica-sync bytes == the MODEL-AWARE cost model for
    every model x execution; gat pays the attention-coefficient + max-pass
    bytes, sage/gin pay exactly gcn's bytes (self features are resident)."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.cost_models import (
            model_exchange_widths, replica_sync_bytes_per_step)
        from repro.core.partition.vertex_cut import VERTEX_CUTS
        from repro.core.partition.vertex_layout import build_vertex_layout

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        lay = build_vertex_layout(g, VERTEX_CUTS["libra"](g, 4, seed=0), 4)
        per_model = {}
        for model in ("gcn", "sage", "gat", "gin"):
            for exe in ("broadcast", "ring", "p2p"):
                cfg = EngineConfig(model=model, partition_family="vertex_cut",
                                   vertex_cut="libra", execution=exe,
                                   hidden=16, lr=0.3)
                eng = DistGNNEngine(g, cfg=cfg)
                eng.train(3)
                expected = 3 * replica_sync_bytes_per_step(
                    lay.rep_count, 4, lay.nv, exe, eng.dims, model=model)
                got = eng.comm_stats.replica_sync_bytes
                assert got == expected and got > 0, (model, exe, got, expected)
            per_model[model] = got
            widths = model_exchange_widths(model, eng.dims, "vertex_cut")
            print(model, "widths", widths, "p2p bytes", got)
        assert per_model["sage"] == per_model["gcn"]
        assert per_model["gin"] == per_model["gcn"]
        assert per_model["gat"] != per_model["gcn"]
        print("MODEL_BYTES_OK", per_model)
    """, n_devices=4, timeout=600)
    assert "MODEL_BYTES_OK" in out


def test_minibatch_fcap_bucketing_4dev():
    """Satellite: the p2p frontier fetch rides power-of-two installments —
    bucketed plans are loss-identical (bitwise) to the monolithic fcap
    buffer and still match the oracle; the per-round send operand is
    ~buckets x narrower."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        kw = dict(execution="p2p", batching="node_wise", batch_size=12,
                  fanouts=(4, 4), hidden=16, lr=0.3)
        e1 = DistGNNEngine(g, cfg=EngineConfig(**kw))
        eB = DistGNNEngine(g, cfg=EngineConfig(p2p_buckets=4, **kw))
        assert len(eB.fcap_widths) > 1, (eB.fcap, eB.fcap_widths)
        assert eB.fcap_widths[0] < e1.fcap_widths[0]
        assert sum(eB.fcap_widths) >= eB.fcap  # still covers the halo cap
        l1, _ = e1.train(4)
        lB, _ = eB.train(4)
        assert l1 == lB, (l1, lB)
        lr_, _ = eB.train(4, reference=True)
        err = max(abs(a - b) for a, b in zip(lB, lr_))
        assert err <= 1e-4, err
        print("FCAP_BUCKETS_OK", e1.fcap, eB.fcap_widths)
    """, n_devices=4)
    assert "FCAP_BUCKETS_OK" in out


def test_stale_protocol_config_fails_fast():
    """Satellite: a config mutated to an async protocol AFTER construction
    fails at epoch entry with an actionable message, not deep in jit."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import er_graph

    g = er_graph(32, avg_degree=4, seed=0)
    mesh = jax.make_mesh((1,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
        batching="node_wise", batch_size=4, fanouts=(2, 2), hidden=8))
    eng.cfg.protocol = "epoch_adaptive"  # stale mutation
    with pytest.raises(ValueError, match="protocol='sync'"):
        eng.run_epoch_minibatch(2)
    with pytest.raises(ValueError, match="protocol='sync'"):
        eng.train(2)
    eng.cfg.protocol = "sync"
    _, losses, _ = eng.run_epoch_minibatch(2)  # recovers once fixed
    assert len(losses) == 2
    # full-graph engines reject the mini-batch epoch entry too
    eng2 = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(hidden=8))
    with pytest.raises(ValueError, match="full_graph"):
        eng2.run_epoch_minibatch(2)


def test_model_single_device_paths_agree():
    """On one device every model's distributed step IS its oracle, and it
    learns."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.01, seed=1)
    mesh = jax.make_mesh((1,), ("w",))
    for model in ("sage", "gat", "gin"):
        eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
            model=model, execution="p2p", hidden=16, lr=0.2))
        ld, _ = eng.train(8)
        lr_, _ = eng.train(8, reference=True)
        assert max(abs(a - b) for a, b in zip(ld, lr_)) < 1e-4, model
        assert ld[-1] < ld[0], (model, ld)


def test_gat_fused_s_column_chunk_invariance_4dev():
    """The edge-cut GAT attention-coefficient column rides CHUNK 0 of the
    chunked exchange (fused with the first Hw columns) instead of a separate
    width-1 pre-pass — so the forward pass must be BITWISE identical for any
    ``exchange_chunks`` (per-column math never changes with the chunking),
    and training must stay on the oracle contract."""
    out = run_with_devices("""
        import numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(60, num_blocks=4, p_in=0.1, p_out=0.02, seed=0)
        for exe in ("broadcast", "p2p"):
            base_fwd = base_loss1 = None
            for C in (1, 2, 3):
                cfg = EngineConfig(model="gat", execution=exe,
                                   exchange_chunks=C, hidden=12, lr=0.3)
                eng = DistGNNEngine(g, cfg=cfg)
                fwd = np.asarray(eng.infer_full_graph(
                    eng.init_state())).tobytes()
                losses, _ = eng.train(3)
                lr_, _ = eng.train(3, reference=True)
                err = max(abs(a - b) for a, b in zip(losses, lr_))
                assert err <= 1e-4, (exe, C, err)
                if base_fwd is None:
                    base_fwd, base_loss1 = fwd, losses[0]
                else:
                    # forward sweep: bitwise equal across chunk counts
                    assert fwd == base_fwd, (exe, C)
                    # first loss is forward-only -> bitwise equal too
                    assert losses[0] == base_loss1, (exe, C)
        print("GAT_FUSE_OK")
    """, n_devices=4, timeout=600)
    assert "GAT_FUSE_OK" in out
