"""Streaming partition ingest tier (partition/streaming.py).

The chunked edge-stream builder must be a DROP-IN for the in-memory layout:
every array the engine derives from a resident CSR graph — relabeling, ELL
adjacency + mask + degree, owner-sharded features, label/mask planes,
boundary rows — must come out bit-identical from the two-pass
ingest -> owner-shuffle -> incremental-scatter path, for any chunk size.
And the point of streaming must be checkable: the builder's self-reported
peak transient footprint is a function of ``chunk_edges``, NOT of |E|.
"""
import numpy as np
import pytest

from conftest import run_with_devices


def test_streaming_layout_identical_to_engine_4dev():
    """Array-for-array equality with `DistGNNEngine._build_layout` across
    chunk sizes (including chunk < K, chunk > E) and graph families, on the
    engine's own metis-like assignment."""
    out = run_with_devices("""
        import numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph, sbm_graph
        from repro.core.partition.streaming import (
            GraphEdgeChunks,
            build_streaming_layout,
        )

        for gname, g in (
                ("sbm", sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01,
                                  seed=0)),
                ("powerlaw", powerlaw_graph(128, avg_degree=6, seed=1))):
            eng = DistGNNEngine(g, cfg=EngineConfig(hidden=8))
            for chunk in (7, 64, 10**6):
                lay = build_streaming_layout(
                    GraphEdgeChunks(g, chunk), eng.part.assignment, eng.k,
                    g.num_vertices, features=g.features, labels=g.labels,
                    train_mask=g.train_mask, test_mask=g.test_mask)
                assert (lay.nb, lay.Vp, lay.K) == (eng.nb, eng.Vp, eng.K)
                np.testing.assert_array_equal(lay.new_of_old, eng.new_of_old)
                np.testing.assert_array_equal(lay.ids, eng.ids_global)
                np.testing.assert_array_equal(lay.mask, np.asarray(eng.mask))
                np.testing.assert_array_equal(lay.deg, np.asarray(eng.deg))
                np.testing.assert_array_equal(
                    lay.X, np.asarray(eng.store._table))
                np.testing.assert_array_equal(lay.y, np.asarray(eng.y))
                np.testing.assert_array_equal(
                    lay.train_w, np.asarray(eng.train_w))
                np.testing.assert_array_equal(
                    lay.test_w, np.asarray(eng.test_w))
                np.testing.assert_array_equal(lay.emb_touched,
                                              eng.emb_touched)
                np.testing.assert_array_equal(lay.bmask,
                                              np.asarray(eng.bmask))
                print(f"{gname}/chunk={chunk}: identical "
                      f"(peak_transient={lay.peak_transient_bytes})")
        print("STREAM_EQ_OK")
    """, n_devices=4, timeout=420)
    assert "STREAM_EQ_OK" in out


def _hash_assignment(V, k, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.permutation(V) % k).astype(np.int32)


def test_peak_memory_bounded_by_chunk_not_graph():
    """4x the edges, same chunk size -> same peak transient footprint; and
    growing the chunk grows the peak.  (Pure host path, no devices.)"""
    from repro.core.graph import er_graph
    from repro.core.partition.streaming import (
        GraphEdgeChunks,
        build_streaming_layout,
    )

    def build(g, chunk):
        return build_streaming_layout(
            GraphEdgeChunks(g, chunk), _hash_assignment(g.num_vertices, 4),
            4, g.num_vertices, features=g.features, labels=g.labels,
            train_mask=g.train_mask)

    g_small = er_graph(256, avg_degree=4, seed=0)
    g_big = er_graph(1024, avg_degree=4, seed=1)  # ~4x the edges
    assert g_big.num_edges > 3 * g_small.num_edges
    chunk = 128
    lay_s, lay_b = build(g_small, chunk), build(g_big, chunk)
    # transient ingest state is per-chunk: |E| must not show up in it
    assert lay_b.peak_transient_bytes == lay_s.peak_transient_bytes, (
        lay_b.peak_transient_bytes, lay_s.peak_transient_bytes)
    # ... while the chunk size does, linearly
    lay_b2 = build(g_big, 4 * chunk)
    assert lay_b2.peak_transient_bytes > 2 * lay_b.peak_transient_bytes
    # the persistent output is the per-device layout, reported separately
    assert lay_b.layout_bytes > lay_b.peak_transient_bytes


def test_stream_order_defines_slots_and_validation():
    """ELL slots fill in stream order per destination; bad inputs raise."""
    from repro.core.graph import from_edges
    from repro.core.partition.streaming import (
        GraphEdgeChunks,
        build_streaming_layout,
    )

    # vertex 3's in-neighbors arrive as 2, 0, 1 (edge-list order) and must
    # land in slots 0, 1, 2 of its row regardless of chunking
    src = np.array([2, 0, 1, 0], np.int64)
    dst = np.array([3, 3, 3, 1], np.int64)
    g = from_edges(src, dst, 4)
    assign = np.array([0, 0, 1, 1], np.int32)
    for chunk in (1, 2, 10):
        lay = build_streaming_layout(
            GraphEdgeChunks(g, chunk), assign, 2, 4,
            features=np.zeros((4, 2), np.float32),
            labels=np.zeros(4, np.int32))
        row = lay.ids[lay.new_of_old[3]]
        np.testing.assert_array_equal(
            row[:3], lay.new_of_old[np.array([2, 0, 1])])
        assert lay.bmask[lay.new_of_old[0]]  # 0 (part 0) feeds 3 (part 1)
        assert not lay.bmask[lay.new_of_old[2]]  # 2 -> 3 stays on part 1

    with pytest.raises(ValueError, match="chunk_edges"):
        GraphEdgeChunks(g, 0)
    with pytest.raises(ValueError, match="assignment"):
        build_streaming_layout(GraphEdgeChunks(g, 2), assign[:2], 2, 4,
                               features=np.zeros((4, 2), np.float32),
                               labels=np.zeros(4, np.int32))
