"""End-to-end GNN training (the survey's pipeline, Fig. 2): sync/full-graph,
bounded-staleness async, mini-batch with cache, LLCG vs PSGD-PA."""
import numpy as np
import pytest

from repro.core import full_graph_train, llcg_train, minibatch_train, sbm_graph


@pytest.fixture(scope="module")
def g():
    return sbm_graph(200, num_blocks=4, p_in=0.08, p_out=0.005, seed=1)


def test_sync_full_graph_converges(g):
    r = full_graph_train(g, epochs=50)
    assert r.losses[-1] < r.losses[0] * 0.7
    assert r.test_acc > 0.5


@pytest.mark.parametrize("protocol,kw", [
    ("epoch_fixed", dict(staleness=2)),
    ("epoch_adaptive", dict(staleness=3)),
    ("variation", dict(eps_v=0.05)),
])
def test_bounded_staleness_matches_sync_accuracy(g, protocol, kw):
    """The PipeGCN/SANCUS claim: bounded staleness converges to ~sync accuracy
    while pushing fewer bytes than an every-epoch broadcast."""
    sync = full_graph_train(g, epochs=50)
    r = full_graph_train(g, protocol=protocol, epochs=50, **kw)
    assert r.losses[-1] < r.losses[0] * 0.8
    assert r.test_acc > sync.test_acc - 0.12
    assert r.bytes_pushed > 0


def test_pipegcn_matches_sync_accuracy(g):
    """PipeGCN (Table 3): staleness-1 embeddings AND gradients converge to
    ~sync accuracy (custom-vjp stale-gradient injection + warm-up epoch)."""
    sync = full_graph_train(g, epochs=60, lr=0.3)
    r = full_graph_train(g, protocol="pipegcn", epochs=60, lr=0.3)
    assert r.losses[-1] < r.losses[1] * 0.9
    assert r.test_acc > sync.test_acc - 0.12
    assert r.bytes_pushed > 0


def test_adaptive_pushes_fewer_bytes_than_fixed(g):
    fixed = full_graph_train(g, protocol="epoch_fixed", staleness=2, epochs=30)
    adaptive = full_graph_train(g, protocol="epoch_adaptive", staleness=2, epochs=30)
    assert adaptive.bytes_pushed <= fixed.bytes_pushed


def test_minibatch_training_learns(g):
    r = minibatch_train(g, epochs=3, cache_capacity=60)
    assert r.losses[-1] < r.losses[0]
    assert r.cache_hit_ratio > 0.05


def test_llcg_global_correction_helps(g):
    """§5.2: LLCG's periodic global correction should not hurt, and PSGD-PA
    (no correction) loses the cross-partition signal."""
    llcg = llcg_train(g, rounds=12, local_steps=3, seed=0, lr=0.3)
    assert llcg.losses[-1] < llcg.losses[0]
    assert llcg.test_acc >= 0.5
    # expansion restores boundary context
    exp = llcg_train(g, rounds=6, local_steps=2, expand_hops=1, seed=0, lr=0.3)
    assert exp.test_acc >= 0.4


@pytest.mark.parametrize("model", ["gcn", "sage", "gat", "gin"])
def test_all_gnn_models_train(model, g):
    r = full_graph_train(g, model=model, epochs=30, lr=0.2)
    assert np.isfinite(r.losses[-1])
    assert r.losses[-1] < r.losses[0]


def test_gat_isolated_vertex_self_fallback():
    """Regression (ISSUE 5 satellite): a dense-GAT row whose neighbors are
    ALL masked used to emit zeros after `att = where(mask, att, 0)`; the
    padded-engine contract promises the self-loop fallback Hw_dst instead —
    and this dense path is the oracle the distributed GAT path is checked
    against."""
    import jax
    import jax.numpy as jnp

    from repro.core.models.gnn import gnn_layer, init_gnn_params

    rng = np.random.default_rng(3)
    n = 5
    A = np.zeros((n, n), np.float32)
    A[:3, :3] = rng.random((3, 3)) + 0.1  # rows 3, 4 are isolated
    H = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    p = init_gnn_params("gat", [4, 3], jax.random.PRNGKey(0))["layers"][0]
    out = gnn_layer("gat", p, jnp.asarray(A), H, last=True)
    want_iso = np.asarray(H @ p["w"])[3:]
    assert np.allclose(np.asarray(out[3:]), want_iso, atol=1e-6), (
        "isolated rows must fall back to Hw_dst (self-loop), got "
        f"{np.asarray(out[3:])}")
    # connected rows attend over their neighbors, not the fallback
    assert not np.allclose(np.asarray(out[:3]), np.asarray(H @ p["w"])[:3])
    # gradients stay finite through the fallback (the -1e30 mask trick must
    # not leak NaNs into the isolated rows' backward pass)
    def loss(h):
        return (gnn_layer("gat", p, jnp.asarray(A), h, last=True) ** 2).sum()
    assert np.isfinite(np.asarray(jax.grad(loss)(H))).all()
