"""Samplers, distributed sampling protocols, and cache policies (survey §5)."""
import numpy as np
import pytest

from repro.core.graph import powerlaw_graph
from repro.core.partition import PARTITIONERS
from repro.core.sampling import (
    FIFOCache,
    analysis_cache,
    csp_sample,
    importance_cache,
    layer_wise_sample,
    node_wise_sample,
    presampling_cache,
    proximity_ordering,
    pull_based_sample,
    simulate_hit_ratio,
    skewed_weighted_sample,
    static_degree_cache,
    subgraph_sample,
)


@pytest.fixture(scope="module")
def g():
    return powerlaw_graph(300, avg_degree=10, seed=3)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def test_node_wise_sample_structure(g, rng):
    targets = np.arange(16)
    mb = node_wise_sample(g, targets, (4, 4), rng)
    assert len(mb.layer_adj) == 2
    # rows of last block == targets
    assert mb.layer_adj[-1].shape[0] == len(targets)
    # block shapes chain: cols of layer l == rows count source frontier
    for l in range(2):
        assert mb.layer_adj[l].shape == (len(mb.layer_vertices[l + 1]),
                                         len(mb.layer_vertices[l]))
    # row normalization
    for A in mb.layer_adj:
        assert (A.sum(1) <= 1.0 + 1e-5).all()
    assert mb.input_features.shape[0] == mb.num_input_vertices


def test_fanout_bounds_frontier_growth(g, rng):
    mb = node_wise_sample(g, np.arange(8), (3, 3), rng)
    # frontier growth bounded by fanout+1 per hop
    assert len(mb.layer_vertices[1]) <= 8 * (3 + 1)
    assert len(mb.layer_vertices[0]) <= len(mb.layer_vertices[1]) * (3 + 1)


def test_layer_wise_and_subgraph_samplers(g, rng):
    mb = layer_wise_sample(g, np.arange(8), (32, 32), rng)
    assert len(mb.layer_adj) == 2
    mb2 = subgraph_sample(g, np.arange(4), walk_length=8, rng=rng)
    assert mb2.layer_adj[0].shape[0] == mb2.layer_adj[0].shape[1]


def test_csp_beats_pull_on_communication(g, rng):
    """DSP's claim: pushing the sampling task moves less data than pulling
    full neighbor lists (power-law graphs: deg >> fanout)."""
    part = PARTITIONERS["hash"](g, 4)
    targets = np.arange(64)
    _, pull = pull_based_sample(g, part, 0, targets, fanout=3, rng=rng)
    _, push = csp_sample(g, part, 0, targets, fanout=3, rng=rng)
    assert push.total() < pull.total()


def test_skewed_sampling_locality_increases_with_s(g, rng):
    part = PARTITIONERS["hash"](g, 4)
    targets = np.arange(64)
    _, _, loc1 = skewed_weighted_sample(g, part, 0, targets, 4, s=1.0,
                                        rng=np.random.default_rng(1))
    _, _, loc4 = skewed_weighted_sample(g, part, 0, targets, 4, s=8.0,
                                        rng=np.random.default_rng(1))
    assert loc4 > loc1


def _access_stream(g, n_batches=20, seed=0):
    rng = np.random.default_rng(seed)
    train = np.where(g.train_mask)[0]
    for _ in range(n_batches):
        batch = rng.choice(train, 16, replace=False)
        mb = node_wise_sample(g, batch, (4, 4), rng)
        yield mb.layer_vertices[0]


def test_cache_policies_beat_random(g):
    cap = 60
    rng = np.random.default_rng(9)
    random_ids = rng.choice(g.num_vertices, cap, replace=False)
    hr_rand = simulate_hit_ratio(random_ids, _access_stream(g))
    hr_deg = simulate_hit_ratio(static_degree_cache(g, cap), _access_stream(g))
    hr_pre = simulate_hit_ratio(presampling_cache(g, cap), _access_stream(g))
    hr_ana = simulate_hit_ratio(analysis_cache(g, cap), _access_stream(g))
    assert hr_deg > hr_rand
    assert hr_pre >= hr_deg - 0.05  # pre-sampling ~ at least degree-level
    assert hr_ana > hr_rand


def test_engine_cache_policies_beat_random_on_powerlaw():
    """The two policies the DistGNNEngine exposes as its resident feature
    cache (static_degree, presampling) must beat a random cache of the same
    capacity on a power-law graph — on the degree-skewed workloads where
    caching matters, across several random baselines."""
    gpl = powerlaw_graph(400, avg_degree=12, seed=7)
    cap = 50
    hr_deg = simulate_hit_ratio(static_degree_cache(gpl, cap),
                                _access_stream(gpl, seed=5))
    hr_pre = simulate_hit_ratio(presampling_cache(gpl, cap),
                                _access_stream(gpl, seed=5))
    for rseed in range(3):
        rand_ids = np.random.default_rng(rseed).choice(
            gpl.num_vertices, cap, replace=False)
        hr_rand = simulate_hit_ratio(rand_ids, _access_stream(gpl, seed=5))
        assert hr_deg > hr_rand, (hr_deg, hr_rand, rseed)
        assert hr_pre > hr_rand, (hr_pre, hr_rand, rseed)


def test_fifo_eviction_order():
    """BGL FIFO semantics: first-in is evicted first, a hit does NOT refresh
    recency (FIFO, not LRU), and re-inserting after eviction misses."""
    fifo = FIFOCache(capacity=2)
    assert fifo.access(1) is False  # [1]
    assert fifo.access(2) is False  # [1, 2]
    assert fifo.access(1) is True   # hit; order unchanged (FIFO)
    assert fifo.access(3) is False  # evicts 1 (first in) -> [2, 3]
    assert fifo.access(2) is True   # 2 survived: the hit didn't reorder
    assert fifo.access(1) is False  # 1 was evicted; re-inserting evicts 2
    assert fifo.access(3) is True   # [1, 3] -> 3 still resident
    assert fifo.access(2) is False  # 2 went out when 1 came back


def test_importance_cache_nonempty(g):
    ids = importance_cache(g, 40)
    assert len(ids) == 40 and len(set(ids.tolist())) == 40


def test_fifo_with_proximity_ordering(g):
    train = np.where(g.train_mask)[0]
    order = proximity_ordering(g, train, seed=0)
    assert sorted(order.tolist()) == sorted(train.tolist())
    fifo = FIFOCache(capacity=80)
    rng = np.random.default_rng(0)
    stream = []
    for i in range(0, len(order) - 16, 16):
        mb = node_wise_sample(g, order[i : i + 16], (4, 4), rng)
        stream.append(mb.layer_vertices[0])
    hr_bfs = fifo.run(stream)
    # random ordering for comparison
    fifo2 = FIFOCache(capacity=80)
    perm = np.random.default_rng(1).permutation(train)
    stream2 = []
    for i in range(0, len(perm) - 16, 16):
        mb = node_wise_sample(g, perm[i : i + 16], (4, 4), rng)
        stream2.append(mb.layer_vertices[0])
    hr_rand = fifo2.run(stream2)
    assert hr_bfs >= hr_rand - 0.05  # BGL claim: proximity ordering helps FIFO


# -- edge cases and the cache-as-store-overlay contract ---------------------

def test_simulate_hit_ratio_empty_stream():
    """No accesses -> 0.0, not a ZeroDivisionError; an empty cache over a
    real stream is all misses."""
    assert simulate_hit_ratio(np.array([1, 2]), []) == 0.0
    assert simulate_hit_ratio(np.zeros(0, np.int64),
                              [np.array([1, 2, 3])]) == 0.0


def test_fifo_capacity_zero_all_misses():
    """capacity=0 must behave as 'nothing is ever resident' — the old code
    raised KeyError popping from an empty OrderedDict on the first miss."""
    fifo = FIFOCache(capacity=0)
    assert fifo.access(7) is False
    assert fifo.access(7) is False  # still a miss: nothing was admitted
    assert fifo.run([np.array([1, 1, 2, 2])]) == 0.0


def test_device_cache_ids_capacity_exceeds_remote_count(g):
    """Asking for more cached rows than remote vertices exist returns all
    remote vertices (no padding, no local rows, no duplicates)."""
    from repro.core.sampling.cache import device_cache_ids

    part = PARTITIONERS["hash"](g, 4)
    n_remote = int((part.assignment != 0).sum())
    ids = device_cache_ids(g, part.assignment, 0, "static_degree",
                           capacity=g.num_vertices * 2)
    assert len(ids) == n_remote
    assert len(set(ids.tolist())) == len(ids)
    assert not np.any(part.assignment[ids] == 0)
    # capacity 0 / policy none: empty, never an error
    assert len(device_cache_ids(g, part.assignment, 0, "static_degree", 0)) == 0
    assert len(device_cache_ids(g, part.assignment, 0, "none", 8)) == 0


def test_cache_is_store_overlay_consistent(g):
    """The mini-batch cache as a FeatureStore overlay: the overlay snapshot
    equals row-by-row lookups of the pinned ids; after owner rows are
    UPDATED the snapshot is stale until refresh_overlay, then bitwise exact
    again — the staleness trainable-feature engines must (and do) handle
    with the in-step refresh."""
    from repro.core.feature_store import FeatureStore
    from repro.core.sampling.cache import device_cache_ids

    k = 4
    part = PARTITIONERS["hash"](g, k)
    V = g.num_vertices
    nb = -(-V // k)
    # store-id relabel: device d owns slots [d*nb, (d+1)*nb)
    sid_of = np.zeros(V, np.int64)
    for d in range(k):
        mine = np.where(part.assignment == d)[0]
        sid_of[mine] = d * nb + np.arange(len(mine))
    flat = np.zeros((k * nb, g.features.shape[1]), np.float32)
    flat[sid_of] = g.features
    store = FeatureStore.from_flat(flat, k)
    cap = 12
    overlay = [sid_of[device_cache_ids(g, part.assignment, d,
                                       "static_degree", cap)]
               for d in range(k)]
    store.attach_overlay(overlay, cap)
    tab = store.overlay_table()
    for d in range(k):
        assert np.array_equal(tab[d, : len(overlay[d])],
                              store.lookup(overlay[d]))
        assert np.all(tab[d, len(overlay[d]):] == 0)
    # update every device-0-pinned row, as a training step would
    new = store.lookup(overlay[0]) + 1.5
    store.update_rows(overlay[0], new)
    assert not np.array_equal(store.overlay_table()[0, : len(overlay[0])],
                              new)  # snapshot is stale
    store.refresh_overlay()
    assert np.array_equal(store.overlay_table()[0, : len(overlay[0])], new)


# -- ISSUE 7 bugfix regressions: analysis propagation + proximity restarts --

def test_analysis_propagation_mass_conserved(g):
    """SALIENT++ propagation is a probability flow: each hop ships at most
    the previous hop's mass (scale <= 1, the per-neighbor split sums to one).
    The pre-fix update cancelled the /len(nb) split, handing EVERY neighbor
    the full p[v]*scale[v] — hop mass then multiplied by the degree and this
    assertion fails on any graph with a vertex of degree > 1."""
    from repro.core.sampling.cache import analysis_propagation

    total, per_hop = analysis_propagation(g, fanouts=(5, 5))
    prev = 1.0  # p_0 is uniform over the train set: mass exactly 1
    for h, p in enumerate(per_hop):
        assert p.sum() <= prev + 1e-9, (h, p.sum(), prev)
        prev = p.sum()
    assert np.all(total >= 0)


def test_analysis_cache_parallel_edges_hub_outranks_leaf():
    """Parallel edges (duplicate neighbor entries) must ACCUMULATE: a hub a
    trainer reaches over two parallel edges collects twice the leaf's mass.
    The pre-fix fancy-index `+=` silently dropped the duplicate write, tying
    hub and leaf — np.add.at keeps the strict inequality."""
    from repro.core.graph import Graph
    from repro.core.sampling.cache import analysis_propagation

    # trainer 0 -> in-neighbors [hub, hub, leaf, filler]; sinks have no edges
    indptr = np.asarray([0, 4, 4, 4, 4], np.int64)
    indices = np.asarray([1, 1, 2, 3], np.int32)
    g = Graph(indptr=indptr, indices=indices, num_vertices=4,
              features=np.zeros((4, 2), np.float32),
              labels=np.zeros(4, np.int32),
              train_mask=np.asarray([True, False, False, False]))
    total, _ = analysis_propagation(g, fanouts=(5,))
    hub, leaf = total[1], total[2]
    assert hub > leaf, (hub, leaf)
    assert np.isclose(hub, 2 * leaf), (hub, leaf)


def test_proximity_ordering_many_components_linear_time():
    """A graph of thousands of isolated train vertices is all restarts: every
    train vertex must be emitted exactly once, in linear-ish time.  The
    pre-fix restart rebuilt `set(order)` per component — quadratic, blowing
    this budget by an order of magnitude."""
    import time

    from repro.core.graph import Graph

    V = 6000
    g = Graph(indptr=np.zeros(V + 1, np.int64),
              indices=np.zeros(0, np.int32), num_vertices=V,
              features=np.zeros((V, 2), np.float32),
              labels=np.zeros(V, np.int32),
              train_mask=np.ones(V, bool))
    train = np.arange(V)
    t0 = time.perf_counter()
    order = proximity_ordering(g, train, seed=0)
    wall = time.perf_counter() - t0
    assert sorted(order.tolist()) == list(range(V))
    assert wall < 3.0, f"restart path took {wall:.2f}s for {V} components"
