"""DistGNNEngine mini-batch tier (subprocess, forced host devices): every
sampler x execution model x cache configuration must match the single-device
`reference_minibatch_step` oracle to <=1e-4 — the oracle consumes the EXACT
same sampled, padded batches (host sampling is deterministic in
(seed, step, device)), so partition-block target draws, static padding, the
feature-fetch exchange, and the resident cache may not change the math.

Also locked down here: bitwise determinism across runs, the one-compile-per-
fanout-config contract (recompile-count guard), and the agreement between the
engine's reported feature bytes and the standalone
`feature_fetch_bytes` / `CommStats` cost model.
"""
import pytest

from conftest import run_with_devices

_MATRIX_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for batching, exe in itertools.product({batchings}, {execs}):
        cfg = EngineConfig(
            execution=exe, batching=batching, batch_size=8,
            fanouts=(3, 3), layer_sizes=(16, 16), walk_length=3,
            hidden=16, lr=0.3,
            cache_policy={cache_policy!r}, cache_capacity={cache_capacity})
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        tag = f"{{batching}}/{{exe}}/cache={{cfg.cache_policy}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}}")
        if not (err <= 1e-4 and lerr <= 1e-4 and np.isfinite(losses_d[-1])):
            fails.append((tag, err, lerr))
    assert not fails, fails
    print("MB_MATRIX_OK")
"""


def test_minibatch_matrix_4dev_nocache():
    """All samplers x all execution models, no cache, 4 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=96, epochs=3,
        batchings=("node_wise", "layer_wise", "subgraph"),
        execs=("broadcast", "ring", "p2p"),
        cache_policy="none", cache_capacity=0,
    ), n_devices=4, timeout=600)
    assert "MB_MATRIX_OK" in out


def test_minibatch_matrix_4dev_cached():
    """All samplers x all execution models with the static-degree resident
    cache: hits must short-circuit the exchange without changing the math."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=96, epochs=3,
        batchings=("node_wise", "layer_wise", "subgraph"),
        execs=("broadcast", "ring", "p2p"),
        cache_policy="static_degree", cache_capacity=12,
    ), n_devices=4, timeout=600)
    assert "MB_MATRIX_OK" in out


def test_minibatch_matrix_8dev():
    """Execution models x {node_wise, subgraph}, cache on, 8 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=128, epochs=3,
        batchings=("node_wise", "subgraph"),
        execs=("broadcast", "ring", "p2p"),
        cache_policy="static_degree", cache_capacity=12,
    ), n_devices=8, timeout=600)
    assert "MB_MATRIX_OK" in out


def test_minibatch_determinism_and_recompile_4dev():
    """Same seed -> bitwise-identical losses (host sampling is part of the
    SPMD contract), and the jitted step compiles EXACTLY once across steps
    with fixed fanouts (static padding caps)."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        cfg = EngineConfig(execution="p2p", batching="node_wise",
                           batch_size=8, fanouts=(3, 3), hidden=16, lr=0.3,
                           cache_policy="static_degree", cache_capacity=12)
        eng = DistGNNEngine(g, cfg=cfg)
        l1, _ = eng.train(5)
        n_compiles = eng._jit_mb_step._cache_size()
        assert n_compiles == 1, f"expected 1 compile, got {n_compiles}"
        l2, _ = eng.train(5)
        assert l1 == l2, (l1, l2)
        assert eng._jit_mb_step._cache_size() == 1
        eng2 = DistGNNEngine(g, cfg=cfg)
        l3, _ = eng2.train(5)
        assert l1 == l3, (l1, l3)
        print("MB_DET_OK", l1[-1])
    """, n_devices=4)
    assert "MB_DET_OK" in out


def test_minibatch_comm_stats_cross_check_4dev():
    """Engine-reported feature bytes == the standalone feature_fetch_bytes
    cost model over the same deterministic frontiers; the cache strictly
    reduces wire bytes while total requested bytes stay identical."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph
        from repro.core.sampling import CommStats, feature_fetch_bytes

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        cfg = EngineConfig(execution="p2p", batching="node_wise",
                           batch_size=8, fanouts=(3, 3), hidden=16, lr=0.3,
                           cache_policy="static_degree", cache_capacity=12)
        eng = DistGNNEngine(g, cfg=cfg)
        eng.train(4)
        stats = eng.comm_stats
        # recompute from a FRESH engine: deterministic sampling means the
        # standalone cost model must reproduce the engine's accounting
        eng2 = DistGNNEngine(g, cfg=cfg)
        expected = CommStats()
        D = g.features.shape[1]
        for i in range(4):
            for d, mb in enumerate(eng2._sample_host(i)):
                feature_fetch_bytes(
                    eng2.part, d, mb.layer_vertices[0], D,
                    cached_ids=set(int(v) for v in eng2.cache_old_ids[d]),
                    stats=expected)
        assert stats.pull_bytes == expected.pull_bytes, (stats, expected)
        assert stats.cache_hit_bytes == expected.cache_hit_bytes
        assert stats.cache_hit_bytes > 0, "cache never hit on a power-law graph"
        # cache off: same requested bytes, strictly more on the wire
        cfg0 = EngineConfig(execution="p2p", batching="node_wise",
                            batch_size=8, fanouts=(3, 3), hidden=16, lr=0.3)
        eng0 = DistGNNEngine(g, cfg=cfg0)
        eng0.train(4)
        assert eng0.comm_stats.cache_hit_bytes == 0
        assert eng0.comm_stats.pull_bytes > stats.pull_bytes
        assert eng0.comm_stats.requested() == stats.requested()
        print("MB_BYTES_OK", stats.pull_bytes, stats.cache_hit_bytes)
    """, n_devices=4)
    assert "MB_BYTES_OK" in out


def test_minibatch_pipeline_schedules_4dev():
    """The §6.1 schedules drive the engine's real sampler / extract / jitted
    train stages and agree on the losses (the schedule only reorders work)."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        cfg = EngineConfig(execution="broadcast", batching="node_wise",
                           batch_size=8, fanouts=(3, 3), hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        ref = None
        for sched in ("conventional", "factored", "operator_parallel"):
            _, losses, times = eng.run_epoch_minibatch(3, schedule=sched)
            assert times.wall > 0 and times.busy() > 0
            if ref is None:
                ref = losses
            else:
                assert losses == ref, (sched, losses, ref)
        print("MB_SCHED_OK", ref)
    """, n_devices=4)
    assert "MB_SCHED_OK" in out


def test_minibatch_rejects_bad_config():
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import er_graph

    g = er_graph(32, avg_degree=4, seed=0)
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(batching="nope"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(batching="node_wise",
                                          protocol="variation"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(cache_policy="nope"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(batching="node_wise", fanouts=(3,)))


def test_p2p_fcap_tight_at_256_parts():
    """ROADMAP follow-up from PR 2: the p2p halo cap derives from the
    MEASURED hops-hop halo instead of the worst case caps[0], so the 256-part
    all_to_all buffer shrinks >10x on the power-law config (host-side plan
    math only — no devices needed)."""
    import numpy as np

    from repro.core.graph import powerlaw_graph
    from repro.core.partition.edge_cut import hash_partition
    from repro.core.sampling.partition_batch import p2p_frontier_halo_cap
    from repro.core.sampling.samplers import frontier_caps

    g = powerlaw_graph(4096, avg_degree=8, seed=0)
    part = hash_partition(g, 256)
    caps = frontier_caps("node_wise", 2, 1024, fanouts=(4, 4),
                         num_vertices=g.num_vertices)
    fcap = p2p_frontier_halo_cap(g, part, 2, caps[0])
    assert caps[0] / fcap > 10, (caps[0], fcap)
    # the cap stays a TRUE upper bound: it can never be smaller than the
    # largest single-owner 2-hop halo share, which bounds any sampled batch
    owned = np.bincount(part.assignment, minlength=256)
    assert fcap <= owned.max()


def test_p2p_fcap_is_safe_upper_bound_4dev():
    """Engine-level: the tightened fcap never overflows across many sampled
    batches (the overflow assert in _make_batch stays silent) and the
    exchange still matches the oracle."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        for batching, kw in (("node_wise", dict(fanouts=(4, 4))),
                             ("subgraph", dict(walk_length=4))):
            eng = DistGNNEngine(g, cfg=EngineConfig(
                execution="p2p", batching=batching, batch_size=12,
                hidden=16, lr=0.3, **kw))
            assert eng.fcap <= eng.caps[0]
            for i in range(6):
                eng.sample_minibatch(i)  # would assert on overflow
            ld, _ = eng.train(3)
            lr_, _ = eng.train(3, reference=True)
            err = max(abs(a - b) for a, b in zip(ld, lr_))
            assert err <= 1e-4, (batching, err)
            print(f"{batching}: fcap={eng.fcap} caps0={eng.caps[0]} "
                  f"err={err:.2e}")
        print("FCAP_SAFE_OK")
    """, n_devices=4)
    assert "FCAP_SAFE_OK" in out


def test_minibatch_single_device_paths_agree():
    """On one device the distributed mini-batch step IS the oracle."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.01, seed=1)
    mesh = jax.make_mesh((1,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
        execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
        hidden=16, lr=0.3, cache_policy="static_degree", cache_capacity=8))
    ld, _ = eng.train(8)
    lr_, _ = eng.train(8, reference=True)
    assert max(abs(a - b) for a, b in zip(ld, lr_)) < 1e-4
    assert min(ld) < ld[0]  # it learns
