"""Multi-device tests (subprocess with forced host devices): distributed SpMM
vs oracle, MoE expert-parallel vs reference path, sharded train step, and
flash-decode with a sequence-sharded cache."""
import pytest

from conftest import run_with_devices


def test_spmm_models_match_oracle_8dev():
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.graph import er_graph
        from repro.core.execution.spmm_models import (spmm_replicated,
            spmm_1d_broadcast, spmm_1d_ring, spmm_1d_p2p, spmm_2d_summa,
            spmm_15d, p2p_plan)
        g = er_graph(64, avg_degree=6, seed=3)
        A_np = g.to_dense_adj()
        H_np = np.random.default_rng(0).standard_normal((64, 16)).astype(np.float32)
        ref = A_np @ H_np
        A, H = jnp.asarray(A_np), jnp.asarray(H_np)
        m1 = jax.make_mesh((8,), ("w",))
        m2 = jax.make_mesh((4, 2), ("r", "c"))
        for name, fn, mesh in [("replicated", spmm_replicated, m1),
                               ("1d", spmm_1d_broadcast, m1),
                               ("ring", spmm_1d_ring, m1),
                               ("2d", spmm_2d_summa, m2),
                               ("15d", spmm_15d, m2)]:
            err = float(np.abs(np.asarray(fn(mesh, A, H)) - ref).max())
            assert err < 1e-4, (name, err)
        plan = p2p_plan(A_np, 8)
        err = float(np.abs(np.asarray(spmm_1d_p2p(m1, A, H, plan)) - ref).max())
        assert err < 1e-4, ("p2p", err)
        print("SPMM_OK")
    """)
    assert "SPMM_OK" in out


def test_moe_expert_parallel_matches_reference_4dev():
    out = run_with_devices("""
        import dataclasses
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_apply, moe_params, _moe_reference
        from repro.models.layers import ParamBuilder
        from repro.launch.sharding import make_rules, use_rules
        cfg = get_smoke_config("kimi-k2-1t-a32b")
        cfg = dataclasses.replace(cfg, capacity_factor=8.0, dtype="float32",
                                  moe_dispatch_chunk=32)
        p = moe_params(ParamBuilder("init", jax.random.PRNGKey(0)), cfg)
        x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 16, cfg.d_model)) * 0.1,
                        jnp.float32)
        y_ref, aux_ref = _moe_reference(p, x, cfg)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = make_rules(cfg, mesh)
        with use_rules(mesh, rules):
            y_ep, aux_ep = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
        err = float(jnp.abs(y_ep - y_ref).max())
        rel = err / float(jnp.abs(y_ref).max())
        assert rel < 2e-2, (err, rel)
        assert abs(float(aux_ep) - float(aux_ref)) < 0.15
        print("MOE_OK", err)
    """, n_devices=4)
    assert "MOE_OK" in out


def test_sharded_train_step_runs_8dev():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.launch.train import (default_optimizer, init_train_state,
                                        make_sharded_train_step)
        from repro.data.pipeline import make_batch
        cfg = get_smoke_config("llama3.2-1b")
        shape = ShapeConfig("tiny_train", 64, 8, "train")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = default_optimizer(cfg)
        step, state_sh, batch_sh, rules = make_sharded_train_step(cfg, opt, mesh, shape)
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        state = jax.device_put(state, state_sh)
        batch = jax.device_put(make_batch(cfg, shape), batch_sh)
        losses = []
        for i in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0]  # same batch -> must descend
        print("TRAIN_OK", losses)
    """)
    assert "TRAIN_OK" in out


def test_flash_decode_seq_sharded_cache_8dev():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.models.layers import decode_attention, flash_decode_sharded
        mesh = jax.make_mesh((8,), ("data",))
        B, H, T, D = 1, 4, 64, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
        want = decode_attention(q, k, v, 50)
        from repro.compat import shard_map
        fn = shard_map(partial(flash_decode_sharded, axis="data"),
                       mesh=mesh,
                       in_specs=(P(), P(None, "data", None, None),
                                 P(None, "data", None, None), P()),
                       out_specs=P(), check_vma=False)
        got = fn(q, k, v, jnp.int32(50))
        err = float(jnp.abs(got - want).max())
        assert err < 1e-4, err
        print("DECODE_OK", err)
    """)
    assert "DECODE_OK" in out


def test_dryrun_entrypoint_small_arch():
    """The actual deliverable-e entrypoint, end to end, for one pair."""
    import os
    import subprocess
    import sys

    from conftest import REPO, SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "decode_32k", "--mesh", "single", "--out", "/tmp/dryrun_pytest"],
        capture_output=True, text=True, timeout=400, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_manual_tp_block_matches_plain_4dev():
    """mtp (Megatron-SP manual collectives) must be numerically identical to
    the plain path."""
    out = run_with_devices("""
        import dataclasses
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import transformer as T
        from repro.launch.sharding import make_rules, use_rules
        cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), dtype="float32",
                                  num_heads=8, num_kv_heads=2, head_dim=16)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 16
        batch = {"tokens": jnp.ones((B,S), jnp.int32),
                 "labels": jnp.zeros((B,S), jnp.int32),
                 "positions": jnp.broadcast_to(jnp.arange(S)[None], (B,S))}
        loss_plain, _ = T.loss_fn(cfg, params, batch)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = make_rules(cfg, mesh, {"act_res_seq": "model", "_manual_tp": True})
        with use_rules(mesh, rules):
            loss_tp, _ = jax.jit(lambda p, b: T.loss_fn(cfg, p, b))(params, batch)
        err = abs(float(loss_tp) - float(loss_plain))
        assert err < 2e-4, (float(loss_tp), float(loss_plain))
        print("MTP_OK", err)
    """, n_devices=4)
    assert "MTP_OK" in out


def test_moe_dedup_and_2d_decode_match_reference_4dev():
    out = run_with_devices("""
        import dataclasses
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_apply, moe_params, _moe_reference
        from repro.models.layers import ParamBuilder
        from repro.launch.sharding import make_rules, use_rules
        base = dataclasses.replace(get_smoke_config("kimi-k2-1t-a32b"),
                                   capacity_factor=8.0, dtype="float32",
                                   moe_dispatch_chunk=16)
        p = moe_params(ParamBuilder("init", jax.random.PRNGKey(0)), base)
        x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8, base.d_model)) * 0.1,
                        jnp.float32)
        y_ref, _ = _moe_reference(p, x, base)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        # dedup dispatch, full groups (math-identical)
        cfg = dataclasses.replace(base, moe_group_limit=2)
        with use_rules(mesh, make_rules(cfg, mesh)):
            y1, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
        r1 = float(jnp.abs(y1 - y_ref).max()) / float(jnp.abs(y_ref).max())
        assert r1 < 2e-2, r1
        # 2D weights-stationary decode layout
        rules = make_rules(base, mesh, {"_moe_2d": True, "expert_embed": None,
                                        "expert_mlp": "data"})
        with use_rules(mesh, rules):
            y2, _ = jax.jit(lambda p, x: moe_apply(p, x, base))(p, x)
        r2 = float(jnp.abs(y2 - y_ref).max()) / float(jnp.abs(y_ref).max())
        assert r2 < 2e-2, r2
        print("MOE_PERF_OK", r1, r2)
    """, n_devices=4)
    assert "MOE_PERF_OK" in out


def test_mla_seqsharded_decode_matches_dense_4dev():
    out = run_with_devices("""
        import dataclasses
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import layers as L
        from repro.launch.sharding import make_rules, use_rules
        cfg = dataclasses.replace(get_smoke_config('deepseek-v2-236b'),
                                  dtype="float32", num_heads=4, head_dim=32)
        p = L.mla_params(L.ParamBuilder("init", jax.random.PRNGKey(1)), cfg)
        B, T = 2, 16
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((B,1,cfg.d_model))*0.1, jnp.float32)
        c = jnp.asarray(rng.standard_normal((B,T,cfg.kv_lora_rank))*0.1, jnp.float32)
        kr = jnp.asarray(rng.standard_normal((B,T,cfg.rope_head_dim))*0.1, jnp.float32)
        pos = jnp.int32(9)
        y_ref, c_ref, kr_ref = L.mla_decode(p, x, c, kr, pos, cfg)
        mesh = jax.make_mesh((2,2), ("data","model"))
        rules = make_rules(cfg, mesh, {"act_kv_seq": ("model",), "kv_lora": None})
        with use_rules(mesh, rules):
            y2, c2, kr2 = jax.jit(lambda *a: L.mla_decode_seqsharded(*a, cfg))(p, x, c, kr, pos)
        assert float(jnp.abs(y2-y_ref).max()) < 1e-4
        assert float(jnp.abs(c2-c_ref).max()) < 1e-5
        print("MLA_FD_OK")
    """, n_devices=4)
    assert "MLA_FD_OK" in out


def test_dryrun_gnn_production_scale():
    """The paper's own workload (full-graph GCN, 2^20 vertices) lowers and
    compiles on the production mesh."""
    import os
    import subprocess
    import sys

    from conftest import REPO, SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun_gnn", "--out", "/tmp/dryrun_gnn_pytest"],
        capture_output=True, text=True, timeout=400, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert os.path.exists("/tmp/dryrun_gnn_pytest/gcn-paper__fullgraph__pod16x16.json")
