"""Attention + SSM layer math: chunked flash == naive; sliding window; decode
== full-sequence; chunked linear attention == per-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention, decode_attention
from repro.models.ssm import (
    _chunked_linear_attention,
    linear_attention_step,
)

RNG = np.random.default_rng(7)


def _naive_attention(q, k, v, causal=True, window=0):
    S, T = q.shape[1], k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / (q.shape[-1] ** 0.5)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= ki <= qi
    if window:
        m &= qi - ki < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


@pytest.mark.parametrize("S,qc,kc", [(128, 32, 64), (256, 256, 256), (64, 16, 16)])
@pytest.mark.parametrize("window", [0, 48])
def test_chunked_attention_matches_naive(S, qc, kc, window):
    B, H, D = 2, 3, 32
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window, q_chunk=qc, kv_chunk=kc)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_decode_matches_full_attention_last_position():
    B, S, H, D = 2, 48, 4, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    full = _naive_attention(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, S)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("mode", ["mamba", "rwkv"])
def test_chunked_linear_attention_matches_step_recurrence(mode):
    B, S, H, K, V = 1, 64, 2, 8, 8
    q = jnp.asarray(RNG.standard_normal((B, S, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, V)) * 0.5, jnp.float32)
    g = jnp.asarray(-np.abs(RNG.standard_normal((B, S, H, K))) * 0.3, jnp.float32)
    if mode == "mamba":
        g = g[..., :1]
    bonus = jnp.asarray(RNG.standard_normal((H, K)) * 0.1, jnp.float32) if mode == "rwkv" else None
    y_chunk, state_f = _chunked_linear_attention(q, k, v, g, chunk=16, mode=mode,
                                                 bonus=bonus, return_state=True)
    # per-step recurrence
    state = jnp.zeros((B, H, K, V), jnp.float32)
    ys = []
    for t in range(S):
        y, state = linear_attention_step(q[:, t], k[:, t], v[:, t], g[:, t], state,
                                         mode=mode, bonus=bonus)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-3)
    # final states agree too
    np.testing.assert_allclose(np.asarray(state_f), np.asarray(state),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("mode", ["mamba", "rwkv"])
def test_chunk_size_invariance(mode):
    B, S, H, K = 1, 48, 2, 8
    q = jnp.asarray(RNG.standard_normal((B, S, H, K)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, K)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, K)), jnp.float32)
    g = jnp.full((B, S, H, K if mode == "rwkv" else 1), -0.2, jnp.float32)
    a = _chunked_linear_attention(q, k, v, g, chunk=8, mode=mode)
    b = _chunked_linear_attention(q, k, v, g, chunk=24, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)
