"""Serving tier (ISSUE 7): layer-wise full-graph inference + the query engine.

Locks the two serving tiers to their oracles:

* ``DistGNNEngine.infer_full_graph`` — the O(L) layer-wise sweep — matches
  the single-device reference <= 1e-4 for BOTH partition families x all
  three execution models x all four GNN models, on 4 AND 8 forced-host
  devices; bitwise-deterministic across calls; CommStats.inference_bytes
  equals the STANDALONE ``cost_models.inference_bytes_per_sweep`` exactly;
  the sweep compiles once.
* ``GNNQueryEngine`` — the K-target padded-query path — matches the
  single-device reference on the SAME padded round, answers fully
  cache-resident queries with ZERO new wire bytes, coalesces overlapping
  requests (shared targets embedded once), reproduces bitwise across a
  fresh rebuild, and compiles its serve step exactly once.
* serving edge cases: degree-0 (isolated) vertices through the sweep under
  both families and through the sampled query path; live FeatureStore
  updates flowing into the next sweep without a retrace; the
  ``publish_embeddings`` trainable->frozen serving handoff.
"""
from conftest import run_with_devices

# ---------------------------------------------------------------------------
# throughput tier: the layer-wise sweep matrix
# ---------------------------------------------------------------------------

_INFER_MATRIX_CODE = """
import itertools
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.partition.cost_models import inference_bytes_per_sweep
g = sbm_graph(96, num_blocks=4, p_in=0.1, p_out=0.01, seed=0)
fails = []
for family, execution, model in itertools.product(
        ("edge_cut", "vertex_cut"), ("broadcast", "ring", "p2p"),
        ("gcn", "sage", "gat", "gin")):
    cfg = EngineConfig(execution=execution, model=model,
                       partition_family=family, hidden=8, lr=0.3)
    eng = DistGNNEngine(g, cfg=cfg)
    params = eng.init_state()["params"]
    H1 = np.asarray(eng.infer_full_graph(params=params))
    H2 = np.asarray(eng.infer_full_graph(params=params))
    ref = np.asarray(eng.infer_full_graph(params=params, reference=True))
    emb = eng.global_embeddings(H1)
    emb_ref = eng.global_embeddings(ref)
    err = float(np.max(np.abs(emb - emb_ref)))
    kw = (dict(k=eng.k, nv=eng.nv, rep_counts=eng.layout.rep_count)
          if family == "vertex_cut"
          else dict(k=eng.k, nb=eng.nb, g=g, part=eng.part))
    expect = 2 * inference_bytes_per_sweep(execution, eng.dims, model=model,
                                           family=family, **kw)
    ok = (err <= 1e-4 and np.array_equal(H1, H2)
          and eng.comm_stats.inference_bytes == expect
          and eng._jit_infer._cache_size() == 1)
    print(family, execution, model, "err", err,
          "bytes", eng.comm_stats.inference_bytes, "expect", expect,
          "compiles", eng._jit_infer._cache_size(), "OK" if ok else "FAIL")
    if not ok:
        fails.append((family, execution, model, err))
assert not fails, fails
print("INFER_MATRIX_OK")
"""


def test_infer_full_graph_matrix_4dev():
    out = run_with_devices(_INFER_MATRIX_CODE, n_devices=4, timeout=900)
    assert "INFER_MATRIX_OK" in out


def test_infer_full_graph_matrix_8dev():
    out = run_with_devices(_INFER_MATRIX_CODE, n_devices=8, timeout=900)
    assert "INFER_MATRIX_OK" in out


# ---------------------------------------------------------------------------
# edge case: degree-0 (isolated) vertices through the sweep — both families
# ---------------------------------------------------------------------------

def _isolated_graph_code():
    return """
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import Graph
rng = np.random.default_rng(0)
V, RINGV, C = 48, 40, 4
indptr = [0]
indices = []
for v in range(V):
    if v < RINGV:  # directed ring: two in-neighbors each
        indices += [(v - 1) % RINGV, (v + 1) % RINGV]
    # v >= RINGV: isolated — no in-neighbors, never referenced
    indptr.append(len(indices))
g = Graph(indptr=np.asarray(indptr, np.int64),
          indices=np.asarray(indices, np.int32), num_vertices=V,
          features=rng.standard_normal((V, 6)).astype(np.float32),
          labels=rng.integers(0, C, V).astype(np.int32),
          train_mask=rng.random(V) < 0.5)
g.test_mask = ~g.train_mask
"""


def test_infer_degree0_vertices_both_families():
    """Isolated vertices get their self-fallback embedding, identical to the
    reference, under both partition families (gat included: its masked
    segment-softmax must not NaN on an empty neighborhood)."""
    code = _isolated_graph_code() + """
for family in ("edge_cut", "vertex_cut"):
    for model in ("gcn", "gat"):
        eng = DistGNNEngine(g, cfg=EngineConfig(
            execution="p2p", model=model, partition_family=family,
            hidden=8, lr=0.3))
        params = eng.init_state()["params"]
        emb = eng.global_embeddings(eng.infer_full_graph(params=params))
        ref = eng.global_embeddings(
            eng.infer_full_graph(params=params, reference=True))
        assert np.isfinite(emb).all(), (family, model, "non-finite rows")
        err = float(np.max(np.abs(emb - ref)))
        assert err <= 1e-4, (family, model, err)
        print(family, model, "deg0 err", err)
print("DEG0_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "DEG0_OK" in out


# ---------------------------------------------------------------------------
# latency tier: the query engine
# ---------------------------------------------------------------------------

_QUERY_SETUP = """
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine
g = sbm_graph(192, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
def build(cache_capacity=16, cache_policy="static_degree"):
    eng = DistGNNEngine(g, cfg=EngineConfig(
        execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
        hidden=8, lr=0.3, cache_policy=cache_policy,
        cache_capacity=cache_capacity))
    state, _, _ = eng.run_epoch_minibatch(3)
    return eng, state["params"]
"""


def test_query_engine_matches_reference_and_compiles_once():
    """serve_round == reference_round on the SAME padded batch (target
    slots), and repeated queries reuse ONE compile."""
    code = _QUERY_SETUP + """
eng, params = build()
qe = GNNQueryEngine(eng, params)
rng = np.random.default_rng(1)
for trial in range(3):
    targets = rng.choice(g.num_vertices, 12, replace=False)
    per_dev = [[] for _ in range(eng.k)]
    for v in targets:
        per_dev[int(eng.part.assignment[v])].append(int(v))
    round_tgts = [np.asarray(x[:8], np.int64) for x in per_dev]
    batch = qe.build_round(round_tgts)
    H = np.asarray(qe.serve_round(batch))
    R = np.asarray(qe.reference_round(batch))
    for d, tg in enumerate(round_tgts):
        if len(tg):
            err = float(np.max(np.abs(H[d, :len(tg)] - R[d, :len(tg)])))
            assert err <= 1e-4, (trial, d, err)
assert qe.num_compiles() == 1, qe.num_compiles()
# the coalescing front door returns a row per requested target
emb = qe.query([int(targets[0])])
assert emb.shape == (1, H.shape[-1])
assert qe.num_compiles() == 1
print("QUERY_REF_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "QUERY_REF_OK" in out


def test_query_fully_cache_resident_zero_exchange_bytes():
    """With every vertex's features resident (capacity >= V), a query's
    remote frontier rows are all cache hits: zero NEW pull bytes cross the
    wire, and the answers still match the reference."""
    code = _QUERY_SETUP + """
eng, params = build(cache_capacity=g.num_vertices)
qe = GNNQueryEngine(eng, params)
before = eng.comm_stats.pull_bytes
hits_before = eng.comm_stats.cache_hit_bytes
rng = np.random.default_rng(2)
targets = rng.choice(g.num_vertices, 10, replace=False)
per_dev = [[] for _ in range(eng.k)]
for v in targets:
    per_dev[int(eng.part.assignment[v])].append(int(v))
round_tgts = [np.asarray(x[:8], np.int64) for x in per_dev]
batch = qe.build_round(round_tgts)
H = np.asarray(qe.serve_round(batch))
R = np.asarray(qe.reference_round(batch))
for d, tg in enumerate(round_tgts):
    if len(tg):
        assert np.max(np.abs(H[d, :len(tg)] - R[d, :len(tg)])) <= 1e-4
assert eng.comm_stats.pull_bytes == before, (
    "cache-resident query pulled bytes", eng.comm_stats.pull_bytes - before)
assert eng.comm_stats.cache_hit_bytes > hits_before, "no hits recorded"
print("CACHE_RESIDENT_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "CACHE_RESIDENT_OK" in out


def test_query_coalescing_and_determinism():
    """Overlapping submits coalesce: the union is embedded once, every
    request gets its rows back in its own order, round packing respects the
    per-device cap, and a FRESH rebuild reproduces the stream bitwise."""
    code = _QUERY_SETUP + """
def stream(qe):
    r1 = qe.submit([5, 9, 17, 9])       # duplicate inside a request
    r2 = qe.submit([17, 30, 41])        # overlap across requests
    r3 = qe.submit(np.arange(40))       # forces multiple rounds per device
    out = qe.flush()
    return r1, r2, r3, out

eng, params = build()
qe = GNNQueryEngine(eng, params)
r1, r2, r3, out = stream(qe)
assert out[r1].shape[0] == 4 and out[r2].shape[0] == 3
assert np.array_equal(out[r1][1], out[r1][3]), "duplicate target differs"
assert np.array_equal(out[r1][2], out[r2][0]), "shared target re-embedded"
assert qe.stats.queries == 3 and qe.stats.targets == len(set(
    [5, 9, 17, 30, 41] + list(range(40))))
# packing: ceil(max per-device owned share / batch_size) rounds
per_dev = np.bincount(eng.part.assignment[
    np.asarray(sorted(set([5, 9, 17, 30, 41] + list(range(40)))))],
    minlength=eng.k)
assert qe.stats.rounds == int(np.ceil(per_dev.max() / 8)), (
    qe.stats.rounds, per_dev)
assert qe.num_compiles() == 1

eng2, params2 = build()
qe2 = GNNQueryEngine(eng2, params2)
_, _, _, out2 = stream(qe2)
for rid in out:
    assert np.array_equal(out[rid], out2[rid]), "rebuild not deterministic"
print("COALESCE_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "COALESCE_OK" in out


def test_query_engine_rejects_wrong_configs():
    """Constructor contract: node-wise batching only, frozen features only."""
    code = """
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine
g = sbm_graph(96, num_blocks=4, p_in=0.1, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(execution="p2p", hidden=8))
try:
    GNNQueryEngine(eng, eng.init_state()["params"])
    raise SystemExit("full_graph engine accepted")
except ValueError as e:
    assert "node_wise" in str(e)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
    hidden=8, trainable_features=True))
try:
    GNNQueryEngine(eng, eng.init_minibatch_state()["params"])
    raise SystemExit("trainable engine accepted")
except ValueError as e:
    assert "publish_embeddings" in str(e)
print("REJECT_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "REJECT_OK" in out


# ---------------------------------------------------------------------------
# store liveness + the trainable -> frozen serving handoff
# ---------------------------------------------------------------------------

def test_infer_reads_live_store_without_retrace():
    """`store.update_rows` flows into the NEXT sweep (layer-0 is read through
    the FeatureStore, not baked into the compiled step) and changing the rows
    does not retrace."""
    code = """
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
g = sbm_graph(96, num_blocks=4, p_in=0.1, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(execution="broadcast", hidden=8,
                                        lr=0.3))
params = eng.init_state()["params"]
H0 = np.asarray(eng.infer_full_graph(params=params))
rows = np.arange(10)
eng.store.update_rows(rows, np.asarray(eng.store.flat()[rows]) + 1.0)
H1 = np.asarray(eng.infer_full_graph(params=params))
ref1 = np.asarray(eng.infer_full_graph(params=params, reference=True))
assert not np.array_equal(H0, H1), "sweep ignored the store update"
assert float(np.max(np.abs(H1 - ref1))) <= 1e-4
assert eng._jit_infer._cache_size() == 1, "store update retraced the sweep"
print("LIVE_STORE_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "LIVE_STORE_OK" in out


def test_publish_embeddings_handoff():
    """Trainable engine -> publish_embeddings -> a frozen clone on the same
    partition serves the TRAINED table: its sweep equals the trainable
    engine's own (state-fed) sweep."""
    code = """
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
g = sbm_graph(96, num_blocks=4, p_in=0.1, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(execution="p2p", hidden=8, lr=0.3,
                                        trainable_features=True))
step = eng.make_step()
state = eng.init_state()
for _ in range(2):
    state, _, _ = step(state)
eng.publish_embeddings(state)
assert np.allclose(np.asarray(eng.store.flat()),
                   np.asarray(state["embed"]), atol=0), "store != embed"
H_train = np.asarray(eng.infer_full_graph(state))
clone = DistGNNEngine(g, cfg=EngineConfig(execution="p2p", hidden=8, lr=0.3),
                      partition=eng.part)
clone.store.update_rows(np.arange(clone.store.num_rows),
                        np.asarray(eng.store.flat()))
H_serve = np.asarray(clone.infer_full_graph(params=state["params"]))
assert float(np.max(np.abs(H_train - H_serve))) <= 1e-5, "handoff diverged"
print("PUBLISH_OK")
"""
    out = run_with_devices(code, n_devices=4, timeout=600)
    assert "PUBLISH_OK" in out
