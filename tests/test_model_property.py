"""Hypothesis property tier for the GNN model axis (same optional
`property` extra gating as the other hypothesis tiers):

  * padded-row inertness PER MODEL: perturbing pad-slot inputs of a padded
    mini-batch forward never changes any real target row, for every model —
    the static-padding contract the distributed step relies on;
  * GAT masked segment-softmax: attention rows sum to 1 over the real slots
    (and to 0 for rows with no real slots — the self-fallback case), pad
    slots carry zero weight;
  * self-feature locality: sage/gin's model-aware exchange widths equal
    gcn's exactly (zero extra bytes on the wire), while gat's differ by the
    attention-coefficient terms.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.models.gnn import (  # noqa: E402
    init_gnn_params,
    padded_minibatch_forward,
)
from repro.core.partition.cost_models import model_exchange_widths  # noqa: E402

MODELS = ("gcn", "sage", "gat", "gin")


def _padded_batch(rng, n_real, cap, d_in):
    """One padded two-layer batch: real rows first, pad rows zero, every
    real row gets a folded self-loop (the sampler contract)."""
    adj = []
    self_idx = []
    for _ in range(2):
        A = np.zeros((cap, cap), np.float32)
        raw = (rng.random((n_real, n_real)) < 0.4).astype(np.float32)
        raw += np.eye(n_real, dtype=np.float32)  # folded self loop
        A[:n_real, :n_real] = raw / raw.sum(1, keepdims=True)
        adj.append(jnp.asarray(A))
        si = np.zeros(cap, np.int64)
        si[:n_real] = np.arange(n_real)
        self_idx.append(jnp.asarray(si))
    X = np.zeros((cap, d_in), np.float32)
    X[:n_real] = rng.standard_normal((n_real, d_in))
    return adj, self_idx, jnp.asarray(X)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(MODELS), st.integers(2, 6), st.integers(0, 5),
       st.integers(0, 2 ** 31 - 1))
def test_padded_rows_inert_per_model(model, n_real, n_pad, seed):
    rng = np.random.default_rng(seed)
    cap = n_real + n_pad
    adj, self_idx, X = _padded_batch(rng, n_real, cap, d_in=5)
    params = init_gnn_params(model, [5, 4, 3], jax.random.PRNGKey(seed % 97))
    out = padded_minibatch_forward(params, adj, X, model=model,
                                   self_idx=self_idx)
    # perturb ONLY the pad rows' inputs: real rows must not move
    X2 = X.at[n_real:].set(7.5) if n_pad else X
    out2 = padded_minibatch_forward(params, adj, X2, model=model,
                                    self_idx=self_idx)
    assert np.allclose(np.asarray(out[:n_real]), np.asarray(out2[:n_real]),
                       atol=0, rtol=0), model
    assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_gat_softmax_rows_sum_to_one_over_real_slots(V, K, seed):
    """The engine's masked segment-softmax pieces: weights are zero on pad
    slots, sum to 1 over real slots, and to 0 for empty rows (which the
    engine routes to the self fallback)."""
    from repro.core.engine import DistGNNEngine

    rng = np.random.default_rng(seed)
    e = rng.standard_normal((V, K)).astype(np.float32) * 3
    mask = (rng.random((V, K)) < 0.6).astype(np.float32)
    e_masked = jnp.where(mask > 0, jnp.asarray(e), -1e30)
    pw, den = DistGNNEngine._gat_softmax(e_masked)
    att = np.asarray(pw / jnp.maximum(den, 1e-30))
    assert (np.asarray(pw)[mask == 0] == 0).all()
    row_has = mask.sum(1) > 0
    sums = att.sum(1)
    assert np.allclose(sums[row_has], 1.0, atol=1e-5)
    assert np.allclose(sums[~row_has], 0.0, atol=0)
    assert (np.asarray(den)[~row_has] == 0).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 64), st.integers(1, 64),
       st.integers(2, 32))
def test_self_feature_locality_zero_extra_bytes(L, d_in, hidden, classes):
    """sage/gin exchange EXACTLY gcn's widths (self features are resident);
    gat's widths are the transformed width + the coefficient terms."""
    dims = [d_in] + [hidden] * (L - 1) + [classes]
    for family in ("edge_cut", "vertex_cut"):
        base = model_exchange_widths("gcn", dims, family)
        assert model_exchange_widths("sage", dims, family) == base
        assert model_exchange_widths("gin", dims, family) == base
        extra = 2 if family == "vertex_cut" else 1
        gat = model_exchange_widths("gat", dims, family)
        assert gat == [dims[l + 1] + extra for l in range(L)]
