"""Process-pool prefetch failure-mode tier (sampling/proc_prefetch.py).

The GIL-free sampler pool must uphold the thread `PrefetchWorker`'s
contracts across a PROCESS boundary: strict in-order delivery (bitwise
reuse across epochs of one pool), producer exceptions relayed to the
consumer at the batch index they occurred (including BaseException and
unpicklable exceptions), the consumer abandoning mid-epoch never strands a
worker blocked on the full shared-memory ring, the tightest ring
(depth=1, workers > slots) completes in order without deadlock, and
close() always unlinks every shared-memory segment — no /dev/shm litter,
no resource-tracker "leaked shared_memory" warnings at interpreter exit.

Everything here is numpy-only by construction: workers must never import
jax (`host_batch` keeps the producer import chain clean), so this tier
runs without devices.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

from repro.core.sampling.proc_prefetch import (  # noqa: E402
    ProcPrefetchPool,
    ProcPrefetchWorker,
    WorkerFailure,
)

LAYOUT = {"a": ((4,), np.dtype(np.int64)),
          "b": ((2, 3), np.dtype(np.float32))}


def _produce(i):
    return ({"a": np.arange(4, dtype=np.int64) + i,
             "b": np.full((2, 3), float(i), np.float32)},
            {"item": i, "sample_seconds": 0.0, "extract_seconds": 0.0})


def _shm_litter():
    return [f for f in os.listdir("/dev/shm") if f.startswith("repro-")]


def _fail_at(i):
    if i == _fail_at.at:
        raise ValueError(f"boom at {i}")
    return _produce(i)


_fail_at.at = None


def _fail_first(i):
    _fail_at.at = 0
    return _fail_at(i)


def _fail_mid(i):
    _fail_at.at = 3
    return _fail_at(i)


def _fail_last(i):
    _fail_at.at = 5
    return _fail_at(i)


def _fail_base(i):
    if i == 1:
        raise KeyboardInterrupt
    return _produce(i)


class _Unpicklable(Exception):
    def __init__(self):
        super().__init__("cursed")
        self.payload = lambda: None  # lambdas don't pickle


def _fail_unpicklable(i):
    if i == 2:
        raise _Unpicklable()
    return _produce(i)


def test_in_order_delivery_and_pool_reuse():
    """Strict input order, correct slot contents, and the SAME pool serving
    multiple epochs (monotone global indices, shm ring reused).
    cache_items=0 keeps every epoch on the ring — the LRU fast path has its
    own test below."""
    pool = ProcPrefetchPool(_produce, LAYOUT, depth=2, num_workers=3,
                            cache_items=0)
    try:
        for _epoch in range(3):
            out = list(pool.run(list(range(7))))
            assert [o[0] for o in out] == list(range(7))
            for item, arrays, meta in out:
                np.testing.assert_array_equal(
                    arrays["a"], np.arange(4, dtype=np.int64) + item)
                assert arrays["b"][0, 0] == item
                assert meta["item"] == item
                # delivered arrays are COPIES — ring reuse can't alias them
                arrays["a"][:] = -1
    finally:
        pool.close()
    assert not pool.alive
    assert _shm_litter() == []


_SEEN: dict = {}  # per-worker-process memory for _produce_once


def _produce_once(i):
    if i in _SEEN:
        raise RuntimeError(f"resampled item {i}")
    _SEEN[i] = True
    return _produce(i)


def test_finished_batch_cache_skips_workers():
    """Deterministic producers are pure functions of their item, so the
    pool's LRU serves repeat items without touching a worker: a producer
    that FAILS on re-request proves epoch 2 never resampled."""
    pool = ProcPrefetchPool(_produce_once, LAYOUT, depth=2, num_workers=1)
    try:
        out1 = list(pool.run(list(range(5))))
        out2 = list(pool.run(list(range(5))))  # all hits — no worker calls
        for (i1, a1, m1), (i2, a2, m2) in zip(out1, out2):
            assert i1 == i2
            np.testing.assert_array_equal(a1["a"], a2["a"])
            assert m2["cache_hit"] and m2["sample_seconds"] == 0.0
            a2["a"][:] = -7  # hits hand out copies too
        out2b = list(pool.run(list(range(5))))
        assert out2b[0][1]["a"][0] == 0  # mutation did not reach the cache
        # mixed epoch: cached 0/1 around a fresh item — order preserved
        out3 = list(pool.run([0, 6, 1]))
        assert [o[0] for o in out3] == [0, 6, 1]
        np.testing.assert_array_equal(out3[2][1]["a"],
                                      np.arange(4, dtype=np.int64) + 1)
        assert len(pool._cache) <= pool.cache_items
    finally:
        pool.close()
    assert _shm_litter() == []


def test_cache_pinned_hits_survive_eviction():
    """A hit planned at run() start must deliver even if this epoch's own
    misses evict its LRU entry before its turn (cache_items=1)."""
    pool = ProcPrefetchPool(_produce, LAYOUT, depth=1, num_workers=1,
                            cache_items=1)
    try:
        list(pool.run([0, 1]))           # cache = {1}
        out = list(pool.run([1, 0, 1]))  # miss 0 evicts 1 mid-epoch
        assert [o[0] for o in out] == [1, 0, 1]
        np.testing.assert_array_equal(out[2][1]["a"],
                                      np.arange(4, dtype=np.int64) + 1)
    finally:
        pool.close()
    assert _shm_litter() == []


def test_depth1_more_workers_than_slots_no_deadlock():
    """The tightest ring with more workers than slots: the released-counter
    protocol keeps the writer of the next-released index unblocked."""
    pool = ProcPrefetchPool(_produce, LAYOUT, depth=1, num_workers=3)
    try:
        out = list(pool.run(list(range(12))))
        assert [o[0] for o in out] == list(range(12))
    finally:
        pool.close()
    assert _shm_litter() == []


@pytest.mark.parametrize("produce,at,n", [(_fail_first, 0, 5),
                                          (_fail_mid, 3, 6),
                                          (_fail_last, 5, 6)])
def test_exception_relayed_at_batch_index(produce, at, n):
    """A producer exception surfaces in the consumer exactly after the
    preceding batches — first, mid-epoch, and last position."""
    pool = ProcPrefetchPool(produce, LAYOUT, depth=2, num_workers=2)
    got = []
    try:
        with pytest.raises(ValueError, match=f"boom at {at}"):
            for item, arrays, meta in pool.run(list(range(n))):
                got.append(item)
        assert got == list(range(at))
    finally:
        pool.close()
    assert _shm_litter() == []


def test_base_exception_relays():
    """KeyboardInterrupt in a worker must not vanish into the pool."""
    pool = ProcPrefetchPool(_fail_base, LAYOUT, depth=1, num_workers=2)
    try:
        it = pool.run(list(range(3)))
        assert next(it)[0] == 0
        with pytest.raises(KeyboardInterrupt):
            next(it)
    finally:
        pool.close()


def test_unpicklable_exception_becomes_worker_failure():
    """An exception that can't cross the process boundary still relays — as
    a WorkerFailure carrying the remote traceback."""
    pool = ProcPrefetchPool(_fail_unpicklable, LAYOUT, depth=2,
                            num_workers=1)
    try:
        with pytest.raises(WorkerFailure, match="cursed") as ei:
            list(pool.run(list(range(4))))
        assert "remote traceback" in str(ei.value)
    finally:
        pool.close()


def test_consumer_death_unblocks_full_ring_producer():
    """Consumer abandons mid-epoch with workers blocked on the full ring:
    close() must stop, join, and unlink within bounded time."""
    w = ProcPrefetchWorker(list(range(10_000)), _produce, LAYOUT, depth=1,
                           num_workers=2)
    item, arrays, meta = next(iter(w))  # consume one, then abandon
    assert item == 0
    t0 = time.monotonic()
    w.close()
    w.close()  # idempotent
    assert time.monotonic() - t0 < 10.0
    assert not w.alive
    assert _shm_litter() == []


def test_run_iterator_close_resyncs_pool():
    """Abandoning one run() mid-epoch and starting another on the SAME pool:
    the drain must resynchronize the ring so the next epoch is clean."""
    pool = ProcPrefetchPool(_produce, LAYOUT, depth=2, num_workers=2)
    try:
        it = pool.run(list(range(6)))
        assert next(it)[0] == 0
        it.close()  # abandon with 5 outstanding
        out = list(pool.run(list(range(4))))
        assert [o[0] for o in out] == list(range(4))
    finally:
        pool.close()
    assert _shm_litter() == []


def test_validation():
    with pytest.raises(ValueError, match="depth"):
        ProcPrefetchPool(_produce, LAYOUT, depth=0)
    with pytest.raises(ValueError, match="num_sample_workers"):
        ProcPrefetchPool(_produce, LAYOUT, num_workers=0)
    with pytest.raises(ValueError, match="cache_items"):
        ProcPrefetchPool(_produce, LAYOUT, cache_items=-1)
    pool = ProcPrefetchPool(_produce, LAYOUT, depth=1, num_workers=1)
    it = pool.run([0])
    with pytest.raises(RuntimeError, match="one run"):
        pool.run([1])
    list(it)
    pool.close()
    with pytest.raises(RuntimeError, match="closed"):
        pool.run([2])


def test_shared_graph_roundtrip_and_worker_jax_hygiene():
    """share_graph -> materialize reproduces the graph read-only; the
    producer import chain (host_batch + proc_prefetch) stays jax-free."""
    import importlib

    from repro.core.graph import sbm_graph
    from repro.core.sampling.proc_prefetch import share_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.02, seed=0)
    shared, arena = share_graph(g)
    try:
        g2 = shared.materialize()
        np.testing.assert_array_equal(g2.indptr, g.indptr)
        np.testing.assert_array_equal(g2.indices, g.indices)
        np.testing.assert_array_equal(g2.labels, g.labels)
        np.testing.assert_array_equal(g2.train_mask, g.train_mask)
        assert g2.num_vertices == g.num_vertices
        assert not g2.indices.flags.writeable
        del g2
    finally:
        arena.close()
    assert _shm_litter() == []

    # the import-chain contract, in a pristine interpreter
    code = ("import sys\n"
            "import repro.core.sampling.host_batch\n"
            "import repro.core.sampling.proc_prefetch\n"
            "assert 'jax' not in sys.modules, 'jax leaked'\n"
            "print('JAX_FREE')\n")
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=120, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "JAX_FREE" in proc.stdout


def test_no_leaked_shm_warnings_at_interpreter_exit():
    """A full pool lifecycle in a fresh interpreter must exit with clean
    stderr: no resource-tracker 'leaked shared_memory' warnings, no
    KeyErrors from double-unregistration, and an empty /dev/shm."""
    code = """
# produce must live in an importable module: the forkserver/spawn workers
# unpickle it by qualified name (the engine's HostBatchBuilder.produce
# satisfies this by construction)
from test_proc_prefetch import LAYOUT, _produce
from repro.core.sampling.proc_prefetch import ProcPrefetchPool

pool = ProcPrefetchPool(_produce, LAYOUT, depth=2, num_workers=2)
assert [o[0] for o in pool.run(list(range(5)))] == list(range(5))
pool.close()
# second pool reclaimed by GC only — the finalizer must unlink for it
pool2 = ProcPrefetchPool(_produce, LAYOUT, depth=1, num_workers=1)
next(iter(pool2.run(list(range(3)))))
del pool2
print("LIFECYCLE_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=180, env=env)
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "LIFECYCLE_OK" in proc.stdout
    assert "leaked shared_memory" not in proc.stderr, proc.stderr
    assert "KeyError" not in proc.stderr, proc.stderr
    assert _shm_litter() == []
