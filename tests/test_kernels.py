"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ell_spmm import ell_attend, ell_spmm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sddmm import sddmm_ell, sddmm_pallas
from repro.kernels.wkv_chunk import wkv_chunk_pallas

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("V,K,D", [(128, 8, 64), (256, 16, 128), (128, 32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("normalize", [True, False])
def test_ell_spmm(V, K, D, dtype, normalize):
    ids = jnp.asarray(RNG.integers(0, V, (V, K)), jnp.int32)
    mask = jnp.asarray(RNG.random((V, K)) < 0.6, jnp.float32)
    H = jnp.asarray(RNG.standard_normal((V, D)), dtype)
    got = ell_spmm_pallas(ids, mask, H, normalize=normalize, interpret=True)
    want = ref.ell_spmm_ref(ids, mask, H, normalize=normalize)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("V,K,D", [(128, 8, 32), (256, 12, 64)])
def test_sddmm(V, K, D):
    ids = jnp.asarray(RNG.integers(0, V, (V, K)), jnp.int32)
    mask = jnp.asarray(RNG.random((V, K)) < 0.5, jnp.float32)
    Hw = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
    a_src = jnp.asarray(RNG.standard_normal(D), jnp.float32)
    a_dst = jnp.asarray(RNG.standard_normal(D), jnp.float32)
    got = sddmm_pallas(ids, mask, Hw, a_src, a_dst, interpret=True)
    want = ref.sddmm_ref(ids, mask, Hw, a_src, a_dst)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("V,K,N,D", [(13, 5, 17, 8), (130, 7, 150, 16)])
def test_sddmm_ell_differentiable(V, K, N, D):
    """The distributed-GAT logit wrapper: awkward (padded) row counts, halo
    rows appended after the V dst rows, and an analytic VJP that matches
    jnp autodiff of the oracle for Hw / a_src / a_dst."""
    ids = jnp.asarray(RNG.integers(0, N, (V, K)), jnp.int32)
    mask = jnp.asarray(RNG.random((V, K)) < 0.6, jnp.float32)
    Hw = jnp.asarray(RNG.standard_normal((N, D)), jnp.float32)
    a_src = jnp.asarray(RNG.standard_normal(D), jnp.float32)
    a_dst = jnp.asarray(RNG.standard_normal(D), jnp.float32)
    got = sddmm_ell(ids, mask, Hw, a_src, a_dst, interpret=True)
    want = ref.sddmm_ref(ids, mask, Hw, a_src, a_dst)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)

    def masked_loss(fn):
        def loss(hw, a_s, a_d):
            e = fn(ids, mask, hw, a_s, a_d)
            return (jnp.where(mask > 0, jnp.tanh(e), 0.0)).sum()
        return loss

    g1 = jax.grad(masked_loss(
        lambda *a: sddmm_ell(*a, interpret=True)), argnums=(0, 1, 2))(
        Hw, a_src, a_dst)
    g2 = jax.grad(masked_loss(ref.sddmm_ref), argnums=(0, 1, 2))(
        Hw, a_src, a_dst)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("V,K,N,D", [(13, 5, 17, 8), (200, 9, 260, 32)])
def test_ell_attend_differentiable(V, K, N, D):
    """The attention-weighted ELL sum: gradients flow to BOTH the weights
    (GAT's attention coefficients) and the gathered table — `ell_spmm`
    deliberately zeroes the mask cotangent, so the GAT path needs this."""
    ids = jnp.asarray(RNG.integers(0, N, (V, K)), jnp.int32)
    w = jnp.asarray(RNG.random((V, K)), jnp.float32)
    H = jnp.asarray(RNG.standard_normal((N, D)), jnp.float32)

    def jnp_ref(w_, H_):
        return (w_[..., None] * jnp.take(H_, ids, axis=0)).sum(1)

    np.testing.assert_allclose(
        np.asarray(ell_attend(ids, w, H, interpret=True)),
        np.asarray(jnp_ref(w, H)), atol=1e-5, rtol=1e-5)
    g1 = jax.grad(lambda w_, H_: (ell_attend(ids, w_, H_, interpret=True)
                                  ** 2).sum(), argnums=(0, 1))(w, H)
    g2 = jax.grad(lambda w_, H_: (jnp_ref(w_, H_) ** 2).sum(),
                  argnums=(0, 1))(w, H)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
    assert float(jnp.abs(g1[0]).max()) > 0  # weights DO get a gradient


@pytest.mark.parametrize("B,H,S,D", [(1, 2, 128, 64), (2, 4, 256, 64), (1, 1, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, S, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, H, S, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, H, S, D)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,S,K,chunk", [(1, 2, 64, 16, 16), (2, 3, 128, 32, 32),
                                           (1, 1, 128, 64, 64)])
def test_wkv_chunk(B, H, S, K, chunk):
    r = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    g = jnp.asarray(-np.exp(RNG.standard_normal((B, H, S, K)) * 0.5 - 1.0), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, K)) * 0.1, jnp.float32)
    got = wkv_chunk_pallas(r, k, v, g, u, chunk=chunk, interpret=True)
    want = ref.wkv_chunk_ref(r, k, v, jnp.clip(g, -1.2, 0.0), u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_wkv_chunk_invariance():
    """Same result for different chunk sizes (the chunking is exact)."""
    B, H, S, K = 1, 2, 96, 16
    r = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, K)) * 0.5, jnp.float32)
    g = jnp.asarray(np.full((B, H, S, K), -0.3), jnp.float32)
    u = jnp.zeros((H, K), jnp.float32)
    a = wkv_chunk_pallas(r, k, v, g, u, chunk=16, interpret=True)
    b = wkv_chunk_pallas(r, k, v, g, u, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)
