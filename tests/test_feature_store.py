"""Sharded FeatureStore + trainable embedding tier.

Host half: the owner-partitioned id-addressed store itself — flat-id
addressing, sentinel reads, overlay attach/validation, snapshot staleness
semantics, and the touched-row extraction the sparse optimizer consumes.

Device half (subprocess, forced host devices): `trainable_features=True`
turns layer-0 rows into owner-sharded learnable embeddings updated by
row-sparse AdamW — every partition family x execution model x batching mode
must match the single-device DENSE-table oracle to <=1e-4, bitwise
deterministically, in ONE compile; rows a run never touched keep bitwise-zero
moment buffers; and the engine's reported embedding-gradient bytes must equal
the standalone cost models exactly.
"""
import numpy as np
import pytest

from conftest import run_with_devices

from repro.core.feature_store import (
    FeatureStore,
    touched_rows_from_frontier,
)


# ----------------------------------------------------------------------
# host-level store semantics
# ----------------------------------------------------------------------

def _store(k=3, rows=4, D=2, seed=0):
    rng = np.random.default_rng(seed)
    return FeatureStore(rng.normal(size=(k, rows, D)).astype(np.float32))


def test_store_flat_id_addressing_roundtrip():
    st = _store()
    ids = np.arange(st.num_rows)
    assert np.array_equal(st.owner_of(ids) * st.rows + st.slot_of(ids), ids)
    # from_flat(flat(), k) reproduces the table bitwise
    st2 = FeatureStore.from_flat(st.flat(), st.k)
    assert np.array_equal(st2.flat(), st.flat())
    # lookup by flat id == direct table row
    got = st.lookup([5])
    assert np.array_equal(got[0], st.flat()[5])


def test_store_sentinel_and_out_of_range_read_zero():
    st = _store()
    out = st.lookup([st.num_rows, -1, 0])
    assert np.all(out[0] == 0) and np.all(out[1] == 0)
    assert np.array_equal(out[2], st.flat()[0])


def test_store_update_rows_visible_to_lookup():
    st = _store()
    new = np.full((2, st.dim), 7.0, np.float32)
    st.update_rows([1, 9], new)
    assert np.array_equal(st.lookup([1, 9]), new)
    # the owner table view sees the same write
    assert np.array_equal(st._table[st.owner_of(9), st.slot_of(9)], new[1])


def test_overlay_rejects_local_rows_and_over_capacity():
    st = _store(k=2, rows=4)
    with pytest.raises(ValueError, match="own rows"):
        st.attach_overlay([np.array([0]), np.array([1])], capacity=2)
    with pytest.raises(ValueError, match="capacity"):
        st.attach_overlay([np.array([4, 5, 6]), np.zeros(0, np.int64)],
                          capacity=2)
    with pytest.raises(ValueError, match="id lists"):
        st.attach_overlay([np.zeros(0, np.int64)], capacity=2)


def test_overlay_snapshot_staleness_and_refresh():
    """The cache-as-store-overlay contract: a snapshot is exact at attach
    time, goes STALE when owner rows are updated (what frozen-feature
    engines may ignore but trainable ones must not), and one refresh makes
    it bitwise-exact again."""
    st = _store(k=2, rows=4)
    ids0 = np.array([4, 6])  # device 0 pins rows owned by device 1
    st.attach_overlay([ids0, np.array([1])], capacity=3)
    tab = st.overlay_table()
    assert np.array_equal(tab[0, :2], st.lookup(ids0))
    assert np.all(tab[0, 2] == 0) and np.all(tab[1, 1:] == 0)
    st.update_rows([6], np.full((1, st.dim), 3.25, np.float32))
    stale = st.overlay_table()
    assert not np.array_equal(stale[0, 1], st.lookup([6])[0])  # stale
    st.refresh_overlay()
    assert np.array_equal(st.overlay_table()[0, :2], st.lookup(ids0))


def test_touched_rows_from_frontier_sorted_unique_per_owner():
    k, rows, cap = 2, 4, 4
    sent = k * rows
    frontier = np.array([[5, 1, 1, sent],   # device 0 reads owner1:1, owner0:1
                         [7, 0, 5, sent]])  # device 1 reads owner1:{3,1}, owner0:0
    out = touched_rows_from_frontier(frontier, k, rows, cap)
    assert out.dtype == np.int32 and out.shape == (k, cap)
    assert out[0].tolist() == [0, 1, rows, rows]      # owner 0: slots {0,1}
    assert out[1].tolist() == [1, 3, rows, rows]      # owner 1: slots {1,3}
    with pytest.raises(AssertionError, match="cap overflow"):
        touched_rows_from_frontier(np.arange(sent)[None], k, rows, cap=1)


def test_trainable_features_requires_sync_protocol():
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph
    g = sbm_graph(48, num_blocks=4, p_in=0.1, p_out=0.02, seed=0)
    with pytest.raises(ValueError, match="protocol='sync'"):
        DistGNNEngine(g, cfg=EngineConfig(
            trainable_features=True, protocol="epoch_fixed"))


# ----------------------------------------------------------------------
# device tier: trainable embeddings == dense single-device Adam oracle
# ----------------------------------------------------------------------

_FULL_GRAPH_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for fam, exe in itertools.product({families}, {execs}):
        cfg = EngineConfig(
            execution=exe, partition_family=fam, hidden=16, lr=0.3,
            trainable_features=True, embed_lr=0.05, embed_weight_decay=0.01)
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        # bitwise determinism + the one-compile contract
        losses_d2, _ = eng.train({epochs})
        det = losses_d == losses_d2
        n = eng._jit_step._cache_size()
        # the embedding table must actually have LEARNED (moved off X)
        st = eng.init_state()
        st2 = st
        step = eng.make_step()
        for _ in range({epochs}):
            st2, _, _ = step(st2)
        moved = float(abs(st2["embed"] - st["embed"]).max()) > 0
        tag = f"{{fam}}/{{exe}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}} "
              f"compiles={{n}} moved={{moved}}")
        if not (err <= 1e-4 and lerr <= 1e-4 and det and moved and n == 1
                and np.isfinite(losses_d[-1])):
            fails.append((tag, err, lerr, det, moved, n))
    assert not fails, fails
    print("FS_FG_OK")
"""

_MINIBATCH_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    fails = []
    for batching, exe in itertools.product({batchings}, {execs}):
        cfg = EngineConfig(
            execution=exe, batching=batching, batch_size=8,
            fanouts=(3, 3), layer_sizes=(16, 16), walk_length=3,
            hidden=16, lr=0.3, trainable_features=True, embed_lr=0.05,
            cache_policy={cache_policy!r}, cache_capacity={cache_capacity})
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        losses_d2, _ = eng.train({epochs})
        det = losses_d == losses_d2
        n = eng._jit_mb_step._cache_size()
        tag = f"{{batching}}/{{exe}}/cache={{cfg.cache_policy}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}} "
              f"compiles={{n}}")
        if not (err <= 1e-4 and lerr <= 1e-4 and det and n == 1
                and np.isfinite(losses_d[-1])):
            fails.append((tag, err, lerr, det, n))
    assert not fails, fails
    print("FS_MB_OK")
"""


def test_trainable_full_graph_matrix_4dev():
    """Both partition families x all execution models, 4 devices: trainable
    layer-0 rows (sparse-AdamW on the store shards) == the dense-table
    single-device oracle, deterministic, one compile, and learning."""
    out = run_with_devices(_FULL_GRAPH_CODE.format(
        V=96, epochs=3,
        families=("edge_cut", "vertex_cut"),
        execs=("broadcast", "ring", "p2p"),
    ), n_devices=4, timeout=600)
    assert "FS_FG_OK" in out


def test_trainable_minibatch_matrix_4dev():
    """Sampled batchings x execution models, no cache: the frontier fetch
    moves inside the grad, the collective transposes route cotangents back
    to the owners, and only the touched rows update."""
    out = run_with_devices(_MINIBATCH_CODE.format(
        V=96, epochs=3,
        batchings=("node_wise", "layer_wise", "subgraph"),
        execs=("broadcast", "ring", "p2p"),
        cache_policy="none", cache_capacity=0,
    ), n_devices=4, timeout=600)
    assert "FS_MB_OK" in out


def test_trainable_minibatch_cached_matrix_4dev():
    """With the hot-row overlay on: cache hits read LIVE rows (the in-step
    overlay refresh), so hit gradients still land on the owner shards and
    the math stays oracle-exact."""
    out = run_with_devices(_MINIBATCH_CODE.format(
        V=96, epochs=3,
        batchings=("node_wise", "subgraph"),
        execs=("broadcast", "ring", "p2p"),
        cache_policy="static_degree", cache_capacity=12,
    ), n_devices=4, timeout=600)
    assert "FS_MB_OK" in out


def test_trainable_matrix_8dev():
    """Scale sanity at 8 devices: both families full-graph p2p, plus cached
    node-wise mini-batch."""
    out = run_with_devices(_FULL_GRAPH_CODE.format(
        V=128, epochs=2,
        families=("edge_cut", "vertex_cut"), execs=("p2p",),
    ), n_devices=8, timeout=600)
    assert "FS_FG_OK" in out
    out = run_with_devices(_MINIBATCH_CODE.format(
        V=128, epochs=2,
        batchings=("node_wise",), execs=("broadcast", "ring", "p2p"),
        cache_policy="static_degree", cache_capacity=12,
    ), n_devices=8, timeout=600)
    assert "FS_MB_OK" in out


def test_untouched_rows_bitwise_frozen_4dev():
    """The sparse-update contract, verified on the live engine: embedding
    rows NO mini-batch step touched keep their initial values and ZERO
    moment/step buffers bitwise; touched rows have moved.  Under vertex_cut
    full-graph, non-master replica slots keep zero moments and every
    replica group stays bitwise consistent after training."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.feature_store import touched_rows_from_frontier
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
        cfg = EngineConfig(execution="p2p", batching="node_wise",
                           batch_size=4, fanouts=(2, 2), hidden=16, lr=0.3,
                           trainable_features=True, embed_lr=0.05)
        eng = DistGNNEngine(g, cfg=cfg)
        steps = 3
        step = eng.make_minibatch_step()
        state0 = eng.init_minibatch_state()
        state = state0
        touched = np.zeros(eng.Vp, bool)
        for i in range(steps):
            batch = eng.sample_minibatch(i)
            ids = np.asarray(batch["emb_ids"])  # [k, tcap] local rows
            for d in range(eng.k):
                rows = ids[d][ids[d] < eng.nb]
                touched[d * eng.nb + rows] = True
            state, _, _ = step(state, batch)
        emb0 = np.asarray(state0["embed"])
        emb = np.asarray(state["embed"])
        m = np.asarray(state["emb_m"])
        v = np.asarray(state["emb_v"])
        t = np.asarray(state["emb_t"])
        u = ~touched
        assert np.array_equal(emb[u], emb0[u]), "untouched rows moved"
        assert np.all(m[u] == 0) and np.all(v[u] == 0) and np.all(t[u] == 0)
        assert touched.any() and t[touched].min() >= 1
        assert float(np.abs(emb[touched] - emb0[touched]).max()) > 0
        print("UNTOUCHED_MB_OK", int(touched.sum()), "/", eng.Vp)

        cfg2 = EngineConfig(execution="broadcast",
                            partition_family="vertex_cut", hidden=16,
                            lr=0.3, trainable_features=True, embed_lr=0.05)
        eng2 = DistGNNEngine(g, cfg=cfg2)
        st = eng2.init_state()
        fg = eng2.make_step()
        for _ in range(3):
            st, _, _ = fg(st)
        mask = np.asarray(eng2.emb_touched).astype(bool)  # master slots
        m2 = np.asarray(st["emb_m"]); t2 = np.asarray(st["emb_t"])
        assert np.all(m2[~mask] == 0) and np.all(t2[~mask] == 0)
        assert np.all(t2[mask & (np.asarray(eng2.layout.vert_ids).ravel()
                                 < g.num_vertices)] >= 1)
        # replica groups bitwise consistent after the delta re-broadcast
        emb2 = np.asarray(st["embed"])
        vid = np.asarray(eng2.layout.vert_ids).ravel()
        for vtx in range(g.num_vertices):
            rows = emb2[vid == vtx]
            if len(rows) > 1:
                assert np.array_equal(rows, np.repeat(rows[:1], len(rows),
                                                      axis=0))
        print("VC_REPLICA_OK")
    """, n_devices=4, timeout=600)
    assert "UNTOUCHED_MB_OK" in out and "VC_REPLICA_OK" in out


def test_embed_grad_bytes_cross_check_4dev():
    """Engine-reported CommStats.embed_grad_bytes == the standalone cost
    models, recomputed from a FRESH engine: `embedding_grad_bytes_per_step`
    for full-graph (all executions + vertex_cut), `embedding_update_bytes`
    over the deterministic frontiers for mini-batch."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import powerlaw_graph
        from repro.core.partition.cost_models import (
            embedding_grad_bytes_per_step)
        from repro.core.sampling import CommStats
        from repro.core.sampling.distributed import embedding_update_bytes

        g = powerlaw_graph(120, avg_degree=8, seed=2)
        steps = 3
        for exe in ("broadcast", "ring", "p2p"):
            cfg = EngineConfig(execution=exe, hidden=16, lr=0.3,
                               trainable_features=True, embed_lr=0.05)
            eng = DistGNNEngine(g, cfg=cfg)
            eng.train(steps)
            per = embedding_grad_bytes_per_step(
                g, exe, eng.dims, k=eng.k, part=eng.part, nb=eng.nb)
            assert eng.comm_stats.embed_grad_bytes == steps * per, (
                exe, eng.comm_stats, per)
            assert per > 0
        cfgv = EngineConfig(execution="broadcast",
                            partition_family="vertex_cut", hidden=16,
                            lr=0.3, trainable_features=True, embed_lr=0.05)
        engv = DistGNNEngine(g, cfg=cfgv)
        engv.train(steps)
        perv = embedding_grad_bytes_per_step(
            g, "broadcast", engv.dims, k=engv.k, family="vertex_cut",
            replica_rows=engv._vc_rows_per_layer)
        assert engv.comm_stats.embed_grad_bytes == steps * perv
        print("FG_BYTES_OK")

        cfg = EngineConfig(execution="p2p", batching="node_wise",
                           batch_size=8, fanouts=(3, 3), hidden=16, lr=0.3,
                           cache_policy="static_degree", cache_capacity=12,
                           trainable_features=True, embed_lr=0.05)
        eng = DistGNNEngine(g, cfg=cfg)
        eng.train(steps)
        eng2 = DistGNNEngine(g, cfg=cfg)
        expected = CommStats()
        D = g.features.shape[1]
        for i in range(steps):
            for d, mb in enumerate(eng2._sample_host(i)):
                embedding_update_bytes(
                    eng2.part, d, mb.layer_vertices[0], D,
                    cached_ids=eng2._cache_set[d],
                    overlay_rows=len(eng2.cache_old_ids[d]), stats=expected)
        assert eng.comm_stats.embed_grad_bytes == expected.embed_grad_bytes
        assert expected.embed_grad_bytes > 0
        # feature-fetch accounting is unchanged by trainable mode
        assert eng.comm_stats.pull_bytes > 0
        print("MB_BYTES_OK")
    """, n_devices=4, timeout=600)
    assert "FG_BYTES_OK" in out and "MB_BYTES_OK" in out
