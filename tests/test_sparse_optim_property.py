"""Property tier for the row-sparse AdamW (optim/sparse_optim.py).

Randomized multi-step runs over random touched-id sequences (duplicates,
empty steps, sentinel padding included) check the two contracts everything
else builds on:

  * equivalence — `sparse_adamw_ids` over any id list produces EXACTLY the
    trajectory of the masked-dense `row_adamw_update` with the scatter-added
    dense gradient (and, for always-touched rows, of the repo's dense
    `adamw` with the same hyperparameters);
  * isolation — rows a step does not touch are bitwise unchanged in params,
    both moments, AND the per-row step counts.

The seeded checks below always run; when the optional `property` extra
(hypothesis) is installed — the same gating as test_model_property.py — the
same properties are additionally driven by generated cases.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw, make_optimizer, sparse_adamw
from repro.optim.sparse_optim import row_adamw_update, sparse_adamw_ids

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

HP = dict(lr=0.07, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.013)


def _random_id_steps(rng, N):
    """A short run of per-step id lists: duplicates, empty steps, and
    sentinel (== N) entries all occur."""
    steps = []
    for _ in range(int(rng.integers(1, 5))):
        n_ids = int(rng.integers(0, 2 * N))
        steps.append(rng.integers(0, N + 1, size=n_ids).tolist())
    steps.append([])  # always exercise an empty step
    dup = int(rng.integers(0, N))
    steps.append([dup, dup, dup])  # and a pure-duplicate step
    return steps


def _check_ids_path_matches_masked_dense(N, D, steps, seed):
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(N, D)).astype(np.float32)
    sp = dict(p=jnp.asarray(p), m=jnp.zeros((N, D), jnp.float32),
              v=jnp.zeros((N, D), jnp.float32), t=jnp.zeros((N,), jnp.int32))
    dn = {k: v for k, v in sp.items()}
    for ids_list in steps:
        R = max(len(ids_list), 1) + 2  # always some sentinel padding
        ids = np.full((R,), N, np.int64)
        ids[: len(ids_list)] = ids_list
        g_rows = rng.normal(size=(R, D)).astype(np.float32)
        # dense oracle: scatter-ADD duplicate rows, touched = scattered ids
        g_dense = np.zeros((N, D), np.float32)
        touched = np.zeros((N,), bool)
        for j, i in enumerate(ids):
            if i < N:
                g_dense[i] += g_rows[j]
                touched[i] = True
        sp["p"], sp["m"], sp["v"], sp["t"] = sparse_adamw_ids(
            sp["p"], sp["m"], sp["v"], sp["t"], jnp.asarray(ids),
            jnp.asarray(g_rows), dedup=True, **HP)
        prev = {k: np.asarray(v) for k, v in dn.items()}
        dn["p"], dn["m"], dn["v"], dn["t"] = row_adamw_update(
            dn["p"], jnp.asarray(g_dense), dn["m"], dn["v"], dn["t"],
            jnp.asarray(touched), **HP)
        for key in ("p", "m", "v", "t"):
            a, b = np.asarray(sp[key]), np.asarray(dn[key])
            assert np.array_equal(a, b), (key, a, b)
            u = ~touched
            assert np.array_equal(a[u], prev[key][u]), (
                f"untouched rows of {key} changed")


def _check_lazy_matches_dense_adamw(N, D, seeds):
    rng = np.random.default_rng(seeds[0])
    params = {"emb": jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))}
    lr_fn = lambda s: HP["lr"]  # noqa: E731
    osp = make_optimizer("sparse_adamw", lr_fn, b1=HP["b1"], b2=HP["b2"],
                         eps=HP["eps"], weight_decay=HP["weight_decay"])
    odn = adamw(lr_fn, b1=HP["b1"], b2=HP["b2"], eps=HP["eps"],
                weight_decay=HP["weight_decay"])
    ssp, sdn = osp.init(params), odn.init(params)
    psp = pdn = params
    for step, seed in enumerate(seeds):
        g = {"emb": jnp.asarray(
            np.random.default_rng(seed).normal(size=(N, D))
            .astype(np.float32) + 0.01)}
        usp, ssp = osp.update(g, ssp, psp, jnp.asarray(step))
        udn, sdn = odn.update(g, sdn, pdn, jnp.asarray(step))
        np.testing.assert_allclose(np.asarray(usp["emb"]),
                                   np.asarray(udn["emb"]), atol=1e-6)
        psp = {"emb": psp["emb"] + usp["emb"]}
        pdn = {"emb": pdn["emb"] + udn["emb"]}
    # now zero out row 0's gradient: it must freeze bitwise
    before = (np.asarray(psp["emb"][0]), np.asarray(ssp["m"]["emb"][0]),
              np.asarray(ssp["v"]["emb"][0]), int(ssp["t"]["emb"][0]))
    g = {"emb": jnp.asarray(np.ones((N, D), np.float32)).at[0].set(0.0)}
    usp, ssp = osp.update(g, ssp, psp, jnp.asarray(len(seeds)))
    psp = {"emb": psp["emb"] + usp["emb"]}
    assert np.array_equal(np.asarray(psp["emb"][0]), before[0])
    assert np.array_equal(np.asarray(ssp["m"]["emb"][0]), before[1])
    assert np.array_equal(np.asarray(ssp["v"]["emb"][0]), before[2])
    assert int(ssp["t"]["emb"][0]) == before[3]
    if N > 1:
        assert int(ssp["t"]["emb"][1]) == before[3] + 1


# -- always-on seeded sweeps ------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_ids_path_matches_masked_dense_oracle(seed):
    """sparse_adamw_ids (dedup on, sentinel-padded, duplicate ids) ==
    row_adamw_update with the dense scatter-added gradient, every step;
    untouched rows bitwise frozen in all four buffers."""
    rng = np.random.default_rng(1000 + seed)
    N, D = int(rng.integers(2, 10)), int(rng.integers(1, 5))
    _check_ids_path_matches_masked_dense(
        N, D, _random_id_steps(rng, N), seed)


@pytest.mark.parametrize("seed", range(4))
def test_lazy_optimizer_matches_dense_adamw_when_all_rows_touched(seed):
    """The registered Optimizer wrapper: with dense nonzero gradients every
    step, sparse_adamw's trajectory IS adamw's (same hyperparameters); rows
    given an all-zero gradient are bitwise untouched, including t."""
    rng = np.random.default_rng(2000 + seed)
    _check_lazy_matches_dense_adamw(
        int(rng.integers(2, 9)), int(rng.integers(1, 4)),
        rng.integers(0, 2**31 - 1, size=3).tolist())


def test_empty_id_list_is_identity():
    """An all-sentinel step changes nothing, bitwise."""
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))
    m = jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))
    v = jnp.asarray(np.abs(rng.normal(size=(5, 3))).astype(np.float32))
    t = jnp.asarray(np.arange(5, dtype=np.int32))
    ids = jnp.full((4,), 5)
    g = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    p2, m2, v2, t2 = sparse_adamw_ids(p, m, v, t, ids, g, **HP)
    for a, b in ((p, p2), (m, m2), (v, v2), (t, t2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_state_logical_axes_shapes():
    """Moment axes mirror the param axes; the per-row counts keep only the
    leading (row) axis — what the engine's sharded state relies on."""
    opt = sparse_adamw(lambda s: 0.1)
    axes = opt.state_logical_axes({"emb": ("vocab", "embed")})
    assert axes["m"] == {"emb": ("vocab", "embed")}
    assert axes["v"] == {"emb": ("vocab", "embed")}
    assert axes["t"] == {"emb": ("vocab",)}


# -- hypothesis-driven versions (optional `property` extra) -----------------

if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 9), st.integers(1, 4),
           st.integers(0, 2**31 - 1))
    def test_ids_path_property(N, D, seed):
        rng = np.random.default_rng(seed)
        _check_ids_path_matches_masked_dense(
            N, D, _random_id_steps(rng, N), seed)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(2, 8), st.integers(1, 3),
           st.lists(st.integers(0, 2**31 - 1), min_size=2, max_size=4))
    def test_lazy_optimizer_property(N, D, seeds):
        _check_lazy_matches_dense_adamw(N, D, seeds)
