"""DistGNNEngine integration matrix (subprocess, forced host devices): every
execution model x protocol combination must match the single-device oracle to
<=1e-4 max loss error, on 4 and 8 devices, across partitioners; plus
determinism (same seed -> bitwise-identical losses across runs).

This is the engine's contract: the partition plan, the halo exchange, the
Pallas ELL local multiply and the (deterministic-schedule) staleness protocols
may not change the math — only where it runs.
"""
import pytest

from conftest import run_with_devices

_MATRIX_CODE = """
    import itertools
    import jax, numpy as np
    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph({V}, num_blocks=8, p_in=0.08, p_out=0.01, seed=0)
    execs = {execs}
    protocols = {protocols}
    partitioners = {partitioners}
    fails = []
    for i, (exe, proto) in enumerate(itertools.product(execs, protocols)):
        cfg = EngineConfig(execution=exe, protocol=proto,
                           partitioner=partitioners[i % len(partitioners)],
                           hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        losses_d, logits_d = eng.train({epochs})
        losses_r, logits_r = eng.train({epochs}, reference=True)
        err = max(abs(a - b) for a, b in zip(losses_d, losses_r))
        lerr = float(abs(logits_d - logits_r).max())
        tag = f"{{exe}}/{{proto}}/{{cfg.partitioner}}"
        print(f"{{tag}}: loss_err={{err:.2e}} logits_err={{lerr:.2e}}")
        if not (err <= 1e-4 and np.isfinite(losses_d[-1])):
            fails.append((tag, err))
    assert not fails, fails
    print("ENGINE_MATRIX_OK")
"""


def test_engine_matrix_4dev():
    """Full 3 execution models x 4 protocols on 4 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=96, epochs=4,
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync", "epoch_fixed", "epoch_adaptive", "variation"),
        partitioners=("metis_like", "ldg", "hash"),
    ), n_devices=4)
    assert "ENGINE_MATRIX_OK" in out


def test_engine_matrix_8dev():
    """All execution models x {sync, async-historical} on 8 devices."""
    out = run_with_devices(_MATRIX_CODE.format(
        V=128, epochs=4,
        execs=("broadcast", "ring", "p2p"),
        protocols=("sync", "epoch_adaptive"),
        partitioners=("metis_like", "hash"),
    ), n_devices=8)
    assert "ENGINE_MATRIX_OK" in out


def test_engine_determinism_4dev():
    """Same seed -> bitwise-identical losses across two runs (the protocol's
    deterministic refresh schedule is part of the SPMD contract)."""
    out = run_with_devices("""
        import jax
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import sbm_graph

        g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
        cfg = EngineConfig(execution="p2p", protocol="epoch_adaptive",
                           hidden=16, lr=0.3)
        eng = DistGNNEngine(g, cfg=cfg)
        l1, _ = eng.train(5)
        l2, _ = eng.train(5)
        assert l1 == l2, (l1, l2)
        eng2 = DistGNNEngine(g, cfg=cfg)
        l3, _ = eng2.train(5)
        assert l1 == l3, (l1, l3)
        print("ENGINE_DET_OK", l1[-1])
    """, n_devices=4)
    assert "ENGINE_DET_OK" in out


def test_engine_rejects_bad_config():
    from repro.core.engine import EngineConfig, DistGNNEngine
    from repro.core.graph import er_graph

    g = er_graph(32, avg_degree=4, seed=0)
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(execution="nope"))
    with pytest.raises(ValueError):
        DistGNNEngine(g, cfg=EngineConfig(protocol="nope"))


def test_engine_single_device_paths_agree():
    """On one device the distributed step IS the oracle (k=1 partition plan,
    halo cap degenerate): both paths must agree and learn."""
    import jax

    from repro.core.engine import DistGNNEngine, EngineConfig
    from repro.core.graph import sbm_graph

    g = sbm_graph(64, num_blocks=4, p_in=0.1, p_out=0.01, seed=1)
    mesh = jax.make_mesh((1,), ("w",))
    eng = DistGNNEngine(g, mesh=mesh, cfg=EngineConfig(
        execution="p2p", protocol="sync", hidden=16, lr=0.3))
    ld, _ = eng.train(10)
    lr_, _ = eng.train(10, reference=True)
    assert max(abs(a - b) for a, b in zip(ld, lr_)) < 1e-4
    assert ld[-1] < ld[0]
