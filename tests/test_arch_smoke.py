"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each family and run one forward/train step + one decode step on
CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import transformer as T
from repro.models.kvcache import init_cache

B, S = 2, 32


def _batch(cfg):
    batch = {"labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.rope_style == "mrope":
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    else:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder:
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h, aux, _ = jax.jit(lambda p, b: T.forward(cfg, p, b))(params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch), has_aux=True)
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, 64, enc_len=16)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: T.serve_step(cfg, p, c, t, jnp.int32(3)))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structurally preserved
    assert set(cache2.keys()) >= {k for k in cache if k not in ("xk", "xv")} - set()
