from repro.checkpoint.ckpt import load_checkpoint, restore_latest, save_checkpoint

__all__ = ["load_checkpoint", "restore_latest", "save_checkpoint"]
