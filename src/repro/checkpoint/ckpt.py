"""Sharding-aware checkpointing.

Pytrees are flattened to key-path -> array and stored as .npz plus a JSON
manifest carrying step, tree structure, and each leaf's logical axes (so a
restore onto a different mesh re-shards correctly: arrays are loaded on host
and device_put with the target sharding).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.utils import get_logger

log = get_logger("repro.ckpt")


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez(path, **{k.replace("/", "__"): v for k, v in arrays.items()})
    treedef = jax.tree_util.tree_structure(state)
    manifest = {"step": step, "keys": sorted(arrays), "treedef": str(treedef)}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    _gc(ckpt_dir, keep)
    log.info("saved checkpoint %s (%d leaves)", path, len(arrays))
    return path


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
        meta = os.path.join(ckpt_dir, old + ".json")
        if os.path.exists(meta):
            os.remove(meta)


def load_checkpoint(path: str, target: Any, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `target`; optionally device_put each leaf
    with the matching sharding pytree."""
    data = np.load(path)
    flat_target = _flatten_with_paths(target)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None else None
    restored = {}
    for key, ref in flat_target.items():
        arr = data[key.replace("/", "__")]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        if flat_shard is not None:
            restored[key] = jax.device_put(arr.astype(ref.dtype), flat_shard[key])
        else:
            restored[key] = jax.numpy.asarray(arr.astype(ref.dtype))
    leaves_paths = jax.tree_util.tree_flatten_with_path(target)
    keys_in_order = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in leaves_paths[0]
    ]
    return jax.tree_util.tree_unflatten(leaves_paths[1], [restored[k] for k in keys_in_order])


def restore_latest(ckpt_dir: str, target: Any, shardings: Optional[Any] = None):
    """Returns (state, step) or (None, -1)."""
    if not os.path.isdir(ckpt_dir):
        return None, -1
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if re.fullmatch(r"ckpt_\d+\.npz", f)
    )
    if not ckpts:
        return None, -1
    path = os.path.join(ckpt_dir, ckpts[-1])
    step = int(re.findall(r"\d+", ckpts[-1])[0])
    return load_checkpoint(path, target, shardings), step
