"""Analytic FLOP / HBM-traffic accounting for the roofline.

Why analytic: XLA's HloCostAnalysis visits while-loop bodies ONCE (verified
empirically — a scanned 8-layer matmul reports 1 layer of flops), and every
production config here scans its layer stack, so ``compiled.cost_analysis()``
is a *lower bound*, not the workload. We therefore compute exact structural
FLOPs from the model math (the same accounting MaxText/PaLM papers use),
report cost_analysis alongside as a sanity bound, and cross-validate the
analytic numbers against cost_analysis on small UNSCANNED smoke configs in
tests/test_flops.py, where XLA counts everything.

Convention: 1 MAC = 2 FLOPs; causal attention counts the triangular half.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class FlopsReport:
    total: float  # per step, global
    attention: float
    matmul: float
    logits: float
    detail: Dict[str, float]


def _attn_flops(cfg, B, S, T, causal: bool) -> float:
    """Score + PV flops for one layer."""
    H = cfg.num_heads
    if cfg.use_mla:
        qk_dim = cfg.head_dim + cfg.rope_head_dim
        v_dim = cfg.head_dim
    else:
        qk_dim = v_dim = cfg.head_dim
    frac = 0.5 if (causal and S == T) else 1.0
    return 2.0 * B * H * S * T * (qk_dim + v_dim) * frac


def _proj_flops(cfg, N) -> float:
    """Per-layer projection flops for N tokens (excluding FFN)."""
    D = cfg.d_model
    if cfg.ssm_kind == "rwkv6":
        H, K = cfg.ssm_heads, cfg.ssm_state
        inner = H * K
        lora = max(32, D // 16)
        return 2.0 * N * D * (4 * inner) + 2.0 * N * inner * D + 2.0 * N * D * lora + 2.0 * N * lora * inner
    if cfg.ssm_kind == "mamba2":
        d_i = 2 * D
        return 2.0 * N * D * (2 * d_i + 2 * cfg.ssm_state + cfg.ssm_heads) + 2.0 * N * d_i * D
    if cfg.use_mla:
        f = 2.0 * N * D * cfg.num_heads * (cfg.head_dim + cfg.rope_head_dim)  # q
        f += 2.0 * N * D * (cfg.kv_lora_rank + cfg.rope_head_dim)  # down
        f += 2.0 * 2.0 * N * cfg.kv_lora_rank * cfg.num_heads * cfg.head_dim  # up k,v
        f += 2.0 * N * cfg.num_heads * cfg.head_dim * D  # out
        return f
    f = 2.0 * N * D * cfg.q_dim  # q
    f += 2.0 * 2.0 * N * D * cfg.kv_dim  # k,v
    f += 2.0 * N * cfg.q_dim * D  # out
    return f


def _ssm_scan_flops(cfg, B, S) -> float:
    if not cfg.ssm_kind:
        return 0.0
    C = cfg.ssm_chunk
    if cfg.ssm_kind == "rwkv6":
        H, K, V = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_state
    else:
        H, K = cfg.ssm_heads, cfg.ssm_state
        V = 2 * cfg.d_model // cfg.ssm_heads
    intra = 2.0 * B * H * S * C * (K + V)  # A = q k^T (masked) ; y = A v
    inter = 4.0 * B * S * H * K * V  # state read + update
    return intra + inter


def _ffn_flops(cfg, N, layer_is_moe: bool) -> float:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.ssm_kind == "rwkv6":
        return 2.0 * 2.0 * N * D * F  # channel mix: two matmuls
    if layer_is_moe:
        f = 2.0 * N * D * cfg.num_experts  # router
        f += 3.0 * 2.0 * N * D * F * cfg.moe_top_k  # routed experts (active)
        f += 3.0 * 2.0 * N * D * F * cfg.num_shared_experts  # shared
        return f
    return 3.0 * 2.0 * N * D * F


def forward_flops(cfg: ModelConfig, B: int, S: int, T: int = None, *,
                  causal: bool = True, with_logits: bool = True,
                  window: int = 0) -> FlopsReport:
    """One forward pass over B x S query tokens attending to T cache tokens."""
    T = T if T is not None else S
    T_eff = min(T, window) if window > 0 else T
    N = B * S
    attn = matmul = 0.0
    for layer in range(cfg.num_layers):
        is_moe = bool(cfg.num_experts) and layer >= cfg.first_k_dense
        has_attn = (not cfg.ssm_kind) or cfg._layer_has_attn(layer)
        if cfg.ssm_kind:
            matmul += _proj_flops(cfg, N)
            attn += _ssm_scan_flops(cfg, B, S)
            if has_attn:  # hybrid shared attention block
                matmul += 2.0 * N * D_attn_proj(cfg) + _ffn_flops(cfg, N, False)
                attn += _attn_flops(cfg, B, S, T_eff, causal)
        else:
            matmul += _proj_flops(cfg, N)
            attn += _attn_flops(cfg, B, S, T_eff, causal)
        matmul += _ffn_flops(cfg, N, is_moe)
    if cfg.is_encoder_decoder:
        # encoder over its own length (we model enc len == dec len here; the
        # caller passes decoder S) + cross attention per decoder layer
        for _ in range(cfg.encoder_layers):
            matmul += _proj_flops(cfg, N) + _ffn_flops(cfg, N, False)
            attn += _attn_flops(cfg, B, S, S, False)
        matmul += cfg.num_layers * _proj_flops(cfg, N)  # cross-attn projections
        attn += cfg.num_layers * _attn_flops(cfg, B, S, S, False)
    logits = 2.0 * N * cfg.d_model * cfg.vocab_size if with_logits else 0.0
    total = attn + matmul + logits
    return FlopsReport(total, attn, matmul, logits,
                       {"attention": attn, "matmul": matmul, "logits": logits})


def D_attn_proj(cfg) -> float:
    return cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim + cfg.q_dim)


def step_flops(cfg: ModelConfig, shape: ShapeConfig, *, window: int = 0) -> FlopsReport:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            S = S // 2
        f = forward_flops(cfg, B, S, causal=True, window=window)
        return FlopsReport(3.0 * f.total, 3.0 * f.attention, 3.0 * f.matmul,
                           3.0 * f.logits, {k: 3.0 * v for k, v in f.detail.items()})
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            S = S // 2
        return forward_flops(cfg, B, S, causal=True, window=window, with_logits=False)
    # decode: one token against a cache of S
    return forward_flops(cfg, B, 1, T=S, causal=False, window=window)


def model_flops_6nd(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The survey-style usefulness denominator: 6*N(active)*D tokens."""
    n_params = cfg.num_active_params() if cfg.num_experts else cfg.num_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        return 2.0 * n_params * shape.global_batch * shape.seq_len
    return 2.0 * n_params * shape.global_batch  # one token per sequence


def hbm_traffic_bytes(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                      param_bytes_total: int, cache_bytes_total: int = 0) -> float:
    """Per-chip HBM traffic estimate for the memory roofline term.

    decode : weights (each read once per step) + KV cache read + write eps.
    prefill: weights + activations (2 bytes, ~12 tensors of [N,D] per layer).
    train  : 3x weights (fwd+bwd read, grad write) + activations incl. remat
             recompute (~2x forward activations).
    Everything divided by chip count (weights and batch are sharded).
    """
    act_unit = 2.0 * shape.global_batch * shape.seq_len * cfg.d_model  # bf16 [N,D]
    layers = cfg.num_layers + (cfg.encoder_layers if cfg.is_encoder_decoder else 0)
    if shape.kind == "decode":
        per_chip = (param_bytes_total + cache_bytes_total) / chips
        return per_chip
    act_traffic = 12.0 * act_unit * layers
    if shape.kind == "train":
        total = 3.0 * param_bytes_total + 2.0 * act_traffic
    else:
        total = param_bytes_total + act_traffic
    return total / chips
