"""Logical-axis sharding: the single place where model code meets the mesh.

Model code annotates tensors with *logical* axis names via ``logical(x, ...)``
and declares parameter logical axes through the ParamBuilder. The launcher
activates a (mesh, rules) context; outside a context, annotations are no-ops,
which is what CPU smoke tests use.

Rules map logical names -> mesh axis (or tuple of axes, or None). They are
computed per (config, mesh) because e.g. GQA KV heads smaller than the model
axis must be replicated, not unevenly sharded.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, Tuple[str, ...]]

# Baseline rules: data-parallel batch (composed with the pod axis when it
# exists), tensor-parallel heads/ffn/experts/vocab, FSDP (ZeRO-3) on the
# d_model ("embed") dim of weights over the data axis.
DEFAULT_RULES: Dict[str, AxisRule] = {
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_res_seq": None,  # residual-stream seq dim; 'model' => Megatron-SP
    "act_kv_seq": None,  # overridden to ("data",) for seq-sharded decode caches
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "act_expert": "model",
    # params
    "layer": None,
    "embed": "data",  # FSDP dim
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head": None,
    "mlp": "model",
    "expert": "model",
    "expert_embed": "data",  # FSDP dim of expert weights (train layout)
    "expert_mlp": None,  # expert inner dim: experts already consume 'model'
    "kv_lora": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, AxisRule]] = None


_CTX = _Ctx()


def make_rules(cfg: Any, mesh: Mesh, overrides: Optional[Dict[str, AxisRule]] = None) -> Dict[str, AxisRule]:
    """Compute config/mesh-aware rules (divisibility-safe)."""
    rules = dict(DEFAULT_RULES)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = axis_sizes.get("model", 1)
    data_size = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
    if "pod" not in axis_sizes:
        rules["act_batch"] = ("data",)

    def drop_if_indivisible(name: str, dim: int, axis: str = "model"):
        if dim and dim % axis_sizes.get(axis, 1) != 0:
            rules[name] = None

    drop_if_indivisible("kv_heads", getattr(cfg, "num_kv_heads", 0))
    drop_if_indivisible("act_kv_heads", getattr(cfg, "num_kv_heads", 0))
    drop_if_indivisible("heads", getattr(cfg, "num_heads", 0))
    drop_if_indivisible("act_heads", getattr(cfg, "num_heads", 0))
    drop_if_indivisible("expert", getattr(cfg, "num_experts", 0))
    drop_if_indivisible("act_expert", getattr(cfg, "num_experts", 0))
    drop_if_indivisible("mlp", getattr(cfg, "d_ff", 0))
    drop_if_indivisible("act_ff", getattr(cfg, "d_ff", 0))
    drop_if_indivisible("vocab", getattr(cfg, "vocab_size", 0))
    drop_if_indivisible("act_vocab", getattr(cfg, "vocab_size", 0))
    drop_if_indivisible("kv_lora", getattr(cfg, "kv_lora_rank", 0))
    drop_if_indivisible("ssm_heads", getattr(cfg, "ssm_heads", 0))
    if getattr(cfg, "d_model", 0) and cfg.d_model % max(data_size, 1) != 0:
        rules["embed"] = None
        rules["expert_embed"] = None
    if overrides:
        rules.update(overrides)
    return rules


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, AxisRule]]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> Optional[Dict[str, AxisRule]]:
    return _CTX.rules


def spec_for(axes: Sequence[Optional[str]], rules: Optional[Dict[str, AxisRule]] = None) -> P:
    rules = rules if rules is not None else (_CTX.rules or {})
    parts = []
    for name in axes:
        rule = rules.get(name) if name is not None else None
        parts.append(rule)
    return P(*parts)


def logical(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without an active context)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    assert len(axes) == x.ndim, f"{axes} vs rank {x.ndim}"
    spec = spec_for(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def sharding_for_tree(axes_tree: Any, mesh: Mesh, rules: Dict[str, AxisRule]):
    """Build a NamedSharding pytree from a logical-axes pytree."""

    def one(axes):
        return NamedSharding(mesh, spec_for(axes, rules))

    return jax.tree_util.tree_map(one, axes_tree, is_leaf=lambda t: isinstance(t, tuple))
