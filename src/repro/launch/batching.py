"""Continuous batching for serving (beyond-paper production feature).

A fixed pool of decode slots runs one fused serve_step per tick; requests
join free slots as they arrive and leave on EOS/max-len, so throughput stays
at the batch-B decode rate instead of draining per request (the vLLM-style
scheduler, sized for the static-shape constraints of jit: the batch dimension
and cache length are fixed, occupancy is masked).

Works with every decoder family in the framework (the cache layout is opaque
here — slots index the batch dimension of whatever cache dict the arch uses).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.kvcache import init_cache
from repro.utils import get_logger

log = get_logger("repro.batching")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    eos_id: int = -1  # -1: never
    # runtime
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_pos: int = 0

    @property
    def done(self) -> bool:
        if self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0
    occupancy_sum: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.ticks, 1)


class ContinuousBatchingEngine:
    """Slot-based continuous batching over a single jitted serve_step.

    Per-slot position counters let requests at different depths share one
    step; a slot's cache region is logically reset just by restarting its
    position at 0 (stale cache beyond the mask is never read).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: Deque[Request] = deque()
        self.pos = np.zeros(slots, np.int32)  # per-slot next position
        self.stats = EngineStats()
        self._step = jax.jit(lambda p, c, t, pos: T.serve_step_vec(cfg, p, c, t, pos))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.popleft()
                self.pos[i] = 0

    def _occupancy(self) -> int:
        return sum(r is not None for r in self.active)

    def tick(self) -> List[Tuple[int, int]]:
        """One decode wave. Returns [(uid, token)] emitted this tick."""
        self._admit()
        occ = self._occupancy()
        if occ == 0:
            return []
        # build the token batch: prompt tokens (prefill-by-decode) or the
        # last generated token
        toks = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if r.prompt_pos < len(r.prompt):
                toks[i, 0] = r.prompt[r.prompt_pos]
            else:
                toks[i, 0] = r.generated[-1] if r.generated else 0
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks),
                                        jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            self.pos[i] += 1
            if r.prompt_pos < len(r.prompt):
                r.prompt_pos += 1  # consuming the prompt
                if r.prompt_pos == len(r.prompt):
                    # the tick that ate the LAST prompt token predicts the
                    # first generated token
                    r.generated.append(int(nxt[i]))
                    out.append((r.uid, int(nxt[i])))
                    self.stats.tokens_generated += 1
            else:
                r.generated.append(int(nxt[i]))
                out.append((r.uid, int(nxt[i])))
                self.stats.tokens_generated += 1
            if r.done or self.pos[i] >= self.max_len - 1:
                self.active[i] = None
                self.stats.requests_completed += 1
        self.stats.ticks += 1
        self.stats.occupancy_sum += occ / self.slots
        return out

    def run_until_drained(self, max_ticks: int = 10_000) -> EngineStats:
        for _ in range(max_ticks):
            if not self.queue and self._occupancy() == 0:
                break
            self.tick()
        return self.stats
