"""Post-compile HLO analysis: collective-byte extraction with while-loop
trip-count propagation, plus the three-term roofline.

The compiled module text (post SPMD partitioning) contains per-device shapes.
Collectives inside scan bodies appear once in the text but execute
`known_trip_count` times — XLA annotates the while op's backend_config with
the trip count, which we propagate down the call graph (nested scans
multiply).

Byte convention per device per execution:
  all-gather        : result bytes x (n-1)/n        ~ result bytes
  reduce-scatter    : operand bytes ~ result x n    -> result bytes x (n-1)
  all-reduce        : 2 x payload (ring RS+AG)
  all-to-all        : result bytes x (n-1)/n
  collective-permute: result bytes
We conservatively use the simple forms below and report per-op detail so any
convention can be recomputed.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

# TPU v5e-class hardware constants (per chip), per the assignment.
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024,128]{...}' -> bytes. Tuple shapes are summed."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    bytes_per_exec: int
    executions: int
    computation: str

    @property
    def total_bytes(self) -> float:
        mult = 2.0 if self.kind == "all-reduce" else 1.0
        return mult * self.bytes_per_exec * self.executions


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*.*)?\{", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY") or line.startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _find_entry(hlo_text: str, comps: Dict[str, List[str]]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    return m.group(1) if m else next(iter(comps))


def parse_collectives(hlo_text: str) -> List[CollectiveRecord]:
    comps = _split_computations(hlo_text)
    entry = _find_entry(hlo_text, comps)

    # call graph edges with multipliers
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            wm = re.search(r"while\(.*?body=%?([\w\.\-]+)", line)
            if wm:
                trip = 1
                tm = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)"?', line)
                if tm:
                    trip = int(tm.group(1))
                edges[name].append((wm.group(1), trip))
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if cm:
                    edges[name].append((cm.group(1), trip))
                continue
            for cm in re.finditer(r"(?:calls=|to_apply=)%?([\w\.\-]+)", line):
                edges[name].append((cm.group(1), 1))
            bm = re.findall(r"branch_computations=\{([^}]*)\}", line)
            for group in bm:
                for c in re.findall(r"%?([\w\.\-]+)", group):
                    edges[name].append((c, 1))

    # propagate multipliers from entry
    mult: Dict[str, int] = defaultdict(int)
    mult[entry] = 1
    stack = [entry]
    seen_pairs = set()
    while stack:
        cur = stack.pop()
        for child, k in edges.get(cur, []):
            if (cur, child) in seen_pairs:
                continue
            seen_pairs.add((cur, child))
            mult[child] += mult[cur] * k
            stack.append(child)

    records: List[CollectiveRecord] = []
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for line in lines:
            cm = re.search(
                r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
                r"(?:-start)?\(", line)
            if not cm:
                continue
            if re.search(r"(all-reduce|all-gather|all-to-all|collective-permute|reduce-scatter)-done", line):
                continue
            shape_str, kind = cm.group(1), cm.group(2)
            records.append(CollectiveRecord(kind, _shape_bytes(shape_str), m, name))
    return records


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    recs = parse_collectives(hlo_text)
    by_kind: Dict[str, float] = defaultdict(float)
    for r in recs:
        by_kind[r.kind] += r.total_bytes
    return sum(by_kind.values()), dict(by_kind)


def max_collective_buffer_bytes(hlo_text: str, kind: str) -> int:
    """Largest single lowered buffer (shape bytes of one op execution) of a
    collective kind — the peak per-op buffer the schedule materializes, e.g.
    the all-to-all send buffer that bucketed p2p caps shrink or the
    all-gather table that feature chunking shrinks."""
    return max((r.bytes_per_exec for r in parse_collectives(hlo_text)
                if r.kind == kind), default=0)


def executable_summary(compiled) -> Dict[str, object]:
    """Static telemetry facts for ONE compiled executable: collective wire
    bytes (total + by kind) parsed from the optimized HLO, the largest
    single collective buffer, and XLA's per-device peak memory.  Feed the
    result to ``Telemetry.attach_executable(name, ...)`` so a run summary
    is self-describing: measured spans/counters next to the compiler-static
    numbers they should explain."""
    text = compiled.as_text()
    total, by_kind = collective_bytes(text)
    out: Dict[str, object] = {
        "collective_bytes_per_device": int(total),
        "collective_bytes_by_kind": {k: int(v) for k, v in by_kind.items()},
        "max_collective_buffer_bytes": max(
            (int(r.bytes_per_exec) for r in parse_collectives(text)),
            default=0),
    }
    try:
        from repro.compat import peak_memory_in_bytes

        out["peak_memory_bytes"] = peak_memory_in_bytes(
            compiled.memory_analysis())
    except Exception:  # pragma: no cover — backend without memory stats
        pass
    return out


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_raw: float
    analytic_flops: float
    useful_ratio: float  # MODEL_FLOPS / analytic flops
    dominant: str

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(*, analytic_flops: float, chips: int, hbm_bytes_per_chip: float,
                   collective_bytes_per_chip: float, model_flops: float,
                   hlo_flops_raw: float) -> Roofline:
    compute_s = analytic_flops / (chips * PEAK_FLOPS_BF16)
    memory_s = hbm_bytes_per_chip / HBM_BW
    coll_s = collective_bytes_per_chip / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(compute_s, memory_s, coll_s, model_flops, hlo_flops_raw,
                    analytic_flops, model_flops / max(analytic_flops, 1.0), dominant)
