"""Distributed training driver: sharded train state, train_step builder, and a
CLI training loop (used by examples/ and the multi-pod dry-run).
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_latest, save_checkpoint
from repro.configs import get_config, get_shape, get_smoke_config
from repro.data.pipeline import batch_logical_axes, make_batch, synthetic_token_stream
from repro.launch.sharding import make_rules, sharding_for_tree, use_rules
from repro.models import transformer as T
from repro.optim import Optimizer, clip_by_global_norm, cosine_schedule, make_optimizer
from repro.utils import get_logger, human_count, tree_num_params

log = get_logger("repro.train")


def make_train_state_specs(cfg, optimizer: Optimizer):
    """Abstract state + logical axes (no allocation)."""
    abs_params = T.abstract_params(cfg)
    p_axes = T.param_logical_axes(cfg)
    abs_opt = jax.eval_shape(optimizer.init, abs_params)
    o_axes = optimizer.state_logical_axes(p_axes, abs_params)
    state = {"params": abs_params, "opt": abs_opt,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"params": p_axes, "opt": o_axes, "step": ()}
    return state, axes


def make_train_step(cfg, optimizer: Optimizer, *, clip_norm: float = 1.0, window: int = 0):
    def train_step(state, batch):
        def lf(params):
            return T.loss_fn(cfg, params, batch, window=window)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt2 = optimizer.update(grads, state["opt"], state["params"], state["step"])
        params2 = jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                         state["params"], updates)
        new_state = {"params": params2, "opt": opt2, "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def init_train_state(cfg, optimizer: Optimizer, key):
    params = T.init_params(cfg, key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_sharded_train_step(cfg, optimizer: Optimizer, mesh, shape, *,
                            rules_overrides=None, clip_norm: float = 1.0,
                            window: int = 0, donate: bool = True):
    """Returns (jitted step fn wrapped in the rules context, state sharding,
    batch sharding, rules)."""
    rules = make_rules(cfg, mesh, rules_overrides)
    _, state_axes = make_train_state_specs(cfg, optimizer)
    state_sh = sharding_for_tree(state_axes, mesh, rules)
    batch_axes = batch_logical_axes(cfg, shape)
    batch_sh = sharding_for_tree(batch_axes, mesh, rules)
    raw_step = make_train_step(cfg, optimizer, clip_norm=clip_norm, window=window)

    def wrapped(state, batch):
        with use_rules(mesh, rules):
            return raw_step(state, batch["batch"] if "batch" in batch else batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_sh, batch_sh, rules


def default_optimizer(cfg, *, base_lr=3e-4, warmup=100, total=10000) -> Optimizer:
    return make_optimizer(cfg.optimizer, cosine_schedule(base_lr, warmup, total))


# ---------------------------------------------------------------------------
# CLI loop (single-host; real meshes come from the dry-run / cluster launch)
# ---------------------------------------------------------------------------


def run_training(arch: str, steps: int, *, smoke: bool = True, batch: int = 8,
                 seq: int = 128, log_every: int = 10, ckpt_dir: Optional[str] = None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    optimizer = default_optimizer(cfg, total=steps)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, optimizer, key)
    log.info("arch=%s params=%s", cfg.name, human_count(tree_num_params(state["params"])))
    step_fn = jax.jit(make_train_step(cfg, optimizer))
    stream = synthetic_token_stream(cfg.vocab_size, batch, seq)
    t0 = time.time()
    losses = []
    for i in range(steps):
        b = next(stream)
        if cfg.input_mode == "embeddings" and not cfg.is_encoder_decoder:
            # stub frontend: embed tokens through a fixed random projection
            emb = jax.nn.one_hot(b["tokens"] % cfg.d_model, cfg.d_model, dtype=jnp.bfloat16)
            b = {"embeds": emb, "labels": b["labels"], "positions": b["positions"]}
        elif cfg.is_encoder_decoder:
            emb = jax.nn.one_hot(b["tokens"] % cfg.d_model, cfg.d_model, dtype=jnp.bfloat16)
            b = dict(b, enc_embeds=emb)
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0:
            log.info("step %d loss %.4f grad_norm %.3f (%.2fs)", i, losses[-1],
                     float(metrics["grad_norm"]), time.time() - t0)
        if ckpt_dir and (i + 1) % 100 == 0:
            save_checkpoint(ckpt_dir, i + 1, jax.device_get(state))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    losses = run_training(args.arch, args.steps, smoke=not args.full_config,
                          batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir)
    log.info("first loss %.4f final loss %.4f", losses[0], losses[-1])


if __name__ == "__main__":
    main()
