import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization). Placeholder host devices exist ONLY for this
# dry-run; smoke tests and benchmarks see the real single device.

"""Multi-pod dry-run: for every (architecture x input shape x mesh), AOT-lower
and compile the production step function against ShapeDtypeStruct inputs
(no allocation), then record memory analysis, cost analysis, and the
collective schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import cost_analysis as compat_cost_analysis
from repro.compat import peak_memory_in_bytes as compat_peak_memory
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_shape, supports_shape
from repro.data.pipeline import batch_logical_axes, input_specs
from repro.launch import flops as flops_lib
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import decode_rules_overrides, serve_options_for
from repro.launch.sharding import make_rules, sharding_for_tree, use_rules
from repro.launch.train import default_optimizer, make_train_state_specs
from repro.models import transformer as T
from repro.models.kvcache import cache_bytes, cache_logical_axes
from repro.optim import clip_by_global_norm
from repro.utils import get_logger, human_bytes, human_count, tree_bytes

log = get_logger("repro.dryrun")


def _lower_pair(cfg, shape, mesh, *, extra_rules: Optional[Dict] = None,
                window: int = 0, opts_set: frozenset = frozenset()):
    """Build + lower + compile the step for one (arch, shape, mesh).
    Returns (compiled, lowered, meta). opts_set: perf-iteration levers
    ('grads_constraint', 'sp', 'moe_dedup', 'mla_flashdecode')."""
    specs = input_specs(cfg, shape)
    rules_ov = dict(extra_rules or {})
    if "mtp" in opts_set and shape.kind in ("train", "prefill"):
        # manual tensor-parallel blocks with explicit bf16 AG/RS collectives
        rules_ov.setdefault("act_res_seq", "model")
        rules_ov.setdefault("_manual_tp", True)
    if "sp" in opts_set and shape.kind == "train":
        # Megatron-SP: shard the residual stream's seq dim over 'model' so the
        # per-layer activation collectives become RS/AG pairs instead of ARs.
        rules_ov.setdefault("act_res_seq", "model")
    if "mla_flashdecode" in opts_set and shape.kind == "decode" and cfg.use_mla:
        rules_ov.setdefault("act_kv_seq", ("model",))
        rules_ov.setdefault("kv_lora", None)
    if "moe2d" in opts_set and shape.kind == "decode" and cfg.num_experts:
        # weights-stationary 2D expert layout for decode
        rules_ov.setdefault("expert_embed", None)
        rules_ov.setdefault("expert_mlp", "data")
        rules_ov.setdefault("_moe_2d", True)
    if shape.kind == "decode":
        rules_ov = dict(decode_rules_overrides(cfg, shape, mesh), **rules_ov)
    rules = make_rules(cfg, mesh, rules_ov)
    p_axes = T.param_logical_axes(cfg)
    params_sh = sharding_for_tree(p_axes, mesh, rules)
    meta: Dict[str, Any] = {}

    if shape.kind == "train":
        optimizer = default_optimizer(cfg)
        state_abs, state_axes = make_train_state_specs(cfg, optimizer)
        state_sh = sharding_for_tree(state_axes, mesh, rules)
        batch_sh = sharding_for_tree(batch_logical_axes(cfg, shape), mesh, rules)

        def step(state, inputs):
            with use_rules(mesh, rules):
                batch = inputs["batch"]

                def lf(p):
                    if "bf16_gather" in opts_set:
                        # cast BEFORE the FSDP all-gathers so weights cross
                        # the wire in bf16 (grads still flow to f32 masters)
                        p = jax.tree_util.tree_map(
                            lambda a: a.astype(jnp.bfloat16)
                            if a.dtype == jnp.float32 else a, p)
                    return T.loss_fn(cfg, p, batch, window=window)

                (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
                if "grads_constraint" in opts_set:
                    # pin grads to the parameter shardings so GSPMD lowers the
                    # data-parallel reduction as reduce-scatter, not all-reduce
                    grads = jax.lax.with_sharding_constraint(
                        grads, sharding_for_tree(p_axes, mesh, rules))
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                updates, opt2 = optimizer.update(grads, state["opt"], state["params"],
                                                 state["step"])
                params2 = jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                                 state["params"], updates)
                return ({"params": params2, "opt": opt2, "step": state["step"] + 1},
                        dict(metrics, loss=loss, grad_norm=gnorm))

        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        lowered = jitted.lower(state_abs, specs)
        meta["state_bytes"] = tree_bytes(state_abs)
    elif shape.kind == "prefill":
        batch_sh = sharding_for_tree(batch_logical_axes(cfg, shape), mesh, rules)

        def step(params, inputs):
            with use_rules(mesh, rules):
                return T.prefill(cfg, params, inputs["batch"], window=window)

        jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(T.abstract_params(cfg), specs)
    else:  # decode
        opts = serve_options_for(cfg, shape, mesh)
        opts = dataclasses_replace(opts, window=window) if window else opts
        enc_len = shape.seq_len // 2 if cfg.is_encoder_decoder else 0
        c_axes = cache_logical_axes(cfg, shape.global_batch, shape.seq_len, enc_len)
        cache_sh = sharding_for_tree(c_axes, mesh, rules)
        tok_sh = sharding_for_tree(("act_batch", None), mesh, rules)
        logits_sh = sharding_for_tree(("act_batch", "act_vocab"), mesh, rules)

        def step(params, cache, tokens, pos):
            with use_rules(mesh, rules):
                return T.serve_step(cfg, params, cache, tokens, pos, opts)

        jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh, None),
                         out_shardings=(logits_sh, cache_sh), donate_argnums=(1,))
        lowered = jitted.lower(T.abstract_params(cfg), specs["cache"], specs["tokens"],
                               specs["pos"])
        meta["cache_bytes"] = cache_bytes(cfg, shape.global_batch, shape.seq_len, enc_len)
        meta["seq_sharded_cache"] = opts.seq_sharded_cache
    compiled = lowered.compile()
    return compiled, lowered, meta


def dataclasses_replace(opts, **kw):
    import dataclasses

    return dataclasses.replace(opts, **kw)


def run_pair(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Optional[str],
             window: int = 0, save_hlo: bool = False,
             extra_rules: Optional[Dict] = None, tag: str = "",
             opts_set: frozenset = frozenset(), cfg_overrides: Optional[Dict] = None
             ) -> Dict[str, Any]:
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, why = supports_shape(cfg, shape)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "status": "ok",
    }
    if not ok:
        result.update(status="skipped", reason=why)
        log.info("SKIP  %-50s %s", name, why)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(out_dir, name + ".json"), "w") as f:
                json.dump(result, f, indent=1)
        return result
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(mesh.devices.shape))
        compiled, lowered, meta = _lower_pair(cfg, shape, mesh, window=window,
                                              extra_rules=extra_rules,
                                              opts_set=opts_set)
        ma = compiled.memory_analysis()
        ca = compat_cost_analysis(compiled)
        txt = compiled.as_text()
        coll_total, coll_by_kind = collective_bytes(txt)
        analytic = flops_lib.step_flops(cfg, shape, window=window)
        model_fl = flops_lib.model_flops_6nd(cfg, shape)
        param_bytes_total = tree_bytes(T.abstract_params(cfg))
        hbm_traffic = flops_lib.hbm_traffic_bytes(
            cfg, shape, chips=chips, param_bytes_total=param_bytes_total,
            cache_bytes_total=meta.get("cache_bytes", 0))
        rl = roofline_terms(
            analytic_flops=analytic.total, chips=chips,
            hbm_bytes_per_chip=hbm_traffic,
            collective_bytes_per_chip=coll_total,
            model_flops=model_fl, hlo_flops_raw=float(ca.get("flops", 0.0)))
        result.update(
            compile_s=round(time.time() - t0, 1),
            chips=chips,
            param_bytes_total=param_bytes_total,
            state_bytes=meta.get("state_bytes"),
            cache_bytes=meta.get("cache_bytes"),
            seq_sharded_cache=meta.get("seq_sharded_cache"),
            memory={
                "argument_bytes_per_device": ma.argument_size_in_bytes,
                "output_bytes_per_device": ma.output_size_in_bytes,
                "temp_bytes_per_device": ma.temp_size_in_bytes,
                "peak_bytes_per_device": compat_peak_memory(ma),
                "alias_bytes_per_device": ma.alias_size_in_bytes,
            },
            cost_analysis={k: ca[k] for k in ("flops", "bytes accessed") if k in ca},
            collective_bytes_per_device=coll_total,
            collective_by_kind=coll_by_kind,
            analytic_flops=analytic.total,
            analytic_detail=analytic.detail,
            model_flops_6nd=model_fl,
            hbm_traffic_bytes_per_chip=hbm_traffic,
            roofline=rl.as_dict(),
        )
        fits = (ma.argument_size_in_bytes + ma.temp_size_in_bytes) <= 16e9
        result["fits_16g_hbm"] = bool(fits)
        log.info(
            "OK    %-50s %5.1fs args=%s temp=%s coll=%s dom=%s t_dom=%.1fms",
            name, result["compile_s"],
            human_bytes(ma.argument_size_in_bytes), human_bytes(ma.temp_size_in_bytes),
            human_bytes(coll_total), rl.dominant,
            1e3 * max(rl.compute_s, rl.memory_s, rl.collective_s))
        if save_hlo and out_dir:
            with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
                f.write(txt)
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the signal
        result.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-3000:])
        log.error("FAIL  %-50s %s: %s", name, type(e).__name__, str(e)[:200])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(result, f, indent=1, default=float)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="", help="comma list: grads_constraint,sp,moe_dedup,mla_flashdecode")
    ap.add_argument("--set", default="", help="cfg overrides k=v,k=v (ints/floats)")
    args = ap.parse_args()
    opts_set = frozenset(filter(None, args.opt.split(",")))
    cfg_overrides = {}
    for kv in filter(None, args.set.split(",")):
        k, v = kv.split("=")
        try:
            cfg_overrides[k] = int(v)
        except ValueError:
            try:
                cfg_overrides[k] = float(v)
            except ValueError:
                cfg_overrides[k] = v
    archs = list(ASSIGNED_ARCHS) if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape in (None, "all")) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_pair(arch, shape, multi_pod=mp, out_dir=args.out,
                                        window=args.window, save_hlo=args.save_hlo,
                                        tag=args.tag, opts_set=opts_set,
                                        cfg_overrides=cfg_overrides or None))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    log.info("dry-run complete: %d ok, %d skipped, %d FAILED", n_ok, n_skip, n_fail)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
