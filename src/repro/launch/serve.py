"""Serving driver: sharded serve_step / prefill builders and a batched-request
decode loop used by examples/serve_llm.py.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape, get_smoke_config
from repro.data.pipeline import batch_logical_axes
from repro.launch.sharding import make_rules, sharding_for_tree, use_rules
from repro.models import transformer as T
from repro.models.kvcache import cache_logical_axes, init_cache
from repro.models.transformer import ServeOptions
from repro.utils import get_logger

log = get_logger("repro.serve")


def decode_rules_overrides(cfg, shape, mesh) -> Dict[str, Any]:
    """Shape-dependent rule overrides for decode:
    - long-context batch=1: batch unshardable -> shard the KV-cache sequence
      axis over 'data' (flash-decode path).
    - otherwise shard batch, replicate cache seq."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_ways = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.global_batch % batch_ways != 0:
        return {"act_batch": None, "act_kv_seq": ("data",)}
    return {}


def serve_options_for(cfg, shape, mesh) -> ServeOptions:
    ov = decode_rules_overrides(cfg, shape, mesh)
    return ServeOptions(seq_sharded_cache=("act_kv_seq" in ov and ov["act_kv_seq"] is not None))


def make_sharded_serve_step(cfg, mesh, shape, *, opts: Optional[ServeOptions] = None,
                            donate: bool = True):
    rules_ov = decode_rules_overrides(cfg, shape, mesh)
    rules = make_rules(cfg, mesh, rules_ov)
    opts = opts if opts is not None else serve_options_for(cfg, shape, mesh)
    enc_len = shape.seq_len // 2 if cfg.is_encoder_decoder else 0
    c_axes = cache_logical_axes(cfg, shape.global_batch, shape.seq_len, enc_len)
    cache_sh = sharding_for_tree(c_axes, mesh, rules)
    tok_sh = sharding_for_tree(("act_batch", None), mesh, rules)
    logits_sh = sharding_for_tree(("act_batch", "act_vocab"), mesh, rules)

    def wrapped(params, cache, tokens, pos):
        with use_rules(mesh, rules):
            return T.serve_step(cfg, params, cache, tokens, pos, opts)

    from repro.models.transformer import param_logical_axes

    params_sh = sharding_for_tree(param_logical_axes(cfg), mesh, rules)
    jitted = jax.jit(
        wrapped,
        in_shardings=(params_sh, cache_sh, tok_sh, None),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, params_sh, cache_sh, rules, opts


def greedy_decode(cfg, params, prompt_tokens: jnp.ndarray, max_new: int,
                  *, max_len: Optional[int] = None, temperature: float = 0.0,
                  key=None):
    """Single-host greedy/sampling decode for the examples: prefill the prompt
    token-by-token then generate max_new tokens. Returns [B, max_new]."""
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    cache = init_cache(cfg, B, max_len, enc_len=max(S0, 1))
    step = jax.jit(lambda p, c, t, pos: T.serve_step(cfg, p, c, t, pos))
    tok = prompt_tokens[:, :1]
    out = []
    logits = None
    for i in range(S0 + max_new - 1):
        logits, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
        if i + 1 < S0:
            tok = prompt_tokens[:, i + 1 : i + 2]
        else:
            if temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                # keep serve_step's int32 token contract: categorical returns
                # the default int dtype (int64 under x64), and feeding that
                # back would retrigger compilation of the jitted step
                tok = (jax.random.categorical(sub, logits / temperature)
                       [:, None].astype(jnp.int32))
            else:
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.ones((args.batch, args.prompt_len), jnp.int32)
    t0 = time.time()
    toks = greedy_decode(cfg, params, prompt, args.max_new)
    log.info("decoded %s tokens in %.2fs: %s", toks.shape, time.time() - t0,
             np.asarray(toks)[0, :8])


if __name__ == "__main__":
    main()
