"""GNN serving driver: the two inference tiers over a trained engine.

* THROUGHPUT — ``DistGNNEngine.infer_full_graph``: one O(L) layer-wise
  sweep produces final-layer embeddings for EVERY vertex (the production
  answer to neighbor explosion), wire bytes accounted into
  CommStats.inference_bytes and cross-checked against the engine's own
  ``inference_bytes_per_sweep``.
* LATENCY — ``GNNQueryEngine`` (core/serving.py): a persistent K-target
  query server on the padded node-wise sampler path; one compile, request
  coalescing, resident feature cache as the hot set.  Reports qps and
  p50/p99 per-query latency over a synthetic query stream.

Run with forced host devices to see real collectives on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve_gnn --exec p2p --queries 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.engine import (
    EXECUTION_MODELS,
    GNN_MODELS,
    DistGNNEngine,
    EngineConfig,
)
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine
from repro.utils import get_logger

log = get_logger("repro.serve_gnn")


def build_engine(args, g):
    # vertex_cut mini-batch sampling is a ROADMAP follow-up: the latency tier
    # (node-wise query serving) is edge-cut; the layer-wise sweep runs under
    # BOTH families via the full-graph exchange plan.
    vc = args.partition_family == "vertex_cut"
    cfg = EngineConfig(execution=args.exec, model=args.model,
                       partition_family=args.partition_family,
                       vertex_cut=args.vertex_cut,
                       batching="full_graph" if vc else "node_wise",
                       batch_size=args.batch_size,
                       fanouts=tuple(int(x) for x in args.fanouts.split(",")),
                       cache_policy="none" if vc else args.cache,
                       cache_capacity=0 if vc else args.cache_capacity)
    n_dev = len(jax.devices())
    k = args.parts or n_dev
    assert k <= n_dev, f"need {k} devices, have {n_dev} (set XLA_FLAGS)"
    mesh = jax.make_mesh((k,), ("w",))
    return DistGNNEngine(g, mesh=mesh, cfg=cfg)


def run_sweep(eng, params, *, oracle_check=False):
    """Throughput tier: timed layer-wise full-graph sweep."""
    t0 = time.perf_counter()
    H = eng.infer_full_graph(params=params)
    wall = time.perf_counter() - t0
    emb = eng.global_embeddings(H)
    bytes_model = eng.inference_bytes_per_sweep()
    log.info("layer-wise sweep: %d vertices -> [%d, %d] embeddings in %.3fs "
             "(%.3f MB/sweep on the wire, CommStats.inference_bytes=%.3f MB)",
             eng.g.num_vertices, emb.shape[0], emb.shape[1], wall,
             bytes_model / 1e6, eng.comm_stats.inference_bytes / 1e6)
    if oracle_check:
        ref = eng.global_embeddings(eng.infer_full_graph(params=params,
                                                         reference=True))
        err = float(np.max(np.abs(emb - ref)))
        log.info("sweep oracle gap (max |dist - ref|) = %.2e", err)
        assert err <= 1e-4, f"sweep diverged from reference: {err}"
    return emb, wall


def run_query_stream(qe, *, num_queries, targets_per_query, seed=0):
    """Latency tier: a stream of K-target queries through the query engine
    (each flush answers one request here; coalescing is exercised by the
    serving test tier)."""
    rng = np.random.default_rng(seed)
    V = qe.engine.g.num_vertices
    qe.query(rng.choice(V, size=targets_per_query, replace=False))  # warmup
    qe.stats.latencies_s.clear()
    qe.stats.queries = 0
    for _ in range(num_queries):
        qe.query(rng.choice(V, size=targets_per_query, replace=False))
    s = qe.stats
    log.info("query stream: %d queries x %d targets -> %.1f qps, "
             "p50=%.2fms p99=%.2fms (%d serve rounds, %d compiles)",
             num_queries, targets_per_query, s.qps(),
             s.percentile_ms(50), s.percentile_ms(99), s.rounds,
             qe.num_compiles())
    assert qe.num_compiles() == 1, "serve step recompiled"
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", default="p2p", choices=list(EXECUTION_MODELS))
    ap.add_argument("--model", default="gcn", choices=list(GNN_MODELS))
    ap.add_argument("--partition-family", default="edge_cut",
                    choices=["edge_cut", "vertex_cut"])
    ap.add_argument("--vertex-cut", default="cartesian2d",
                    choices=["random", "cartesian2d", "libra"])
    ap.add_argument("--batch-size", type=int, default=16,
                    help="per-device query-round target cap")
    ap.add_argument("--fanouts", default="4,4")
    ap.add_argument("--cache", default="static_degree",
                    help="serving hot-set policy (engine cache policies)")
    ap.add_argument("--cache-capacity", type=int, default=32)
    ap.add_argument("--parts", type=int, default=0, help="0 = all devices")
    ap.add_argument("--vertices", type=int, default=512)
    ap.add_argument("--train-steps", type=int, default=10,
                    help="mini-batch steps to get non-trivial params")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--targets-per-query", type=int, default=8)
    ap.add_argument("--oracle-check", action="store_true")
    args = ap.parse_args()

    g = sbm_graph(args.vertices, num_blocks=8, p_in=0.05, p_out=0.003, seed=0)
    eng = build_engine(args, g)
    log.info("engine: model=%s exec=%s family=%s k=%d (nb=%d, caps=%s)",
             args.model, args.exec, args.partition_family, eng.k, eng.nb,
             getattr(eng, "caps", "-"))
    if eng.cfg.batching == "node_wise":
        state, losses, _ = eng.run_epoch_minibatch(args.train_steps)
        params = state["params"]
    else:  # vertex_cut: full-graph steps (sweep tier only)
        step = eng.make_step()
        state = eng.init_state()
        losses = []
        for _ in range(args.train_steps):
            state, metrics, _ = step(state)
            losses.append(float(metrics["loss"]))
        params = state["params"]
    log.info("trained %d steps: loss %.4f -> %.4f",
             args.train_steps, losses[0], losses[-1])

    run_sweep(eng, params, oracle_check=args.oracle_check)
    if eng.cfg.batching == "node_wise":
        qe = GNNQueryEngine(eng, params)
        run_query_stream(qe, num_queries=args.queries,
                         targets_per_query=args.targets_per_query)
    else:
        log.info("query tier skipped: vertex_cut mini-batch sampling is a "
                 "ROADMAP follow-up (latency tier is edge-cut)")


if __name__ == "__main__":
    main()
