import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Same contract as dryrun.py: placeholder devices before any other import.

"""Production-scale dry-run of the PAPER'S OWN workload: full-graph GCN
training (1M vertices, ELLPACK adjacency) on the 256-chip single-pod mesh
and the 512-chip multi-pod mesh.

Vertices (and their features/ELL rows) are sharded over every chip; the
neighbor aggregation H[ids] gather under GSPMD lowers to the broadcast-style
embedding exchange of the survey's §7.1.1 (all-gather of the row-sharded H) —
the paper-faithful 1D execution model at production scale. Records the same
memory/cost/collective artifacts as the transformer dry-run.

  PYTHONPATH=src python -m repro.launch.dryrun_gnn [--multi-pod]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis as compat_cost_analysis
from repro.compat import peak_memory_in_bytes as compat_peak_memory
from repro.configs.gcn_paper import CONFIG as GNN_CFG
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.utils import get_logger, human_bytes

log = get_logger("repro.dryrun_gnn")


def gcn_train_step_fn(cfg):
    """ELL full-graph GCN train step: params pytree, graph (ids, mask), X, y."""

    def loss_fn(params, ids, mask, X, y, train_w):
        H = X
        L = len(params["w"])
        for l in range(L):
            gathered = jnp.take(H, ids, axis=0)  # [V, K, D] — the §7.1 exchange
            agg = (mask[..., None] * gathered).sum(1)
            deg = jnp.maximum(mask.sum(1, keepdims=True), 1.0)
            H = (agg / deg + H) @ params["w"][l] + params["b"][l]
            if l < L - 1:
                H = jax.nn.relu(H)
        lse = jax.scipy.special.logsumexp(H, axis=-1)
        ll = jnp.take_along_axis(H, y[:, None], axis=-1)[:, 0]
        return ((lse - ll) * train_w).sum() / jnp.maximum(train_w.sum(), 1.0)

    def step(params, ids, mask, X, y, train_w):
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, mask, X, y, train_w)
        params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
        return params, loss

    return step


def gcn_p2p_step_fn(cfg, mesh, cap: int):
    """Selective-P2P full-graph GCN step (survey §7.1.2 at production scale):
    instead of all-gathering H, each device ships only `cap` boundary rows per
    destination (the plan arrays are ShapeDtypeStruct inputs — a real
    deployment builds them from the partitioner's boundary sets; `cap` is set
    from the measured edge-cut fraction). Aggregation looks rows up in
    concat(local H, received rows) via a pre-remapped ELL table."""
    axes = mesh.axis_names
    n_dev = int(np.prod(mesh.devices.shape))

    def loss_fn(params, ids_local, mask, X, y, train_w, send_plan):
        # all leaves arrive device-local under shard_map
        H = X
        L = len(params["w"])
        for l in range(L):
            send = jnp.take(H, send_plan[0], axis=0)  # [n_dev, cap, D]
            recv = jax.lax.all_to_all(send, axes, split_axis=0, concat_axis=0)
            table = jnp.concatenate([H, recv.reshape(-1, H.shape[1])], axis=0)
            gathered = jnp.take(table, ids_local, axis=0)  # [V_l, K, D]
            agg = (mask[..., None] * gathered).sum(1)
            deg = jnp.maximum(mask.sum(1, keepdims=True), 1.0)
            Hn = (agg / deg + H) @ params["w"][l] + params["b"][l]
            H = jax.nn.relu(Hn) if l < L - 1 else Hn
        lse = jax.scipy.special.logsumexp(H, axis=-1)
        ll = jnp.take_along_axis(H, y[:, None], axis=-1)[:, 0]
        loss = ((lse - ll) * train_w).sum()
        return jax.lax.psum(loss, axes) / jnp.maximum(
            jax.lax.psum(train_w.sum(), axes), 1.0)

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    row = P(axes)
    rep = P()

    def step(params, ids_local, mask, X, y, train_w, send_plan):
        def lf(p):
            return loss_fn(p, ids_local, mask, X, y, train_w, send_plan)

        loss, grads = jax.value_and_grad(lf)(params)
        grads = jax.tree_util.tree_map(lambda g: jax.lax.pmean(g, axes), grads)
        params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
        return params, loss

    return shard_map(
        step, mesh=mesh,
        in_specs=({"w": [rep, rep, rep], "b": [rep, rep, rep]},
                  row, row, row, row, row, P(axes, None, None)),
        out_specs=({"w": [rep, rep, rep], "b": [rep, rep, rep]}, rep),
        check_vma=False)


def _partition_families_entry(g, gname, chips, dims):
    """One BENCH_partition_families config row: edge-cut (metis_like / hash)
    vs vertex-cut (random / cartesian2d / libra) vs the hybrid
    degree-threshold sweep ({p90, p95, p99, inf} over metis_like masters),
    total + bottleneck bytes from the standalone cost models."""
    from repro.core.engine import EngineConfig
    from repro.core.partition.cost_models import (
        edge_cut_halo_bytes_per_step,
        edge_cut_halo_device_bytes,
        hybrid_bytes_per_step,
        replica_sync_bytes_per_step,
        replica_sync_device_bytes,
    )
    from repro.core.partition.edge_cut import PARTITIONERS
    from repro.core.partition.hybrid_cut import HybridLayout
    from repro.core.partition.vertex_cut import VERTEX_CUTS
    from repro.core.partition.vertex_layout import build_vertex_layout

    deg = g.degree().astype(np.float64)
    thresholds = dict(p90=float(np.percentile(deg, 90)),
                      p95=float(np.percentile(deg, 95)),
                      p99=float(np.percentile(deg, 99)), inf=np.inf)
    entry = dict(graph=gname, chips=chips, vertices=g.num_vertices,
                 edge_cut={}, vertex_cut={}, hybrid={})
    for pname in ("metis_like", "hash"):
        part = PARTITIONERS[pname](g, chips)
        dev = edge_cut_halo_device_bytes(g, part, dims)
        entry["edge_cut"][pname] = dict(
            total_bytes=edge_cut_halo_bytes_per_step(g, part, dims),
            bottleneck_bytes=int(dev.max()),
            vertex_balance=part.vertex_balance())
    for vname in VERTEX_CUTS:
        vc = VERTEX_CUTS[vname](g, chips)
        lay = build_vertex_layout(g, vc, chips)
        dev = replica_sync_device_bytes(lay, vc.masters, dims)
        entry["vertex_cut"][vname] = dict(
            replication_factor=lay.replication_factor(),
            total_bytes=replica_sync_bytes_per_step(
                lay.rep_count, chips, lay.nv, "p2p", dims),
            bottleneck_bytes=int(dev.max()))
    for tname, thr in thresholds.items():
        lay = HybridLayout(g, chips, EngineConfig(
            partition_family="hybrid", hub_threshold=thr, execution="p2p"))
        dev = lay.device_bytes_per_step("gcn", dims)
        entry["hybrid"][tname] = dict(
            threshold=thr, num_hubs=int(lay.cut.hub.sum()),
            total_bytes=hybrid_bytes_per_step(
                lay.halo_rows_exec if lay.halo_active else 0,
                lay._vc_rows_per_layer if lay.sync_active else 0, dims),
            bottleneck_bytes=int(dev.max()))
    # built-in cross-check: threshold=inf IS the edge-cut dataflow over the
    # same metis_like masters, so the two accountings must agree
    assert (entry["hybrid"]["inf"]["bottleneck_bytes"]
            == entry["edge_cut"]["metis_like"]["bottleneck_bytes"]), entry
    ec = min(v["bottleneck_bytes"] for v in entry["edge_cut"].values())
    vc = min(v["bottleneck_bytes"] for v in entry["vertex_cut"].values())
    hy = min(v["bottleneck_bytes"] for v in entry["hybrid"].values())
    entry["best_edge_cut_bottleneck"] = ec
    entry["best_vertex_cut_bottleneck"] = vc
    entry["best_hybrid_bottleneck"] = hy
    entry["vertex_cut_wins_bottleneck"] = vc < ec
    entry["hybrid_wins_bottleneck"] = hy <= min(ec, vc)
    log.info("%s V=%d %d chips: bottleneck edge-cut %s vs vertex-cut %s vs "
             "hybrid %s (%s)", gname, g.num_vertices, chips,
             human_bytes(ec), human_bytes(vc), human_bytes(hy),
             "hybrid wins" if hy <= min(ec, vc)
             else ("vertex-cut wins" if vc < ec else "edge-cut wins"))
    return entry


def bench_partition_families(out_dir, dims, vertices=2048):
    """Emit BENCH_partition_families.json: per-step comm bytes of the §4
    partition families — edge-cut halo exchange (metis_like / hash),
    vertex-cut replica sync (random / cartesian2d / libra, p2p GAS
    accounting), and the PowerLyra-style hybrid degree-threshold cut (a
    threshold sweep over {p90, p95, p99, inf}) — across {uniform,
    power-law} graphs at {8, 64, 256} chips, plus one double-size power-law
    point at 256 chips.

    Two metrics per config, both from the standalone cost models the engine's
    CommStats are cross-checked against:

      total_bytes       every row that crosses the wire per step.  Edge-cut
                        wins this everywhere: with receiver-side dedup the
                        halo ships each (vertex, consumer) pair once, while
                        GAS replica sync pays gather AND scatter — a
                        structural ~2x.  Reported honestly.
      bottleneck_bytes  max per-device (send+recv) bytes — the straggler
                        that sets the step time at scale.  On skewed
                        power-law graphs a hub's OWNER must ship its rows to
                        up to k-1 consumers; how to beat that depends on the
                        V/chips ratio, and the two assertions below pin one
                        regime each.

    At V/chips = 8 (the base grid's 256-chip power-law point) nearly every
    edge is remote for every vertex, so per-device degree concentration is
    diluted and what wins is bounding + load-balancing ALL traffic by the
    replication factor: the best vertex-cut must beat the best edge-cut (the
    PR-3 finding, still asserted).  At V/chips = 16 (the double-size
    power-law point) the straggler is the hub fan-in itself, and the hybrid
    cut peels exactly that: low-degree vertices keep edge-cut's dedup'd halo
    while only the hubs pay the replication tax — the best hybrid threshold
    must beat BOTH pure families (the ISSUE-10 assertion).  Built-in
    cross-check everywhere: hybrid@inf == edge_cut/metis_like exactly.  On
    the uniform graph there is no hub tail to peel, so hybrid degenerates to
    its edge-cut anchor and the hash partitioner's balance keeps edge-cut
    ahead — reported honestly, not asserted.
    """
    from repro.core.graph import er_graph, powerlaw_graph

    V = min(vertices, 2048)
    result = dict(vertices=V, avg_degree=16, dims=dims, configs=[])
    for gname, gfn in (("uniform", er_graph), ("power_law", powerlaw_graph)):
        g = gfn(V, avg_degree=16, seed=0)
        for chips in (8, 64, 256):
            result["configs"].append(
                _partition_families_entry(g, gname, chips, dims))
    # the hybrid regime point: double the vertices at max chips
    g2 = powerlaw_graph(2 * V, avg_degree=16, seed=0)
    hyb = _partition_families_entry(g2, "power_law", 256, dims)
    result["configs"].append(hyb)
    # write the artifact BEFORE asserting: a failed claim should leave the
    # per-config byte breakdown behind for diagnosis
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_partition_families.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    log.info("OK partition-families bench -> %s", path)
    plaw = [e for e in result["configs"]
            if e["graph"] == "power_law" and e["chips"] == 256
            and e["vertices"] == V][0]
    assert plaw["vertex_cut_wins_bottleneck"], (
        "vertex-cut must beat edge-cut critical-path comm volume on the "
        f"power-law 256-chip config: {plaw}")
    assert hyb["hybrid_wins_bottleneck"], (
        "the best hybrid threshold must beat BOTH pure families' "
        "critical-path comm volume on the double-size power-law 256-chip "
        f"config: {hyb}")
    return path


def run_autotune(args):
    """`--autotune`: enumerate (family, cut, threshold, execution, chunks,
    buckets) plans over the synthetic engine graph with the engines' own
    cost models, choose the predicted-bytes argmin, validate the choice
    against a traced dryrun (2 real train steps on `--autotune-chips`
    forced-host devices; PlanRejected if measured comm.* counters or layout
    imbalance gauges drift past the bound), and write AUTOTUNE_gnn.json."""
    from repro.core.graph import er_graph, powerlaw_graph
    from repro.core.partition.autotune import autotune

    cfg = GNN_CFG
    k = args.autotune_chips
    gfn = powerlaw_graph if args.engine_graph == "powerlaw" else er_graph
    V = min(args.engine_vertices, 4096)
    g = gfn(V, avg_degree=cfg.avg_degree, feature_dim=cfg.feature_dim,
            num_classes=cfg.num_classes, seed=0)
    dims = ([cfg.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
            + [cfg.num_classes])
    mesh = jax.make_mesh((k,), ("w",))
    t0 = time.time()
    plan, report = autotune(g, k, dims, args.engine_model, mesh=mesh)
    val = report["validation"]
    log.info("autotune %s V=%d k=%d model=%s: chose %s of %d candidates — "
             "predicted %s/step (bottleneck %s/device), measured/predicted "
             "ratio %.4f over %d validation steps, %.1fs",
             args.engine_graph, V, k, args.engine_model, plan.label(),
             len(report["candidates"]), human_bytes(plan.predicted_step_bytes),
             human_bytes(plan.predicted_bottleneck_bytes), val["ratio"],
             val["steps"], time.time() - t0)
    for name, b in sorted(val["balance"].items()):
        log.info("  balance %s: claimed %.3f measured %.3f", name,
                 b["claimed"], b["measured"])
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "AUTOTUNE_gnn.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    log.info("OK autotune -> %s", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--protocol", choices=["broadcast", "p2p", "engine"],
                    default="broadcast")
    ap.add_argument("--cut", type=float, default=0.1,
                    help="p2p: boundary fraction per destination pair")
    ap.add_argument("--engine-exec", default="p2p",
                    help="engine: broadcast | ring | p2p")
    ap.add_argument("--engine-model", default="gcn",
                    choices=["gcn", "sage", "gat", "gin"],
                    help="engine: §3 GNN model axis — gat lowers the "
                    "distributed attention step (SDDMM logits + segment-"
                    "softmax; two-pass replica sync under vertex_cut) and "
                    "its exchange ships transformed rows + the attention-"
                    "coefficient column")
    ap.add_argument("--engine-family", default="edge_cut",
                    choices=["edge_cut", "vertex_cut", "hybrid"],
                    help="engine: §4 partition family (vertex_cut lowers the "
                    "replica-sync step and reports replication factor vs "
                    "edge-cut halo bytes; hybrid is the PowerLyra-style "
                    "degree-threshold cut — low-degree halo exchange + hub "
                    "replica sync)")
    ap.add_argument("--hub-threshold", type=float, default=None,
                    help="engine hybrid: degree threshold above which a "
                    "vertex replicates vertex-cut style (default: auto, the "
                    "95th degree percentile; inf = pure edge-cut dataflow, "
                    "0 = pure src-replicating vertex-cut)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the cost-model partition/execution autotuner "
                    "on the synthetic engine graph: enumerate (family, cut, "
                    "threshold, execution, chunks, buckets) plans, choose "
                    "the predicted-bytes argmin, validate it against a "
                    "traced dryrun (PlanRejected past the drift bound), "
                    "print chosen plan + measured/predicted ratio, write "
                    "AUTOTUNE_gnn.json, and exit")
    ap.add_argument("--autotune-chips", type=int, default=8,
                    help="autotune: device count the plan is scored and "
                    "validated for (the validation dryrun trains 2 real "
                    "steps on this many forced-host devices)")
    ap.add_argument("--engine-vertex-cut", default="cartesian2d",
                    choices=["random", "cartesian2d", "libra"],
                    help="engine vertex_cut: which cut builds the layout")
    ap.add_argument("--engine-graph", default="er", choices=["er", "powerlaw"],
                    help="engine: synthetic graph family for the plan build")
    ap.add_argument("--engine-vertices", type=int, default=1 << 14,
                    help="engine: synthetic graph size (the partition plan is "
                    "built host-side from a concrete graph)")
    ap.add_argument("--engine-batching", default="full_graph",
                    help="engine: full_graph | node_wise | layer_wise | "
                    "subgraph — mini-batch modes lower the sampled-batch "
                    "step (static fanout caps + feature cache) instead")
    ap.add_argument("--engine-batch-size", type=int, default=1024,
                    help="engine mini-batch: per-device targets / walk roots")
    ap.add_argument("--engine-cache-capacity", type=int, default=4096,
                    help="engine mini-batch: cached remote feature rows "
                    "per device (static_degree policy)")
    ap.add_argument("--engine-exchange-chunks", type=int, default=1,
                    help="engine: feature-dim chunks for comm/compute "
                    "overlap in the exchange — chunk c+1's collective is "
                    "issued while chunk c feeds the ELL multiply; peak "
                    "gathered-table bytes drop ~chunks/2 x (asserted >= 2x "
                    "on the 256-chip broadcast lowering with >= 4 chunks)")
    ap.add_argument("--engine-trainable-features", action="store_true",
                    help="engine mode: layer-0 rows are learnable embedding "
                    "store rows (sparse-AdamW state enters the lowered step)")
    ap.add_argument("--engine-p2p-buckets", type=int, default=1,
                    help="engine: power-of-two installments splitting the "
                    "p2p all_to_all send caps; the lowered all_to_all "
                    "buffer shrinks ~buckets x (asserted >= 2x when the cap "
                    "actually splits)")
    ap.add_argument("--bench-partition-families", action="store_true",
                    help="emit BENCH_partition_families.json (edge-cut halo "
                    "vs vertex-cut replica-sync vs hybrid degree-threshold "
                    "sweep across graphs x chips) and exit")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    cfg = GNN_CFG
    if args.bench_partition_families:
        dims = ([cfg.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
                + [cfg.num_classes])
        bench_partition_families(args.out, dims,
                                 vertices=args.engine_vertices)
        return
    if args.autotune:
        run_autotune(args)
        return
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    axes = mesh.axis_names  # rows shard over every mesh axis
    row_sh = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    V, K, D, C = cfg.num_vertices, cfg.avg_degree, cfg.feature_dim, cfg.num_classes
    dims = [D] + [cfg.hidden_dim] * (cfg.num_layers - 1) + [C]
    params = {
        "w": [jax.ShapeDtypeStruct((a, b), jnp.float32) for a, b in zip(dims[:-1], dims[1:])],
        "b": [jax.ShapeDtypeStruct((b,), jnp.float32) for b in dims[1:]],
    }
    specs = dict(
        ids=jax.ShapeDtypeStruct((V, K), jnp.int32),
        mask=jax.ShapeDtypeStruct((V, K), jnp.float32),
        X=jax.ShapeDtypeStruct((V, D), jnp.float32),
        y=jax.ShapeDtypeStruct((V,), jnp.int32),
        train_w=jax.ShapeDtypeStruct((V,), jnp.float32),
    )
    in_sh = ({"w": [rep] * (len(dims) - 1), "b": [rep] * (len(dims) - 1)},
             row_sh, row_sh, row_sh, row_sh, row_sh)
    t0 = time.time()
    if args.protocol == "engine":
        # The unified DistGNNEngine step (partition plan + Pallas-ELL local
        # multiply + halo exchange + protocol), lowered on a 1D mesh over all
        # production chips.  The plan needs a concrete graph, so this mode
        # dry-runs a smaller synthetic instance end to end rather than
        # abstract ShapeDtypeStructs.
        from repro.core.engine import DistGNNEngine, EngineConfig
        from repro.core.graph import er_graph, powerlaw_graph

        gfn = powerlaw_graph if args.engine_graph == "powerlaw" else er_graph
        g = gfn(args.engine_vertices, avg_degree=cfg.avg_degree,
                feature_dim=cfg.feature_dim,
                num_classes=cfg.num_classes, seed=0)
        mesh1d = jax.make_mesh((chips,), ("w",))
        minibatch = args.engine_batching != "full_graph"
        ecfg = EngineConfig(
            execution=args.engine_exec, model=args.engine_model,
            hidden=cfg.hidden_dim,
            num_layers=cfg.num_layers, batching=args.engine_batching,
            partition_family=args.engine_family,
            vertex_cut=args.engine_vertex_cut,
            hub_threshold=args.hub_threshold,
            batch_size=args.engine_batch_size,
            fanouts=(4,) * cfg.num_layers,
            layer_sizes=(2 * args.engine_batch_size,) * cfg.num_layers,
            cache_policy="static_degree" if minibatch else "none",
            cache_capacity=args.engine_cache_capacity if minibatch else 0,
            exchange_chunks=args.engine_exchange_chunks,
            p2p_buckets=args.engine_p2p_buckets,
            trainable_features=args.engine_trainable_features)
        eng = DistGNNEngine(g, mesh=mesh1d, cfg=ecfg)
        # run-summary exporter (ISSUE 8): the ad-hoc byte logs below stay for
        # humans; the artifact carries the structured telemetry summary —
        # static per-device layout gauges + the imbalance report + the
        # compiled executable's collective/peak-memory facts
        tel = eng.enable_telemetry()
        if minibatch and args.engine_exec == "p2p":
            # tightened halo cap (PR 2 follow-up): the all_to_all buffer is
            # sized by the MEASURED edge-cut halo, not the worst case caps[0]
            worst = eng.caps[0]
            shrink = worst / eng.fcap
            D = g.features.shape[1]
            log.info("p2p fcap %d (worst-case %d): all_to_all buffer "
                     "%s -> %s per device (%.1fx smaller)",
                     eng.fcap, worst, human_bytes(chips * worst * D * 4),
                     human_bytes(chips * eng.fcap * D * 4), shrink)
            if args.engine_graph == "powerlaw" and chips >= 256:
                assert shrink > 10, (
                    f"measured-halo fcap should shrink the 256-chip "
                    f"all_to_all buffer >10x on the power-law config, "
                    f"got {shrink:.1f}x")
        engine_extra = dict(engine_model=args.engine_model)
        if args.engine_trainable_features:
            engine_extra["trainable_features"] = True
            if not minibatch:
                engine_extra["embed_grad_bytes_per_step"] = \
                    eng._emb_bytes_per_step
                log.info("trainable embeddings: %s/step gradient rows "
                         "routed back to owner shards",
                         human_bytes(eng._emb_bytes_per_step))
            else:
                engine_extra["embed_touched_row_cap"] = eng.tcap
                log.info("trainable embeddings: sparse-AdamW over <= %d "
                         "touched rows per owner per step", eng.tcap)
        if args.engine_family == "vertex_cut":
            from repro.core.partition.cost_models import (
                edge_cut_halo_bytes_per_step,
                edge_cut_halo_device_bytes,
                replica_sync_bytes_per_step,
                replica_sync_device_bytes,
            )
            from repro.core.partition.edge_cut import PARTITIONERS

            dims_g = ([cfg.feature_dim]
                      + [cfg.hidden_dim] * (cfg.num_layers - 1)
                      + [cfg.num_classes])
            ec_part = PARTITIONERS["metis_like"](g, chips)
            m = args.engine_model
            halo = edge_cut_halo_bytes_per_step(g, ec_part, dims_g, model=m)
            halo_max = int(edge_cut_halo_device_bytes(
                g, ec_part, dims_g, model=m).max())
            sync_b = replica_sync_bytes_per_step(
                eng.layout.rep_count, chips, eng.nv, args.engine_exec,
                dims_g, model=m)
            sync_max = int(replica_sync_device_bytes(
                eng.layout, eng.vcut.masters, dims_g, model=m).max())
            engine_extra.update(
                partition_family="vertex_cut",
                vertex_cut=args.engine_vertex_cut,
                replication_factor=eng.layout.replication_factor(),
                replica_sync_bytes_per_step=sync_b,
                replica_sync_bottleneck_bytes=sync_max,
                edge_cut_halo_bytes_per_step=halo,
                edge_cut_halo_bottleneck_bytes=halo_max)
            log.info("vertex-cut %s: replication factor %.2f, replica sync "
                     "%s/step (bottleneck %s) vs edge-cut halo %s/step "
                     "(bottleneck %s)",
                     args.engine_vertex_cut,
                     engine_extra["replication_factor"],
                     human_bytes(sync_b), human_bytes(sync_max),
                     human_bytes(halo), human_bytes(halo_max))
        if args.engine_family == "hybrid":
            from repro.core.partition.cost_models import hybrid_bytes_per_step

            lay = eng.playout
            dims_g = ([cfg.feature_dim]
                      + [cfg.hidden_dim] * (cfg.num_layers - 1)
                      + [cfg.num_classes])
            dev = lay.device_bytes_per_step(args.engine_model, dims_g)
            halo_rows = lay.halo_rows_exec if lay.halo_active else 0
            sync_rows = lay._vc_rows_per_layer if lay.sync_active else 0
            hb = hybrid_bytes_per_step(halo_rows, sync_rows, dims_g,
                                       model=args.engine_model)
            engine_extra.update(
                partition_family="hybrid",
                hub_threshold=float(lay.cut.threshold),
                num_hubs=int(lay.cut.hub.sum()),
                replication_factor=lay.layout.replication_factor(),
                halo_rows_per_pass=int(halo_rows),
                sync_rows_per_layer=int(sync_rows),
                hybrid_bytes_per_step=hb,
                hybrid_bottleneck_bytes=int(dev.max()))
            log.info("hybrid cut thr=%.1f: %d hubs (replication %.2f), "
                     "%d halo rows/pass + %d sync rows/layer -> %s/step "
                     "(bottleneck %s/device)", lay.cut.threshold,
                     engine_extra["num_hubs"],
                     engine_extra["replication_factor"], halo_rows,
                     sync_rows, human_bytes(hb),
                     human_bytes(int(dev.max())))
        compiled = (eng.lower_minibatch_step() if minibatch
                    else eng.lower_step()).compile()
        # --- pipelined-exchange artifacts (ISSUE 4): chunked gathered-table
        # peak + bucketed all_to_all buffer, measured on the LOWERED module
        from repro.core.execution.pipeline_exchange import (
            gathered_table_peak_bytes,
        )
        from repro.launch.hlo_analysis import (
            executable_summary,
            max_collective_buffer_bytes,
        )

        tel.attach_executable(
            "minibatch_train_step" if minibatch else "train_step",
            executable_summary(compiled))
        engine_extra["telemetry"] = tel.run_summary()

        C = args.engine_exchange_chunks
        Dmax = (g.features.shape[1] if minibatch
                else max(eng.dims[:-1]))
        if C > 1 and args.engine_exec == "broadcast":
            mono = gathered_table_peak_bytes(eng.Vp, Dmax, 1)
            chunked = gathered_table_peak_bytes(eng.Vp, Dmax, C)
            red = mono / chunked
            ag = max_collective_buffer_bytes(compiled.as_text(), "all-gather")
            engine_extra.update(
                exchange_chunks=C,
                gathered_table_peak_bytes_monolithic=mono,
                gathered_table_peak_bytes_chunked=chunked,
                gathered_table_reduction=red,
                max_all_gather_buffer_bytes=ag)
            log.info("chunked broadcast exchange (%d chunks): gathered-table "
                     "peak %s -> %s (%.1fx smaller); largest lowered "
                     "all-gather buffer %s", C, human_bytes(mono),
                     human_bytes(chunked), red, human_bytes(ag))
            if C >= 4 and chips >= 256:
                assert red >= 2, (
                    f"chunked broadcast exchange must cut peak gathered-table "
                    f"bytes >= 2x at 256-chip lowering: {red:.2f}x")
        if args.engine_p2p_buckets > 1 and args.engine_exec == "p2p":
            cap_mono = w = None
            if args.engine_family == "vertex_cut":
                cap_mono = max(eng._vc_p2p_caps)
                w = max(eng._vc_plan["send1"].shape[-1],
                        eng._vc_plan["send2"].shape[-1])
            elif minibatch:
                # the frontier fetch rides the same power-of-two installment
                # schedule (ISSUE 5 satellite: no more monolithic fcap send)
                cap_mono, w = eng.fcap, eng.fcap_widths[0]
            elif args.engine_family == "hybrid":
                # the halo leg buckets its caps; the sync leg is accounted
                # under vertex_cut above
                if eng.playout.halo_active:
                    cap_mono = sum(eng.playout.halo_widths)
                    w = eng.playout.halo_widths[0]
            else:
                cap_mono, w = eng.cap, eng.p2p_widths[0]
            if cap_mono is not None:
                mono_buf = chips * cap_mono * Dmax * 4
                a2a = max_collective_buffer_bytes(
                    compiled.as_text(), "all-to-all")
                engine_extra.update(
                    p2p_buckets=args.engine_p2p_buckets,
                    p2p_cap_monolithic=int(cap_mono),
                    p2p_cap_bucketed=int(w),
                    all_to_all_buffer_bytes_monolithic=mono_buf,
                    max_all_to_all_buffer_bytes=a2a)
                log.info("bucketed p2p caps: %d -> %d rows/pair; lowered "
                         "all_to_all buffer %s (monolithic %s)", cap_mono, w,
                         human_bytes(a2a), human_bytes(mono_buf))
                # the cap actually split (hybrid lowers a second, sync-leg
                # all_to_all that the halo-cap model does not bound, so the
                # buffer assert holds for the pure families only)
                if 2 * w <= cap_mono and args.engine_family != "hybrid":
                    assert a2a * 2 <= mono_buf, (
                        f"bucketed p2p caps must shrink the lowered "
                        f"all_to_all buffer >= 2x: {a2a} vs {mono_buf}")
        V = eng.Vp
        K = eng.K
    elif args.protocol == "p2p":
        n_dev = chips
        v_l = V // n_dev
        cap = max(int(args.cut * v_l), 8)  # boundary rows shipped per dest pair
        send_plan = jax.ShapeDtypeStruct((n_dev, n_dev, cap), jnp.int32)
        jitted = jax.jit(gcn_p2p_step_fn(cfg, mesh, cap))
        lowered = jitted.lower(params, specs["ids"], specs["mask"], specs["X"],
                               specs["y"], specs["train_w"], send_plan)
        compiled = lowered.compile()
    else:
        step = gcn_train_step_fn(cfg)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=(in_sh[0], None))
        lowered = jitted.lower(params, specs["ids"], specs["mask"], specs["X"],
                               specs["y"], specs["train_w"])
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compat_cost_analysis(compiled)
    coll, kinds = collective_bytes(compiled.as_text())
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    # analytic: per layer 2*E*D (aggregation) + 2*V*D_in*D_out, x3 for train
    fl = 0.0
    for a, b in zip(dims[:-1], dims[1:]):
        fl += 2.0 * V * K * a + 2.0 * V * a * b
    fl *= 3.0
    rl = roofline_terms(analytic_flops=fl, chips=chips,
                        hbm_bytes_per_chip=(V * D * 4 * 3) / chips,
                        collective_bytes_per_chip=coll,
                        model_flops=fl, hlo_flops_raw=float(ca.get("flops", 0)))
    result = dict(arch="gcn-paper", shape=f"fullgraph_V{V}", mesh=mesh_name,
                  tag=args.protocol if args.protocol != "broadcast" else "",
                  status="ok", chips=chips,
                  memory=dict(argument_bytes_per_device=ma.argument_size_in_bytes,
                              temp_bytes_per_device=ma.temp_size_in_bytes,
                              output_bytes_per_device=ma.output_size_in_bytes,
                              peak_bytes_per_device=compat_peak_memory(ma),
                              alias_bytes_per_device=ma.alias_size_in_bytes),
                  cost_analysis={k: ca[k] for k in ("flops", "bytes accessed") if k in ca},
                  collective_bytes_per_device=coll, collective_by_kind=kinds,
                  analytic_flops=fl, model_flops_6nd=fl,
                  hbm_traffic_bytes_per_chip=(V * D * 4 * 3) / chips,
                  roofline=rl.as_dict())
    if args.protocol == "engine" and engine_extra:
        result.update(engine_extra)
    os.makedirs(args.out, exist_ok=True)
    suffix = f"__{args.protocol}" if args.protocol != "broadcast" else ""
    if args.protocol == "engine" and args.engine_model != "gcn":
        suffix += f"_{args.engine_model}"
    if args.protocol == "engine" and args.engine_batching != "full_graph":
        suffix += f"_{args.engine_batching}"
    if args.protocol == "engine" and args.engine_family == "vertex_cut":
        suffix += f"_vertexcut_{args.engine_vertex_cut}"
    if args.protocol == "engine" and args.engine_family == "hybrid":
        suffix += "_hybrid"
    path = os.path.join(args.out, f"gcn-paper__fullgraph__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    log.info("OK gcn-paper fullgraph %s %.1fs args=%s temp=%s coll=%s dom=%s",
             mesh_name, time.time() - t0, human_bytes(ma.argument_size_in_bytes),
             human_bytes(ma.temp_size_in_bytes), human_bytes(coll), rl.dominant)


if __name__ == "__main__":
    main()
