"""Pallas TPU kernel: chunked RWKV6 WKV scan.

TPU adaptation (DESIGN.md §2/§5): the per-step recurrence becomes per-chunk
masked matmuls; the [K,K] state is carried ACROSS grid steps in a VMEM
scratch buffer — the TPU grid executes sequentially over the chunk axis, so
the scratch acts as the recurrent carry (the standard Pallas-TPU scan idiom).

Grid: (B*H, S // C). Inputs per step: r,k,v,g [1, C, K]; u [1, K].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only import guard; interpret mode works anywhere
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    _SCRATCH = None


def _wkv_kernel(r_ref, k_ref, v_ref, g_ref, u_ref, o_ref, state_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)  # [C, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)  # log decay <= 0 (pre-clamped)
    u = u_ref[0].astype(jnp.float32)  # [K]
    C = r.shape[0]
    state = state_ref[...]  # [K, K]

    L = jnp.cumsum(g, axis=0)  # inclusive
    L_prev = L - g  # exclusive
    L_end = L[-1]
    q_eff = r * jnp.exp(L_prev)
    k_eff = k * jnp.exp(-L)
    A = jax.lax.dot_general(q_eff, k_eff, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [C, C]
    t_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(s_i < t_i, A, 0.0)  # strictly past
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus (current token through u)
    coef = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
    y = y + coef * v
    # inter-chunk: carried state
    y = y + jax.lax.dot_general(q_eff, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    k_dec = k * jnp.exp(L_end[None, :] - L)
    state_new = jnp.exp(L_end)[:, None] * state + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = state_new
    o_ref[0] = y.astype(o_ref.dtype)


def wkv_chunk_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     g: jnp.ndarray, u: jnp.ndarray, *, chunk: int = 64,
                     interpret: bool = False) -> jnp.ndarray:
    """r,k,v,g [B,H,S,K]; u [H,K] -> y [B,H,S,K]."""
    B, H, S, K = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    g = jnp.clip(g, -1.2, 0.0)  # numerics contract shared with ssm.py
    rf = r.reshape(B * H, S, K)
    kf = k.reshape(B * H, S, K)
    vf = v.reshape(B * H, S, K)
    gf = g.reshape(B * H, S, K)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    grid = (B * H, S // chunk)
    scratch = [_SCRATCH((K, K))] if _SCRATCH is not None else [
        pl.BlockSpec(memory_space=None)]  # pragma: no cover
    out = pl.pallas_call(
        _wkv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, K), r.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(rf, kf, vf, gf, uf)
    return out.reshape(B, H, S, K)
