"""Pallas TPU kernel: ELLPACK SpMM (neighbor aggregation).

TPU adaptation of CSR gather-SpMM (DESIGN.md §2): neighbor lists are padded to
width K (ELLPACK), so per row-block the aggregation is a dense gather +
masked reduction over lanes the MXU/VPU handle natively. The feature matrix
block assigned to a grid row (partition-centric processing, PCGCN-style) is
resident in VMEM; rows/features are tiled by BlockSpec.

Grid: (num_row_blocks, num_feat_blocks). Per invocation:
  ids   [Rb, K]   int32 (VMEM)   — neighbor ids into H
  mask  [Rb, K]   f32   (VMEM)
  H     [N, Fb]   f32   (VMEM)   — the feature block (all rows, one col block)
  out   [Rb, Fb]  f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_spmm_kernel(ids_ref, mask_ref, h_ref, out_ref, *, normalize: bool):
    ids = ids_ref[...]  # [Rb, K]
    mask = mask_ref[...]
    h = h_ref[...]  # [N, Fb]
    gathered = jnp.take(h, ids, axis=0)  # [Rb, K, Fb] — dynamic-gather on TPU
    acc = jnp.sum(mask[..., None] * gathered, axis=1)  # [Rb, Fb] f32
    if normalize:
        deg = jnp.sum(mask, axis=1, keepdims=True)
        acc = acc / jnp.maximum(deg, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


def ell_spmm_pallas(ids: jnp.ndarray, mask: jnp.ndarray, H: jnp.ndarray, *,
                    row_block: int = 128, feat_block: int = 128,
                    normalize: bool = True, interpret: bool = False) -> jnp.ndarray:
    V, K = ids.shape
    N, D = H.shape
    row_block = min(row_block, V)
    feat_block = min(feat_block, D)
    assert V % row_block == 0 and D % feat_block == 0, (V, row_block, D, feat_block)
    grid = (V // row_block, D // feat_block)
    kernel = functools.partial(_ell_spmm_kernel, normalize=normalize)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((N, feat_block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((row_block, feat_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((V, D), H.dtype),
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), H)
