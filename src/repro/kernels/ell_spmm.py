"""Pallas TPU kernel: ELLPACK SpMM (neighbor aggregation).

TPU adaptation of CSR gather-SpMM (DESIGN.md §2): neighbor lists are padded to
width K (ELLPACK), so per row-block the aggregation is a dense gather +
masked reduction over lanes the MXU/VPU handle natively. The feature matrix
block assigned to a grid row (partition-centric processing, PCGCN-style) is
resident in VMEM; rows/features are tiled by BlockSpec.

Grid: (num_row_blocks, num_feat_blocks). Per invocation:
  ids   [Rb, K]   int32 (VMEM)   — neighbor ids into H
  mask  [Rb, K]   f32   (VMEM)
  H     [N, Fb]   f32   (VMEM)   — the feature block (all rows, one col block)
  out   [Rb, Fb]  f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import round_up


def _ell_spmm_kernel(ids_ref, mask_ref, h_ref, out_ref, *, normalize: bool):
    ids = ids_ref[...]  # [Rb, K]
    mask = mask_ref[...]
    h = h_ref[...]  # [N, Fb]
    gathered = jnp.take(h, ids, axis=0)  # [Rb, K, Fb] — dynamic-gather on TPU
    acc = jnp.sum(mask[..., None] * gathered, axis=1)  # [Rb, Fb] f32
    if normalize:
        deg = jnp.sum(mask, axis=1, keepdims=True)
        acc = acc / jnp.maximum(deg, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


def ell_spmm_pallas(ids: jnp.ndarray, mask: jnp.ndarray, H: jnp.ndarray, *,
                    row_block: int = 128, feat_block: int = 128,
                    normalize: bool = True, interpret: bool = False) -> jnp.ndarray:
    """Rows/features that don't tile evenly are zero-padded up to the block
    size (pad rows carry mask 0 -> contribute nothing; the padded output is
    sliced away), so awkward (e.g. prime) dimensions keep full-width blocks
    instead of silently degrading the grid to 1-element programs."""
    V, K = ids.shape
    N, D = H.shape
    row_block = min(row_block, V)
    feat_block = min(feat_block, D)
    Vp, Dp = round_up(V, row_block), round_up(D, feat_block)
    if Vp != V:
        ids = jnp.concatenate(
            [ids, jnp.zeros((Vp - V, K), ids.dtype)], axis=0)
        mask = jnp.concatenate(
            [mask, jnp.zeros((Vp - V, K), mask.dtype)], axis=0)
    if Dp != D:
        H = jnp.concatenate([H, jnp.zeros((N, Dp - D), H.dtype)], axis=1)
    grid = (Vp // row_block, Dp // feat_block)
    kernel = functools.partial(_ell_spmm_kernel, normalize=normalize)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i, j: (i, 0)),
            pl.BlockSpec((N, feat_block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((row_block, feat_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Vp, Dp), H.dtype),
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), H)
    return out[:V, :D] if (Vp, Dp) != (V, D) else out


# ---------------------------------------------------------------------------
# Differentiable wrapper
# ---------------------------------------------------------------------------
#
# pallas_call carries no autodiff rule (neither compiled nor interpret mode on
# the supported jax versions), but the aggregation's VJP w.r.t. H is just the
# transpose SpMM — a masked scatter-add the XLA scatter handles fine.  ids and
# mask are graph structure (non-differentiable).


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _ell_spmm_vjp(normalize, interpret, row_block, feat_block, ids, mask, H):
    return ell_spmm_pallas(ids, mask, H, normalize=normalize,
                           interpret=interpret, row_block=row_block,
                           feat_block=feat_block)


def _ell_spmm_fwd(normalize, interpret, row_block, feat_block, ids, mask, H):
    out = ell_spmm_pallas(ids, mask, H, normalize=normalize,
                          interpret=interpret, row_block=row_block,
                          feat_block=feat_block)
    return out, (ids, mask, H.shape[0])


def _ell_spmm_bwd(normalize, interpret, row_block, feat_block, res, ct):
    ids, mask, N = res
    V, K = ids.shape
    ctn = ct.astype(jnp.float32)
    if normalize:
        deg = jnp.maximum(mask.sum(1, keepdims=True), 1.0)
        ctn = ctn / deg
    contrib = (mask[..., None] * ctn[:, None, :]).reshape(V * K, ct.shape[-1])
    dH = jnp.zeros((N, ct.shape[-1]), jnp.float32).at[
        ids.reshape(-1)].add(contrib).astype(ct.dtype)
    # ids are structure (int -> float0 zero cotangent); mask likewise carries
    # no gradient (graph connectivity, not a learnable weight)
    return (jnp.zeros(ids.shape, jax.dtypes.float0),
            jnp.zeros_like(mask), dH)


_ell_spmm_vjp.defvjp(_ell_spmm_fwd, _ell_spmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ell_attend_vjp(interpret, row_block, feat_block, ids, w, H):
    return ell_spmm_pallas(ids, w, H, normalize=False, interpret=interpret,
                           row_block=row_block, feat_block=feat_block)


def _ell_attend_fwd(interpret, row_block, feat_block, ids, w, H):
    out = ell_spmm_pallas(ids, w, H, normalize=False, interpret=interpret,
                          row_block=row_block, feat_block=feat_block)
    return out, (ids, w, H)


def _ell_attend_bwd(interpret, row_block, feat_block, res, ct):
    ids, w, H = res
    V, K = ids.shape
    ctn = ct.astype(jnp.float32)
    contrib = (w[..., None] * ctn[:, None, :]).reshape(V * K, ct.shape[-1])
    dH = jnp.zeros((H.shape[0], ct.shape[-1]), jnp.float32).at[
        ids.reshape(-1)].add(contrib).astype(ct.dtype)
    # dL/dw[v,k] = ct[v] . H[ids[v,k]] — the SDDMM-shaped gather product
    dw = (ctn[:, None, :] * jnp.take(H, ids, axis=0)).sum(-1).astype(w.dtype)
    return (jnp.zeros(ids.shape, jax.dtypes.float0), dw, dH)


_ell_attend_vjp.defvjp(_ell_attend_fwd, _ell_attend_bwd)


def ell_attend(ids: jnp.ndarray, weights: jnp.ndarray, H: jnp.ndarray, *,
               interpret: bool = False, row_block: int = 128,
               feat_block: int = 128) -> jnp.ndarray:
    """Attention-weighted ELL sum: out[v] = sum_k weights[v,k] * H[ids[v,k]],
    with gradients flowing to BOTH ``weights`` and ``H``.

    Same Pallas forward as `ell_spmm` (the weights ride the mask lane), but
    where `ell_spmm` treats the mask as graph structure (zero cotangent),
    GAT's attention coefficients are a function of the params — their VJP is
    the SDDMM-shaped gather product ct[v] . H[ids[v,k]]."""
    return _ell_attend_vjp(interpret, row_block, feat_block, ids,
                           weights.astype(jnp.float32), H)


def ell_spmm(ids: jnp.ndarray, mask: jnp.ndarray, H: jnp.ndarray, *,
             normalize: bool = True, interpret: bool = False,
             row_block: int = 128, feat_block: int = 128) -> jnp.ndarray:
    """Differentiable ELL SpMM: Pallas forward, scatter-add transpose backward.

    out[v] = sum_k mask[v,k] * H[ids[v,k]]  (/ max(deg[v], 1) if normalize)

    ids/mask may be traced values (e.g. selected per ring step inside a scan);
    only H carries gradient.  ``row_block``/``feat_block`` tune the Pallas
    grid (both clipped to the operand) — the chunk-friendly call path: a
    feature-chunked exchange calling with a narrow table keeps full-width
    row blocks instead of degrading the grid.
    """
    return _ell_spmm_vjp(normalize, interpret, row_block, feat_block, ids,
                         mask.astype(jnp.float32), H)
