"""Jit'd dispatch layer: Pallas kernels on TPU, interpret-mode (or the jnp
oracle) on CPU. This is the API the rest of the framework calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ell_spmm import ell_spmm as ell_spmm_diff
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.wkv_chunk import wkv_chunk_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("normalize", "force_pallas"))
def ell_spmm(ids, mask, H, *, normalize: bool = True, force_pallas: bool = False):
    # the differentiable wrapper (custom scatter-add VJP), so grads work
    # through the package-level API on every backend
    if _on_tpu() or force_pallas:
        return ell_spmm_diff(ids, mask, H, normalize=normalize,
                             interpret=not _on_tpu())
    return ref.ell_spmm_ref(ids, mask, H, normalize=normalize)


@functools.partial(jax.jit, static_argnames=("slope", "force_pallas"))
def sddmm(ids, mask, Hw, a_src, a_dst, *, slope: float = 0.2,
          force_pallas: bool = False):
    if _on_tpu() or force_pallas:
        return sddmm_pallas(ids, mask, Hw, a_src, a_dst, slope=slope,
                            interpret=not _on_tpu())
    return ref.sddmm_ref(ids, mask, Hw, a_src, a_dst, slope=slope)


@functools.partial(jax.jit, static_argnames=("causal", "force_pallas"))
def flash_attention(q, k, v, *, causal: bool = True, force_pallas: bool = False):
    if _on_tpu() or force_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("chunk", "force_pallas"))
def wkv(r, k, v, g, u, *, chunk: int = 64, force_pallas: bool = False):
    if _on_tpu() or force_pallas:
        return wkv_chunk_pallas(r, k, v, g, u, chunk=chunk,
                                interpret=not _on_tpu())
    return ref.wkv_chunk_ref(r, k, v, jnp.clip(g, -1.2, 0.0), u)
