"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmm_ref(ids: jnp.ndarray, mask: jnp.ndarray, H: jnp.ndarray,
                 *, normalize: bool = True) -> jnp.ndarray:
    """ELLPACK aggregation. ids [V,K] int32 (padded entries point anywhere but
    are masked), mask [V,K] float, H [N,D]. y[v] = sum_k mask[v,k] H[ids[v,k]]
    (normalized by degree if requested)."""
    gathered = H[ids]  # [V,K,D]
    y = (mask[..., None] * gathered).sum(1)
    if normalize:
        deg = mask.sum(1, keepdims=True)
        y = y / jnp.maximum(deg, 1.0)
    return y


def sddmm_ref(ids: jnp.ndarray, mask: jnp.ndarray, Hw: jnp.ndarray,
              a_src: jnp.ndarray, a_dst: jnp.ndarray,
              *, slope: float = 0.2) -> jnp.ndarray:
    """GAT edge scores on ELL structure: e[v,k] = LeakyReLU(a_dst.Hw[v] +
    a_src.Hw[ids[v,k]]), masked entries -> -inf (pre-softmax).  Hw may hold
    more rows than ids (halo/pad rows appended after the V dst rows) — dst
    row v is table row v, the same prefix contract as the Pallas kernel."""
    s_dst = (Hw @ a_dst)[: ids.shape[0]]  # [V]
    s_src = (Hw @ a_src)[ids]  # [V,K]
    e = s_dst[:, None] + s_src
    e = jnp.where(e > 0, e, slope * e)
    return jnp.where(mask > 0, e, -1e30)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q,k,v [B,H,S,D] -> [B,H,S,D], fp32 softmax."""
    S = q.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / (q.shape[-1] ** 0.5)
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def wkv_chunk_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  g: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """RWKV6 WKV oracle, naive per-step recurrence.
    r,k [B,H,S,K]; v [B,H,S,K]; g [B,H,S,K] log-decay (<=0); u [H,K] bonus.
    Returns y [B,H,S,K]."""
    B, H, S, K = r.shape
    rf = r.reshape(B * H, S, K).astype(jnp.float32)
    kf = k.reshape(B * H, S, K).astype(jnp.float32)
    vf = v.reshape(B * H, S, K).astype(jnp.float32)
    gf = g.reshape(B * H, S, K).astype(jnp.float32)
    uf = jnp.broadcast_to(u.astype(jnp.float32), (B, H, K)).reshape(B * H, K)

    def per_bh(rb, kb, vb, gb, ub):
        def step(state, inp):
            rt, kt, vt, gt = inp
            kv = jnp.outer(kt, vt)
            y = rt @ (state + ub[:, None] * kv)
            state = jnp.exp(gt)[:, None] * state + kv
            return state, y

        _, ys = jax.lax.scan(step, jnp.zeros((K, K), jnp.float32),
                             (rb, kb, vb, gb))
        return ys

    return jax.vmap(per_bh)(rf, kf, vf, gf, uf).reshape(B, H, S, K).astype(r.dtype)
