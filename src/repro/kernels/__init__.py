"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles.

Kernels: ell_spmm (GNN aggregation), sddmm (GAT edge scores),
flash_attention (transformer prefill), wkv_chunk (RWKV6 chunked scan).
Validated in interpret mode on CPU; dispatched natively on TPU via ops.py.
"""
from repro.kernels.ops import ell_spmm, flash_attention, sddmm, wkv

__all__ = ["ell_spmm", "flash_attention", "sddmm", "wkv"]
