"""Pallas TPU kernel: flash attention (prefill/training attention hot spot).

Grid (batch*heads, num_q_blocks); the q block and streaming softmax stats live
in VMEM; k/v are consumed in kv-sized blocks via an inner fori_loop over VMEM
slices of the per-(bh) k/v panels. fp32 accumulation, causal masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import pallas_block_slice

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, causal: bool,
                  scale: float):
    q = q_ref[0]  # [Qb, D]
    Qb, D = q.shape
    T = k_ref.shape[1]
    nkv = T // kv_block
    qi = pl.program_id(1)
    q_idx = qi * Qb + jax.lax.broadcasted_iota(jnp.int32, (Qb, 1), 0)

    def body(kv_i, carry):
        m, l, acc = carry
        # leading block dim indexed with a width-1 slice, not a bare int:
        # jax 0.4.3x interpret-mode load discharge requires Slice/array indices
        k_blk = pl.load(k_ref, (pallas_block_slice(0, 1),
                                pl.dslice(kv_i * kv_block, kv_block), slice(None)))[0]
        v_blk = pl.load(v_ref, (pallas_block_slice(0, 1),
                                pl.dslice(kv_i * kv_block, kv_block), slice(None)))[0]
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            k_idx = kv_i * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (1, kv_block), 1)
            s = jnp.where(k_idx <= q_idx, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v_blk.dtype), v_blk,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr + pv

    m0 = jnp.full((Qb, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Qb, 1), jnp.float32)
    a0 = jnp.zeros((Qb, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           q_block: int = 128, kv_block: int = 128,
                           causal: bool = True, interpret: bool = False
                           ) -> jnp.ndarray:
    """q,k,v [B,H,S,D] -> [B,H,S,D]."""
    B, H, S, D = q.shape
    T = k.shape[2]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    assert S % q_block == 0 and T % kv_block == 0
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, D)
    grid = (B * H, S // q_block)
    kernel = functools.partial(_flash_kernel, kv_block=kv_block, causal=causal,
                               scale=D ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
