"""Pallas TPU kernel: SDDMM-style GAT edge scores on ELL structure.

e[v, k] = LeakyReLU(a_dst . Hw[v]  +  a_src . Hw[ids[v, k]]), masked -> -inf.
The dense-dense products (Hw @ a) ride the VPU; the per-edge combine is a
gather + add over the ELL lanes. Grid over row blocks; Hw resident per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(ids_ref, mask_ref, hw_ref, asrc_ref, adst_ref, out_ref, *,
                  slope: float):
    ids = ids_ref[...]  # [Rb, K]
    mask = mask_ref[...]
    hw = hw_ref[...]  # [N, D]
    a_src = asrc_ref[...]  # [1, D]
    a_dst = adst_ref[...]
    s_all_src = jnp.sum(hw * a_src, axis=1)  # [N]
    s_all_dst = jnp.sum(hw * a_dst, axis=1)  # [N]
    rb = ids.shape[0]
    i = pl.program_id(0)
    row_ids = i * rb + jax.lax.broadcasted_iota(jnp.int32, (rb,), 0)
    s_dst = jnp.take(s_all_dst, row_ids, axis=0)  # [Rb]
    s_src = jnp.take(s_all_src, ids.reshape(-1), axis=0).reshape(ids.shape)  # [Rb,K]
    e = s_dst[:, None] + s_src
    e = jnp.where(e > 0, e, slope * e)
    out_ref[...] = jnp.where(mask > 0, e, -1e30).astype(out_ref.dtype)


def sddmm_pallas(ids: jnp.ndarray, mask: jnp.ndarray, Hw: jnp.ndarray,
                 a_src: jnp.ndarray, a_dst: jnp.ndarray, *, slope: float = 0.2,
                 row_block: int = 128, interpret: bool = False) -> jnp.ndarray:
    V, K = ids.shape
    N, D = Hw.shape
    row_block = min(row_block, V)
    assert V % row_block == 0
    grid = (V // row_block,)
    kernel = functools.partial(_sddmm_kernel, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),
            pl.BlockSpec((N, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((V, K), jnp.float32),
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), Hw, a_src.reshape(1, -1), a_dst.reshape(1, -1))
