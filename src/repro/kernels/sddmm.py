"""Pallas TPU kernel: SDDMM-style GAT edge scores on ELL structure.

e[v, k] = LeakyReLU(a_dst . Hw[v]  +  a_src . Hw[ids[v, k]]), masked -> -inf.
The dense-dense products (Hw @ a) ride the VPU; the per-edge combine is a
gather + add over the ELL lanes. Grid over row blocks; Hw resident per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(ids_ref, mask_ref, hw_ref, asrc_ref, adst_ref, out_ref, *,
                  slope: float):
    ids = ids_ref[...]  # [Rb, K]
    mask = mask_ref[...]
    hw = hw_ref[...]  # [N, D]
    a_src = asrc_ref[...]  # [1, D]
    a_dst = adst_ref[...]
    s_all_src = jnp.sum(hw * a_src, axis=1)  # [N]
    s_all_dst = jnp.sum(hw * a_dst, axis=1)  # [N]
    rb = ids.shape[0]
    i = pl.program_id(0)
    row_ids = i * rb + jax.lax.broadcasted_iota(jnp.int32, (rb,), 0)
    s_dst = jnp.take(s_all_dst, row_ids, axis=0)  # [Rb]
    s_src = jnp.take(s_all_src, ids.reshape(-1), axis=0).reshape(ids.shape)  # [Rb,K]
    e = s_dst[:, None] + s_src
    e = jnp.where(e > 0, e, slope * e)
    out_ref[...] = jnp.where(mask > 0, e, -1e30).astype(out_ref.dtype)


def sddmm_pallas(ids: jnp.ndarray, mask: jnp.ndarray, Hw: jnp.ndarray,
                 a_src: jnp.ndarray, a_dst: jnp.ndarray, *, slope: float = 0.2,
                 row_block: int = 128, interpret: bool = False) -> jnp.ndarray:
    V, K = ids.shape
    N, D = Hw.shape
    row_block = min(row_block, V)
    assert V % row_block == 0
    grid = (V // row_block,)
    kernel = functools.partial(_sddmm_kernel, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),
            pl.BlockSpec((N, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((V, K), jnp.float32),
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), Hw, a_src.reshape(1, -1), a_dst.reshape(1, -1))


# ---------------------------------------------------------------------------
# Differentiable wrapper (the distributed GAT path)
# ---------------------------------------------------------------------------
#
# pallas_call carries no autodiff rule, but the edge-score VJP is analytic:
# with z = s_dst[v] + s_src[ids[v,k]] the masked logits e = LeakyReLU(z) give
#   de/dHw = scatter(dz) * a_dst + scatter_over_ids(dz) * a_src
# — two dense rank-1 products plus a scatter-add, all XLA-native.  ids/mask
# are graph structure (non-differentiable); masked slots emit the constant
# -1e30, so their cotangent is dropped.
#
# Contract (same as the kernel): destination row v's features live at table
# row v — the table's first V rows ARE the dst rows.  Rows are padded to the
# grid here, so any V works.


def _sddmm_padded(ids, mask, Hw, a_src, a_dst, slope, row_block, interpret):
    V, K = ids.shape
    rb = min(row_block, V)
    Vp = -(-V // rb) * rb
    if Vp != V:  # pad rows: ids 0 / mask 0 -> -1e30 logits, sliced away
        ids = jnp.concatenate([ids, jnp.zeros((Vp - V, K), ids.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((Vp - V, K), mask.dtype)], 0)
    out = sddmm_pallas(ids, mask, Hw, a_src, a_dst, slope=slope,
                       row_block=rb, interpret=interpret)
    return out[:V] if Vp != V else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _sddmm_vjp(slope, row_block, interpret, ids, mask, Hw, a_src, a_dst):
    return _sddmm_padded(ids, mask, Hw, a_src, a_dst, slope, row_block,
                         interpret)


def _sddmm_fwd(slope, row_block, interpret, ids, mask, Hw, a_src, a_dst):
    out = _sddmm_padded(ids, mask, Hw, a_src, a_dst, slope, row_block,
                        interpret)
    return out, (ids, mask, Hw, a_src, a_dst)


def _sddmm_bwd(slope, row_block, interpret, res, ct):
    ids, mask, Hw, a_src, a_dst = res
    V, K = ids.shape
    N = Hw.shape[0]
    s_dst = Hw @ a_dst  # [N]
    s_src = Hw @ a_src
    z = s_dst[:V, None] + jnp.take(s_src, ids, axis=0)
    dz = ct.astype(jnp.float32) * jnp.where(z > 0, 1.0, slope) * (mask > 0)
    g_dst = jnp.zeros((N,), jnp.float32).at[:V].set(dz.sum(1))
    g_src = jnp.zeros((N,), jnp.float32).at[ids.reshape(-1)].add(
        dz.reshape(-1))
    dHw = (g_dst[:, None] * a_dst[None, :]
           + g_src[:, None] * a_src[None, :]).astype(Hw.dtype)
    da_dst = (Hw * g_dst[:, None]).sum(0).astype(a_dst.dtype)
    da_src = (Hw * g_src[:, None]).sum(0).astype(a_src.dtype)
    return (jnp.zeros(ids.shape, jax.dtypes.float0), jnp.zeros_like(mask),
            dHw, da_src, da_dst)


_sddmm_vjp.defvjp(_sddmm_fwd, _sddmm_bwd)


def sddmm_ell(ids: jnp.ndarray, mask: jnp.ndarray, Hw: jnp.ndarray,
              a_src: jnp.ndarray, a_dst: jnp.ndarray, *, slope: float = 0.2,
              row_block: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Differentiable masked GAT edge logits over ELL structure: Pallas
    forward (rows padded to the grid), analytic VJP for Hw / a_src / a_dst.

    e[v, k] = LeakyReLU(a_dst . Hw[v] + a_src . Hw[ids[v, k]]), masked slots
    -> -1e30.  Destination row v must be table row v (the table's first V
    rows are the dst rows — the engine's local/p2p/reference layouts)."""
    return _sddmm_vjp(slope, row_block, interpret, ids,
                      mask.astype(jnp.float32), Hw, a_src, a_dst)
