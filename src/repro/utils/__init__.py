"""Small shared utilities: logging, pytree helpers, deterministic RNG."""
from __future__ import annotations

import logging
import math
import sys
from typing import Any, Iterable

import jax
import numpy as np

__all__ = [
    "get_logger",
    "tree_bytes",
    "tree_num_params",
    "human_bytes",
    "human_count",
    "cdiv",
    "round_up",
]


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(
            logging.Formatter("[%(asctime)s %(name)s %(levelname)s] %(message)s", "%H:%M:%S")
        )
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
    return logger


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_num_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


_UNITS = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"]


def human_bytes(n: float) -> str:
    if n <= 0:
        return "0B"
    k = min(int(math.log(n, 1024)), len(_UNITS) - 1)
    return f"{n / 1024 ** k:.2f}{_UNITS[k]}"


def human_count(n: float) -> str:
    for unit, div in (("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(n) >= div:
            return f"{n / div:.2f}{unit}"
    return str(int(n))


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
