"""KV / state cache containers for decode.

A cache is a flat dict of arrays stacked over layers (leading L dim), built in
one of two modes: 'zeros' (real buffers) or 'shape' (ShapeDtypeStruct stand-ins
for the AOT dry-run). ``cache_logical_axes`` returns the structurally
identical logical-axes pytree used to derive shardings.

Layout per family:
  attention       : k, v        [L, B, T, KV, hd]
  MLA (deepseek)  : c [L,B,T,R], kr [L,B,T,Rh]
  enc-dec         : + xk, xv    [L, B, T_enc, KV, hd] (cross-attn, precomputed)
  rwkv6           : tm_x, cm_x  [L, B, D], s [L, B, H, K, K]
  mamba2 (hybrid) : conv [L,B,W-1,2D], s [L,B,H,K,P]
  hybrid (+attn)  : ak, av      [A, B, T, KV, hd]  (A = shared-attn applications)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def num_attn_applications(cfg) -> int:
    if not cfg.ssm_kind:
        return cfg.num_layers
    if cfg.attn_every <= 0:
        return 0
    return sum(1 for i in range(cfg.num_layers) if (i % cfg.attn_every) == cfg.attn_every - 1)


def cache_spec(cfg, batch: int, max_len: int, enc_len: int = 0) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """Returns {name: (shape, dtype)}."""
    L, B, T, D = cfg.num_layers, batch, max_len, cfg.d_model
    dt = jnp.bfloat16
    spec: Dict[str, Tuple[Tuple[int, ...], Any]] = {}
    if cfg.ssm_kind == "rwkv6":
        H, K = cfg.ssm_heads, cfg.ssm_state
        spec["tm_x"] = ((L, B, D), dt)
        spec["cm_x"] = ((L, B, D), dt)
        spec["s"] = ((L, B, H, K, K), jnp.float32)
    elif cfg.ssm_kind == "mamba2":
        H, N = cfg.ssm_heads, cfg.ssm_state
        P_dim = 2 * D // H
        spec["conv"] = ((L, B, cfg.ssm_conv - 1, 2 * D), dt)
        spec["s"] = ((L, B, H, N, P_dim), jnp.float32)
        A = num_attn_applications(cfg)
        if A:
            spec["ak"] = ((A, B, T, cfg.num_kv_heads, cfg.head_dim), dt)
            spec["av"] = ((A, B, T, cfg.num_kv_heads, cfg.head_dim), dt)
    elif cfg.use_mla:
        spec["c"] = ((L, B, T, cfg.kv_lora_rank), dt)
        spec["kr"] = ((L, B, T, cfg.rope_head_dim), dt)
    else:
        spec["k"] = ((L, B, T, cfg.num_kv_heads, cfg.head_dim), dt)
        spec["v"] = ((L, B, T, cfg.num_kv_heads, cfg.head_dim), dt)
    if cfg.is_encoder_decoder:
        spec["xk"] = ((L, B, enc_len or T, cfg.num_kv_heads, cfg.head_dim), dt)
        spec["xv"] = ((L, B, enc_len or T, cfg.num_kv_heads, cfg.head_dim), dt)
    return spec


_AXES = {
    "k": ("layer", "act_batch", "act_kv_seq", "act_kv_heads", None),
    "v": ("layer", "act_batch", "act_kv_seq", "act_kv_heads", None),
    "ak": ("layer", "act_batch", "act_kv_seq", "act_kv_heads", None),
    "av": ("layer", "act_batch", "act_kv_seq", "act_kv_heads", None),
    "xk": ("layer", "act_batch", None, "act_kv_heads", None),
    "xv": ("layer", "act_batch", None, "act_kv_heads", None),
    "c": ("layer", "act_batch", "act_kv_seq", "kv_lora"),
    "kr": ("layer", "act_batch", "act_kv_seq", None),
    "tm_x": ("layer", "act_batch", "act_embed"),
    "cm_x": ("layer", "act_batch", "act_embed"),
    "s": ("layer", "act_batch", "ssm_heads", None, None),
    "conv": ("layer", "act_batch", None, "ssm_inner"),
}


def init_cache(cfg, batch: int, max_len: int, *, enc_len: int = 0, mode: str = "zeros"):
    spec = cache_spec(cfg, batch, max_len, enc_len)
    if mode == "shape":
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in spec.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in spec.items()}


def cache_logical_axes(cfg, batch: int, max_len: int, enc_len: int = 0):
    spec = cache_spec(cfg, batch, max_len, enc_len)
    return {k: _AXES[k] for k in spec}


def cache_bytes(cfg, batch: int, max_len: int, enc_len: int = 0) -> int:
    spec = cache_spec(cfg, batch, max_len, enc_len)
    return sum(int(np.prod(s)) * np.dtype(d).itemsize for s, d in spec.values())
