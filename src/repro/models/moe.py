"""Mixture-of-Experts with expert-parallel all_to_all dispatch.

Two execution paths with identical math:

* ``_moe_reference``: single-device dense-gather path used by CPU smoke tests
  and as the correctness oracle for the distributed path.
* ``_moe_expert_parallel``: shard_map path — experts are sharded over the
  'model' mesh axis; tokens are routed with capacity-based packing and moved
  by ``lax.all_to_all`` (the survey's P2P communication protocol, §7.1.2,
  instantiated for the token->expert bipartite graph), processed with grouped
  matmuls, and combined back. Token chunks bound the dispatch-buffer memory
  (``cfg.moe_dispatch_chunk`` — a §Perf lever).

The survey connection (DESIGN.md §3): MoE dispatch *is* distributed graph
aggregation under a vertex-cut (expert) partition; router load imbalance is
challenge #3, and the aux loss below is the standard mitigation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.launch.sharding import active_mesh, active_rules, logical, spec_for
from repro.models.layers import ParamBuilder, mlp_params, mlp_apply


def moe_params(b: ParamBuilder, cfg, name="moe"):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    with b.scope(name):
        p = {
            "router": b.param("router", (D, E), ("embed", None)),
            "wi": b.param("wi", (E, D, F), ("expert", "expert_embed", "expert_mlp"), fan_in=D),
            "wg": b.param("wg", (E, D, F), ("expert", "expert_embed", "expert_mlp"), fan_in=D),
            "wo": b.param("wo", (E, F, D), ("expert", "expert_mlp", "expert_embed"), fan_in=F),
        }
        if cfg.num_shared_experts:
            p["shared"] = mlp_params(b, cfg, "shared", d_ff=cfg.d_ff * cfg.num_shared_experts)
    return p


def _router(p, x_flat, cfg):
    """x_flat [T,D] -> (weights [T,k], expert_ids [T,k], aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [T,E]
    vals, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    E = cfg.num_experts
    me = probs.mean(0)  # mean router prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (
        x_flat.shape[0] * cfg.moe_top_k)
    aux = E * jnp.sum(me * ce)
    return vals.astype(x_flat.dtype), ids, aux


def _expert_ffn(wi, wg, wo, x, dtype):
    """Grouped SwiGLU: x [E,C,D]; weights [E,D,F]/[E,F,D]."""
    h = jnp.einsum("ecd,edf->ecf", x, wi.astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(dtype))


# ---------------------------------------------------------------------------
# Reference (single-device) path
# ---------------------------------------------------------------------------


def _moe_reference(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    dtype = x.dtype
    T = B * S
    xf = x.reshape(T, D)
    w, ids, aux = _router(p, xf, cfg)
    E, k = cfg.num_experts, cfg.moe_top_k
    cap = max(int(T * k / E * cfg.capacity_factor), 1)
    flat_ids = ids.reshape(-1)  # [T*k]
    tok = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(T * k), flat_ids]
    keep = pos < cap
    ex_in = jnp.zeros((E, cap, D), dtype).at[
        jnp.where(keep, flat_ids, E), jnp.where(keep, pos, 0)
    ].set(xf[tok], mode="drop")
    ex_out = _expert_ffn(p["wi"], p["wg"], p["wo"], ex_in, dtype)
    y_pair = ex_out[jnp.where(keep, flat_ids, 0), jnp.where(keep, pos, 0)]
    y_pair = jnp.where(keep[:, None], y_pair, 0.0)
    y = jnp.zeros((T, D), dtype).at[tok].add(y_pair * w.reshape(-1)[:, None])
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x).reshape(T, D)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path
# ---------------------------------------------------------------------------


def _dispatch_chunk_dedup(p_local, x_c, cfg, model_size: int, dtype,
                          shared_local: bool = False):
    """Deduplicated (and optionally group-limited) dispatch: each token is sent
    ONCE per destination shard carrying its [E_local] weight vector, instead of
    once per (token, expert) pair. With group_limit G < top_k this bounds the
    copies per token to G (DeepSeek-style node-limited routing) — the §Perf
    optimization for all-to-all-bound MoE training. With G == model_size the
    math is identical to the baseline dispatch (pure dedup, given ample
    capacity)."""
    Ck, D = x_c.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    M = model_size
    E_local = E // M
    G = min(cfg.moe_group_limit or M, M)
    w, ids, aux = _router(p_local, x_c, cfg)
    # dense weight matrix [Ck, M, E_local]
    W = jnp.zeros((Ck, E), dtype).at[jnp.arange(Ck)[:, None], ids].set(w)
    W = W.reshape(Ck, M, E_local)
    if G < M:
        # keep only the top-G shards by total routed weight; renormalize
        shard_w = jnp.abs(W).sum(-1)  # [Ck, M]
        topv = jax.lax.top_k(jax.lax.stop_gradient(shard_w), G)[0]
        thresh = topv[:, G - 1 : G]  # G-th largest (selection is not diff'd)
        keep_shard = shard_w >= thresh
        W = W * keep_shard[..., None].astype(W.dtype)
        norm = W.sum((1, 2), keepdims=True)
        W = W / jnp.maximum(norm, 1e-9)
    active = jnp.abs(W).sum(-1) > 0  # [Ck, M]
    # capacity packing over (token, dest) pairs, per destination column
    cap = max(int(Ck * min(G if G < M else k, M) / M * cfg.capacity_factor), 8)
    act_i = active.astype(jnp.int32)
    cnt = jnp.cumsum(act_i, axis=0) - act_i  # per-dest running position
    keep = active & (cnt < cap)
    d_grid = jnp.broadcast_to(jnp.arange(M)[None], (Ck, M))
    t_grid = jnp.broadcast_to(jnp.arange(Ck)[:, None], (Ck, M))
    keep_f = keep.reshape(-1)
    d_idx = jnp.where(keep_f, d_grid.reshape(-1), M)
    p_idx = jnp.where(keep_f, cnt.reshape(-1), 0)
    send = jnp.zeros((M + 1, cap, D + E_local), dtype)
    send = send.at[d_idx, p_idx, :D].set(x_c[t_grid.reshape(-1)], mode="drop")
    send = send.at[d_idx, p_idx, D:].set(W.reshape(Ck * M, E_local), mode="drop")
    send = send[:M]
    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0, tiled=False)
    rx = recv.reshape(M * cap, D + E_local)
    xr, wr = rx[:, :D], rx[:, D:]
    # per-local-expert capacity packing over received slots; lax.scan over the
    # expert axis keeps exactly ONE expert's buffers live (the unrolled-loop
    # version held all E_local of them and tripled temp memory)
    N = M * cap
    cap_e = max(int(N * min(1.0, max(k, 1) / max(E_local, 1)) * cfg.capacity_factor), 8)

    @jax.checkpoint
    def expert_body(y_acc, exp):
        wi_e, wg_e, wo_e, we = exp  # we [N]
        act = jnp.abs(we) > 0
        pos = jnp.cumsum(act.astype(jnp.int32)) - act.astype(jnp.int32)
        kp = act & (pos < cap_e)
        slot_idx = jnp.where(kp, pos, cap_e)
        ex_in = jnp.zeros((cap_e + 1, D), dtype).at[slot_idx].set(
            jnp.where(kp[:, None], xr, 0.0), mode="drop")[:cap_e]
        h = jax.nn.silu(ex_in @ wg_e.astype(dtype)) * (ex_in @ wi_e.astype(dtype))
        out_e = h @ wo_e.astype(dtype)  # [cap_e, D]
        gathered = jnp.where(kp[:, None], out_e[jnp.where(kp, pos, 0)], 0.0)
        return y_acc + gathered * we[:, None], None

    y_slot, _ = jax.lax.scan(
        expert_body, jnp.zeros((N, D), dtype),
        (p_local["wi"], p_local["wg"], p_local["wo"], wr.T))
    y_rx = y_slot.reshape(M, cap, D)
    y_send = jax.lax.all_to_all(y_rx, "model", split_axis=0, concat_axis=0, tiled=False)
    y_pair = y_send[jnp.where(keep_f, d_idx, 0).clip(0, M - 1), p_idx]
    y_pair = jnp.where(keep_f[:, None], y_pair, 0.0)
    y_c = jnp.zeros((Ck, D), dtype).at[t_grid.reshape(-1)].add(y_pair)
    if "shared" in p_local:
        sh = p_local["shared"]
        h = x_c @ sh["wi"].astype(dtype)
        g = x_c @ sh["wg"].astype(dtype)
        part = (jax.nn.silu(g) * h) @ sh["wo"].astype(dtype)
        if shared_local:
            # seq-sharded tokens: every shard holds DIFFERENT tokens, so the
            # shared expert runs fully local on replicated weights (no psum)
            y_c = y_c + part
        else:
            y_c = y_c + jax.lax.psum(part, "model")
    return y_c, aux


def _dispatch_chunk(p_local, x_c, cfg, model_size: int, dtype,
                    shared_local: bool = False):
    """One token chunk, device-local code inside shard_map.
    x_c [Ck, D] -> (y_c [Ck, D], aux scalar)."""
    Ck, D = x_c.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    E_local = E // model_size
    w, ids, aux = _router(p_local, x_c, cfg)
    flat_ids = ids.reshape(-1)
    tok = jnp.repeat(jnp.arange(Ck), k)
    wflat = w.reshape(-1)
    dest = flat_ids // E_local  # destination model shard
    local_eid = flat_ids % E_local
    # --- pack into per-destination capacity buffers ---
    cap = max(int(Ck * k / model_size * cfg.capacity_factor), 8)
    oh = jax.nn.one_hot(dest, model_size, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(Ck * k), dest]
    keep = pos < cap
    d_idx = jnp.where(keep, dest, model_size)
    p_idx = jnp.where(keep, pos, 0)
    send_x = jnp.zeros((model_size, cap, D), dtype).at[d_idx, p_idx].set(
        x_c[tok], mode="drop")
    send_eid = jnp.full((model_size, cap), -1, jnp.int32).at[d_idx, p_idx].set(
        local_eid, mode="drop")
    # --- all_to_all over the expert-parallel axis ---
    recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0, concat_axis=0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, "model", split_axis=0, concat_axis=0, tiled=False)
    rx = recv_x.reshape(model_size * cap, D)
    re = recv_eid.reshape(model_size * cap)
    # --- pack per local expert ---
    N = rx.shape[0]
    cap_e = max(int(N / E_local * cfg.capacity_factor), 8)
    valid = re >= 0
    re_safe = jnp.where(valid, re, 0)
    oh2 = jax.nn.one_hot(re_safe, E_local, dtype=jnp.int32) * valid[:, None]
    pos2 = (jnp.cumsum(oh2, axis=0) - oh2)[jnp.arange(N), re_safe]
    keep2 = valid & (pos2 < cap_e)
    e_idx = jnp.where(keep2, re_safe, E_local)
    c_idx = jnp.where(keep2, pos2, 0)
    ex_in = jnp.zeros((E_local, cap_e, D), dtype).at[e_idx, c_idx].set(rx, mode="drop")
    ex_out = _expert_ffn(p_local["wi"], p_local["wg"], p_local["wo"], ex_in, dtype)
    y_rx = ex_out[jnp.where(keep2, re_safe, 0), c_idx]
    y_rx = jnp.where(keep2[:, None], y_rx, 0.0).reshape(model_size, cap, D)
    # --- return trip ---
    y_send = jax.lax.all_to_all(y_rx, "model", split_axis=0, concat_axis=0, tiled=False)
    y_pair = y_send[d_idx.clip(0, model_size - 1), p_idx]
    y_pair = jnp.where(keep[:, None], y_pair, 0.0)
    y_c = jnp.zeros((Ck, D), dtype).at[tok].add(y_pair * wflat[:, None])
    # --- shared experts: plain tensor-parallel MLP (partial-F + psum) ---
    if "shared" in p_local:
        sh = p_local["shared"]
        h = x_c @ sh["wi"].astype(dtype)
        g = x_c @ sh["wg"].astype(dtype)
        part = (jax.nn.silu(g) * h) @ sh["wo"].astype(dtype)
        if shared_local:
            # seq-sharded tokens: every shard holds DIFFERENT tokens, so the
            # shared expert runs fully local on replicated weights (no psum)
            y_c = y_c + part
        else:
            y_c = y_c + jax.lax.psum(part, "model")
    return y_c, aux




def _moe_decode_2d(p, x, cfg, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weights-stationary decode dispatch (§Perf pair C, iteration 2): expert
    weights are 2D-sharded [E over 'model', F over 'data'] and NEVER move;
    the (tiny) decode token batch is all-gathered over 'data', every shard
    computes the partial-F expert outputs for all tokens, partials psum over
    'data', and each shard keeps its own batch rows. Token payloads are ~MBs
    versus ~1GB/layer of expert-weight FSDP gathers."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B, S, D = x.shape
    bw = 1
    for a in ba:
        bw *= sizes[a]
    batch_sharded = B % max(bw, 1) == 0 and bw > 1

    def local_fn(p_local, x_l):
        dtype = x_l.dtype
        if batch_sharded:
            xg = jax.lax.all_gather(x_l, ba, axis=0, tiled=True)  # [B,S,D] full
        else:
            xg = x_l
        T = xg.shape[0] * xg.shape[1]
        xf = xg.reshape(T, D)
        p_routed = {k: v for k, v in p_local.items() if k != "shared"}
        y_part, aux = _dispatch_chunk(p_routed, xf, cfg, model_size, dtype)
        y = jax.lax.psum(y_part, ba) if ba else y_part  # combine F partials
        if "shared" in p_local:
            sh = p_local["shared"]
            h = xf @ sh["wi"].astype(dtype)
            g = xf @ sh["wg"].astype(dtype)
            part = (jax.nn.silu(g) * h) @ sh["wo"].astype(dtype)
            y = y + jax.lax.psum(part, "model")
        y = y.reshape(xg.shape)
        if batch_sharded:
            me = jax.lax.axis_index(ba)
            Bl = x_l.shape[0]
            y = jax.lax.dynamic_slice_in_dim(y, me * Bl, Bl, axis=0)
        return y, aux

    p_specs = {
        "router": P(None, None),
        "wi": P("model", None, "data"),
        "wg": P("model", None, "data"),
        "wo": P("model", "data", None),
    }
    if "shared" in p:
        p_specs["shared"] = {"wi": P(None, "model"), "wg": P(None, "model"),
                             "wo": P("model", None)}
    x_spec = P(ba if (ba and batch_sharded) else None, None, None)
    fn = shard_map(local_fn, mesh=mesh, in_specs=(p_specs, x_spec),
                   out_specs=(x_spec, P()), check_vma=False)
    return fn(p, x)


def _moe_expert_parallel(p, x, cfg, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ways = 1
    for a in batch_axes:
        batch_ways *= sizes[a]
    if x.shape[0] % max(batch_ways, 1) != 0:
        batch_axes = ()  # e.g. decode with global batch 1: replicate tokens
    rules = active_rules() or {}
    seq_sharded = (rules.get("act_res_seq") == "model"
                   and x.shape[1] % model_size == 0)

    def local_fn(p_local, x_local):
        Bl, S, D = x_local.shape
        dtype = x_local.dtype
        T = Bl * S
        chunk = min(cfg.moe_dispatch_chunk, T)
        n = T // chunk
        assert T % chunk == 0, (T, chunk)
        xf = x_local.reshape(n, chunk, D)

        dispatch = (_dispatch_chunk_dedup if cfg.moe_group_limit
                    else _dispatch_chunk)

        @jax.checkpoint
        def body(_, x_c):
            y_c, aux = dispatch(p_local, x_c, cfg, model_size, dtype,
                                shared_local=seq_sharded)
            return None, (y_c, aux)

        _, (ys, auxs) = jax.lax.scan(body, None, xf)
        y = ys.reshape(Bl, S, D)
        aux = auxs.mean()
        mean_axes = tuple(batch_axes) + (("model",) if seq_sharded else ())
        aux = jax.lax.pmean(aux, mean_axes) if mean_axes else aux
        return y, aux

    # device-local views: experts split over 'model'; x split over batch axes.
    p_specs = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wg": P("model", None, None),
        "wo": P("model", None, None),
    }
    if "shared" in p:
        if seq_sharded:
            p_specs["shared"] = {"wi": P(None, None), "wg": P(None, None),
                                 "wo": P(None, None)}
        else:
            p_specs["shared"] = {"wi": P(None, "model"), "wg": P(None, "model"),
                                 "wo": P("model", None)}
    x_spec = P(batch_axes if batch_axes else None,
               "model" if seq_sharded else None, None)
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(p, x)


def moe_apply(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar)."""
    mesh = active_mesh()
    if mesh is not None and "model" in mesh.axis_names and cfg.num_experts % (
        dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    ) == 0:
        rules = active_rules() or {}
        if rules.get("_moe_2d") and x.shape[0] * x.shape[1] <= 4096:
            return _moe_decode_2d(p, x, cfg, mesh)
        return _moe_expert_parallel(p, x, cfg, mesh)
    return _moe_reference(p, x, cfg)
