"""Core neural layers: ParamBuilder, norms, RoPE (full/half/M-RoPE),
chunked flash-style attention, decode attention (incl. sequence-sharded
flash-decode), GQA/MLA attention blocks, SwiGLU MLP.

All functions are pure; parameters are plain dict pytrees created by
``ParamBuilder`` so that the value pytree, the logical-axes pytree, and the
abstract-shape pytree are guaranteed structurally identical.
"""
from __future__ import annotations

import contextlib
import zlib
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.launch.sharding import active_mesh, active_rules, logical

# ---------------------------------------------------------------------------
# ParamBuilder
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Builds parameter pytrees in one of three modes.

    mode='init'  -> arrays (deterministic per-path RNG)
    mode='axes'  -> logical-axes tuples (for sharding rules)
    mode='shape' -> jax.ShapeDtypeStruct (for AOT dry-runs, no allocation)
    """

    def __init__(self, mode: str, key: Optional[jax.Array] = None, param_dtype=jnp.float32):
        assert mode in ("init", "axes", "shape")
        if mode == "init":
            assert key is not None
        self.mode = mode
        self.key = key
        self.param_dtype = param_dtype
        self._prefix = []
        self._stack = []

    @contextlib.contextmanager
    def scope(self, name: str):
        self._prefix.append(name)
        try:
            yield
        finally:
            self._prefix.pop()

    @contextlib.contextmanager
    def stacked(self, n: int):
        """All params created inside get a leading (n,) 'layer' dim."""
        self._stack.append(n)
        try:
            yield
        finally:
            self._stack.pop()

    def param(self, name, shape, axes, init="fan_in", fan_in=None, scale=1.0):
        assert len(shape) == len(axes), (name, shape, axes)
        full_shape = tuple(self._stack) + tuple(shape)
        full_axes = ("layer",) * len(self._stack) + tuple(axes)
        if self.mode == "axes":
            return full_axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(full_shape, self.param_dtype)
        path = "/".join(self._prefix + [name])
        k = jax.random.fold_in(self.key, zlib.crc32(path.encode()))
        if init == "zeros":
            return jnp.zeros(full_shape, self.param_dtype)
        if init == "ones":
            return jnp.ones(full_shape, self.param_dtype)
        if init == "fan_in":
            fi = fan_in if fan_in is not None else (shape[0] if shape else 1)
            std = scale / max(float(fi), 1.0) ** 0.5
        elif init == "normal":
            std = scale
        else:
            raise ValueError(init)
        return (jax.random.normal(k, full_shape, jnp.float32) * std).astype(self.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(b: ParamBuilder, name: str, dim: int):
    with b.scope(name):
        return {"scale": b.param("scale", (dim,), ("act_embed",), init="ones")}


def rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def _rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _apply_rotary(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x [..., 2m] rotated pairwise by angles [..., m] (broadcastable)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float, style: str = "full"):
    """x [B,S,H,Dh]; positions [B,S] (or [3,B,S] for mrope)."""
    dh = x.shape[-1]
    if style == "half":
        rot, keep = jnp.split(x, 2, axis=-1)
        freqs = _rope_freqs(dh // 2, theta)
        ang = positions[..., None, None].astype(jnp.float32) * freqs  # [B,S,1,m]
        return jnp.concatenate([_apply_rotary(rot, ang), keep], axis=-1)
    if style == "mrope":
        assert positions.ndim == 3, "mrope needs [3,B,S] position triplets"
        half = dh // 2
        s_hw = 3 * half // 8
        sections = (half - 2 * s_hw, s_hw, s_hw)  # (t, h, w): [16,24,24] for dh=128
        freqs = _rope_freqs(dh, theta)  # [half]
        ang_parts = []
        off = 0
        for i, sec in enumerate(sections):
            p = positions[i][..., None, None].astype(jnp.float32)  # [B,S,1,1]
            ang_parts.append(p * freqs[off : off + sec])
            off += sec
        ang = jnp.concatenate(ang_parts, axis=-1)  # [B,S,1,half]
        return _apply_rotary(x, ang)
    # full
    freqs = _rope_freqs(dh, theta)
    ang = positions[..., None, None].astype(jnp.float32) * freqs
    return _apply_rotary(x, ang)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure JAX, TPU-fusable; the Pallas kernel
# in repro.kernels.flash_attention is the TPU-target twin of this routine.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_mask(q_idx, k_idx, causal: bool, window: int):
    m = jnp.ones((q_idx.shape[0], k_idx.shape[0]), jnp.bool_)
    if causal:
        m &= k_idx[None, :] <= q_idx[:, None]
    if window > 0:
        m &= q_idx[:, None] - k_idx[None, :] < window
    return m


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q [B,S,H,D]; k,v [B,T,H,D] (heads already expanded). Streaming softmax
    over kv chunks; memory O(S*chunk) instead of O(S*T)."""
    B, S, H, Dh = q.shape
    Dv = v.shape[-1]
    T = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, q_chunk, T, kv_chunk)
    nq, nk = S // q_chunk, T // kv_chunk
    scale = Dh ** -0.5

    kr = k.reshape(B, nk, kv_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, kv_chunk, H, Dv).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def one_q_chunk(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        q_idx = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint
        def body(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, ki = inp
            k_idx = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk, preferred_element_type=jnp.float32)
            s = s * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            mask = _attn_mask(q_idx, k_idx, causal, window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
            jnp.zeros((B, H, q_chunk, Dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,qc,H,Dv]

    outs = jax.lax.map(one_q_chunk, jnp.arange(nq))  # [nq,B,qc,H,Dv]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """[B,T,KV,D] -> [B,T,KV*n_rep,D] (contiguous groups)."""
    if n_rep == 1:
        return x
    B, T, KV, Dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, T, KV, n_rep, Dh)).reshape(B, T, KV * n_rep, Dh)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0, softcap: float = 0.0):
    """q [B,1,H,D]; caches [B,T,H,D] (heads expanded); cache_len scalar or
    per-batch [B] vector (continuous batching)."""
    B, _, H, Dh = q.shape
    T = k_cache.shape[1]
    scale = Dh ** -0.5
    s = jnp.einsum("bqhd,bthd->bhqt", q, k_cache, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    t_idx = jnp.arange(T)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cl = jnp.broadcast_to(cache_len, (B, 1))
    else:
        cl = cache_len.reshape(B, 1)
    valid = t_idx[None, :] < cl
    if window > 0:
        valid &= t_idx[None, :] > cl - 1 - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqt,bthd->bqhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def flash_decode_sharded(q, k_cache, v_cache, cache_len, *, axis: str = "data"):
    """Sequence-sharded decode attention (long_500k): the KV cache's T axis is
    sharded over ``axis``; partial softmax stats are LSE-combined with psum.

    Called INSIDE shard_map: all inputs are device-local views;
    k_cache/v_cache [B, T_local, H, D]; the global position of local slot t is
    axis_index(axis)*T_local + t.
    """
    B, _, H, Dh = q.shape
    T_l = k_cache.shape[1]
    scale = Dh ** -0.5
    shard = jax.lax.axis_index(axis)
    t_idx = shard * T_l + jnp.arange(T_l)
    s = jnp.einsum("bqhd,bthd->bhqt", q, k_cache, preferred_element_type=jnp.float32) * scale
    s = jnp.where((t_idx < cache_len)[None, None, None, :], s, NEG_INF)
    m_l = s.max(-1)  # [B,H,1]
    p = jnp.exp(s - m_l[..., None])
    l_l = p.sum(-1)
    acc_l = jnp.einsum("bhqt,bthd->bhqd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    m_g = jax.lax.pmax(m_l, axis)
    c = jnp.exp(m_l - m_g)
    l_g = jax.lax.psum(l_l * c, axis)
    acc_g = jax.lax.psum(acc_l * c[..., None], axis)
    out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,1,H,D]


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def attention_params(b: ParamBuilder, cfg, name="attn", cross: bool = False):
    D, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    with b.scope(name):
        p = {
            "wq": b.param("wq", (D, H, Dh), ("embed", "heads", "head")),
            "wk": b.param("wk", (D, KV, Dh), ("embed", "kv_heads", "head")),
            "wv": b.param("wv", (D, KV, Dh), ("embed", "kv_heads", "head")),
            "wo": b.param("wo", (H, Dh, D), ("heads", "head", "embed"), fan_in=H * Dh),
        }
        if cfg.qkv_bias and not cross:
            p["bq"] = b.param("bq", (H, Dh), ("heads", "head"), init="zeros")
            p["bk"] = b.param("bk", (KV, Dh), ("kv_heads", "head"), init="zeros")
            p["bv"] = b.param("bv", (KV, Dh), ("kv_heads", "head"), init="zeros")
    return p


def attention_qkv(p, x, cfg, *, kv_x=None, positions=None, rope: bool = True):
    """Returns q [B,S,H,D], k,v [B,T,KV,D] with RoPE applied to q,k."""
    dtype = x.dtype
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
        k = apply_rope(k, positions if kv_x is None else positions, cfg.rope_theta, cfg.rope_style)
    q = logical(q, "act_batch", "act_seq", "act_heads", None)
    k = logical(k, "act_batch", "act_kv_seq", "act_kv_heads", None)
    v = logical(v, "act_batch", "act_kv_seq", "act_kv_heads", None)
    return q, k, v


def attention_out(p, y, dtype):
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(dtype))
    return logical(out, "act_batch", "act_res_seq", "act_embed")


def attention_apply(p, x, positions, cfg, *, kv_x=None, causal=True, window=0,
                    q_chunk=512, kv_chunk=1024):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = attention_qkv(p, x, cfg, kv_x=kv_x, positions=positions, rope=(kv_x is None))
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    y = chunked_attention(q, k, v, causal=causal, window=window,
                          softcap=cfg.attn_logit_softcap, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return attention_out(p, y, x.dtype)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------


def mla_params(b: ParamBuilder, cfg, name="attn"):
    D, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    R, Rh = cfg.kv_lora_rank, cfg.rope_head_dim
    with b.scope(name):
        return {
            "wq": b.param("wq", (D, H, Dh + Rh), ("embed", "heads", "head")),
            "w_dkv": b.param("w_dkv", (D, R + Rh), ("embed", "kv_lora")),
            # R stays unsharded here: 'heads' already consumes the model axis
            # (the kv_lora rule shards the *cache*, not these projections).
            "w_uk": b.param("w_uk", (R, H, Dh), (None, "heads", "head"), fan_in=R),
            "w_uv": b.param("w_uv", (R, H, Dh), (None, "heads", "head"), fan_in=R),
            "wo": b.param("wo", (H, Dh, D), ("heads", "head", "embed"), fan_in=H * Dh),
        }


def mla_compress(p, x, positions, cfg):
    """Returns compressed cache entries: c [B,T,R], k_rope [B,T,Rh]."""
    dtype = x.dtype
    ckv = jnp.einsum("btd,dr->btr", x, p["w_dkv"].astype(dtype))
    c, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank :]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta, "full")[:, :, 0]
    return c, k_rope


def mla_queries(p, x, positions, cfg):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    q_nope, q_rope = q[..., : cfg.head_dim], q[..., cfg.head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "full")
    return q_nope, q_rope


def mla_apply(p, x, positions, cfg, *, causal=True, window=0, q_chunk=512, kv_chunk=1024):
    """Training/prefill path: expand compressed KV to per-head K,V."""
    dtype = x.dtype
    c, k_rope = mla_compress(p, x, positions, cfg)
    q_nope, q_rope = mla_queries(p, x, positions, cfg)
    k_nope = jnp.einsum("btr,rhk->bthk", c, p["w_uk"].astype(dtype))
    v = jnp.einsum("btr,rhk->bthk", c, p["w_uv"].astype(dtype))
    H = cfg.num_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (H, cfg.rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = logical(q, "act_batch", "act_seq", "act_heads", None)
    k = logical(k, "act_batch", "act_kv_seq", "act_heads", None)
    v = logical(v, "act_batch", "act_kv_seq", "act_heads", None)
    y = chunked_attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return attention_out(p, y, dtype)


def mla_decode(p, x, c_cache, krope_cache, pos, cfg):
    """Absorbed-projection decode: attention in the compressed space.
    x [B,1,D]; c_cache [B,T,R]; krope_cache [B,T,Rh]. Returns [B,1,D] and new
    cache entries for position pos."""
    dtype = x.dtype
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    c_new, kr_new = mla_compress(p, x, positions, cfg)
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new.astype(c_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(krope_cache, kr_new.astype(krope_cache.dtype), pos, axis=1)
    q_nope, q_rope = mla_queries(p, x, positions, cfg)
    # absorb: q_eff [B,1,H,R] = q_nope @ w_uk
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(dtype))
    s = jnp.einsum("bshr,btr->bhst", q_eff, c_cache.astype(dtype), preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshk,btk->bhst", q_rope, krope_cache.astype(dtype), preferred_element_type=jnp.float32)
    s = s * ((cfg.head_dim + cfg.rope_head_dim) ** -0.5)
    T = c_cache.shape[1]
    valid = jnp.arange(T) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", prob.astype(dtype), c_cache.astype(dtype))
    y = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(dtype))
    return attention_out(p, y, dtype), c_cache, krope_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_params(b: ParamBuilder, cfg, name="mlp", d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    with b.scope(name):
        return {
            "wi": b.param("wi", (D, F), ("embed", "mlp")),
            "wg": b.param("wg", (D, F), ("embed", "mlp")),
            "wo": b.param("wo", (F, D), ("mlp", "embed")),
        }


def mlp_apply(p, x):
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    h = logical(h, "act_batch", "act_seq", "act_ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))
    return logical(out, "act_batch", "act_res_seq", "act_embed")


def mla_decode_seqsharded(p, x, c_cache, kr_cache, pos, cfg):
    """MLA flash-decode with the compressed cache SEQUENCE-sharded over
    'model' (§Perf pair C): scores/LSE are computed per T-shard and combined
    with psum; heads stay sharded for the projections and only the tiny
    [B,1,H,R] effective queries are gathered. Per-layer collective payload is
    ~5MB instead of gathering the 512MB compressed cache."""
    mesh = active_mesh()
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    heads_shard = cfg.num_heads % msize == 0
    xs = P(ba if ba else None, None, None)
    cs = P(ba if ba else None, "model", None)
    wq_spec = P(None, "model", None) if heads_shard else P(None, None, None)
    wuk_spec = P(None, "model", None) if heads_shard else P(None, None, None)
    wo_spec = P("model", None, None) if heads_shard else P(None, None, None)

    def local(wq, w_dkv, w_uk, w_uv, wo, x_l, c_l, kr_l, pos_s):
        dt = x_l.dtype
        R, Rh = cfg.kv_lora_rank, cfg.rope_head_dim
        Bl = x_l.shape[0]
        positions = jnp.full((Bl, 1), pos_s, jnp.int32)
        # new compressed entries (replicated compute across model shards)
        ckv = jnp.einsum("btd,dr->btr", x_l, w_dkv.astype(dt))
        c_new, kr_new = ckv[..., :R], ckv[..., R:]
        kr_new = apply_rope(kr_new[:, :, None, :], positions, cfg.rope_theta, "full")[:, :, 0]
        T_l = c_l.shape[1]
        me = jax.lax.axis_index("model")
        off = pos_s - me * T_l
        in_range = (off >= 0) & (off < T_l)
        off_c = jnp.clip(off, 0, T_l - 1)
        c_upd = jax.lax.dynamic_update_slice_in_dim(c_l, c_new.astype(c_l.dtype), off_c, 1)
        kr_upd = jax.lax.dynamic_update_slice_in_dim(kr_l, kr_new.astype(kr_l.dtype), off_c, 1)
        c_l = jnp.where(in_range, c_upd, c_l)
        kr_l = jnp.where(in_range, kr_upd, kr_l)
        # queries on the local head slice, absorbed, then gathered (tiny)
        q = jnp.einsum("bsd,dhk->bshk", x_l, wq.astype(dt))
        q_nope, q_rope = q[..., : cfg.head_dim], q[..., cfg.head_dim :]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "full")
        q_eff_l = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk.astype(dt))
        if heads_shard:
            q_eff = jax.lax.all_gather(q_eff_l, "model", axis=2, tiled=True)
            q_rope_f = jax.lax.all_gather(q_rope, "model", axis=2, tiled=True)
        else:
            q_eff, q_rope_f = q_eff_l, q_rope
        # local scores over the T shard, all heads
        s = jnp.einsum("bshr,btr->bhst", q_eff, c_l.astype(dt),
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bshk,btk->bhst", q_rope_f, kr_l.astype(dt),
                           preferred_element_type=jnp.float32)
        s = s * ((cfg.head_dim + cfg.rope_head_dim) ** -0.5)
        t_idx = me * T_l + jnp.arange(T_l)
        s = jnp.where((t_idx <= pos_s)[None, None, None, :], s, NEG_INF)
        m_l = s.max(-1)  # [B,H,1]
        m_g = jax.lax.pmax(m_l, "model")
        e = jnp.exp(s - m_g[..., None])
        l_g = jax.lax.psum(e.sum(-1), "model")  # [B,H,1]
        ctx = jnp.einsum("bhst,btr->bshr", e.astype(dt), c_l.astype(dt),
                         preferred_element_type=jnp.float32)
        ctx = jax.lax.psum(ctx, "model")  # [B,1,H,R]
        ctx = (ctx / jnp.maximum(l_g, 1e-30).transpose(0, 2, 1)[..., None]).astype(dt)
        # back to the local head slice for the value/out projections
        if heads_shard:
            H_l = wo.shape[0]
            ctx_l = jax.lax.dynamic_slice_in_dim(ctx, me * H_l, H_l, axis=2)
        else:
            ctx_l = ctx
        y = jnp.einsum("bshr,rhk->bshk", ctx_l, w_uv.astype(dt))
        out = jnp.einsum("bshk,hkd->bsd", y, wo.astype(dt))
        if heads_shard:
            out = jax.lax.psum(out, "model")
        return out, c_l, kr_l

    return shard_map(
        local, mesh=mesh,
        in_specs=(wq_spec, P(None, None), wuk_spec, wuk_spec, wo_spec,
                  xs, cs, cs, P()),
        out_specs=(xs, cs, cs), check_vma=False,
    )(p["wq"], p["w_dkv"], p["w_uk"], p["w_uv"], p["wo"], x, c_cache, kr_cache, pos)
