"""SSM layers: RWKV6 ("Finch", data-dependent per-channel decay) and Mamba2
(SSD, scalar-per-head data-dependent decay), implemented as a *chunked* linear
attention scan.

TPU adaptation (DESIGN.md §2): instead of the per-timestep recurrence used by
CUDA implementations, the sequence is split into chunks; within a chunk the
contribution is a masked matmul (MXU-friendly), across chunks a [H,K,V] state
is carried by lax.scan. This is exactly the survey's *sequential chunk-based
execution model* (§6.2.1) applied to the time dimension.

Numerics: log-decays are clamped to >= LOG_DECAY_MIN per step so that the
exp(+|L|) factors in the factorized intra-chunk matmul stay inside fp32 range
for chunk lengths <= 128 (a token >= 64 steps away at the clamp is attenuated
by < e^-76, i.e. exactly zero in fp32 — no information is lost).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import logical
from repro.models.layers import ParamBuilder, rmsnorm, rmsnorm_params

LOG_DECAY_MIN = -1.2


def _chunked_linear_attention(q, k, v, log_decay, *, chunk: int, mode: str,
                              bonus: Optional[jnp.ndarray] = None,
                              init_state: Optional[jnp.ndarray] = None,
                              return_state: bool = False):
    """y_t = sum_{s} decay(s,t) (q_t . k_s) v_s, chunked.

    q,k [B,S,H,K]; v [B,S,H,V]; log_decay [B,S,H,K] (rwkv) or [B,S,H,1] (mamba).
    mode='mamba': inclusive (s<=t), decay prod over (s,t].
    mode='rwkv' : strictly past (s<t), decay prod over (s,t-1], plus bonus
                  term (q_t . (u*k_t)) v_t with u [H,K].
    Returns y [B,S,H,V] (fp32 accumulate, cast to q.dtype) and optionally the
    final state [B,H,K,V].
    """
    B, S, H, K = q.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    f32 = jnp.float32
    out_dtype = q.dtype
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    g = jnp.clip(log_decay.astype(f32), LOG_DECAY_MIN, 0.0)
    g = jnp.broadcast_to(g, (B, S, H, K))
    if pad:
        # zero k/v and unit decay on the tail: earlier outputs unaffected,
        # final state unchanged by padded steps.
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, g = zpad(q), zpad(k), zpad(v), zpad(g)
    S_pad = S + pad
    n = S_pad // chunk

    # [n, B, chunk, H, *]
    def split(x):
        return x.reshape(B, n, chunk, H, -1).transpose(1, 0, 2, 3, 4)

    qs, ks, vs, gs = split(q), split(k), split(v), split(g)
    state0 = (jnp.zeros((B, H, K, V), f32) if init_state is None
              else init_state.astype(f32))
    mask_incl = jnp.tril(jnp.ones((chunk, chunk), bool))  # s <= t
    mask_strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # s < t

    def body(state, inp):
        qc, kc, vc, gc = inp  # [B,chunk,H,*]
        L = jnp.cumsum(gc, axis=1)  # inclusive cumulative log decay [B,c,H,K]
        L_end = L[:, -1]  # [B,H,K]
        if mode == "mamba":
            q_eff = qc * jnp.exp(L)
            k_eff = kc * jnp.exp(-L)
            mask = mask_incl
        else:  # rwkv: past decay over (s, t-1]
            L_prev = L - gc  # exclusive cumsum
            q_eff = qc * jnp.exp(L_prev)
            k_eff = kc * jnp.exp(-L)
            mask = mask_strict
        # intra-chunk
        A = jnp.einsum("bthk,bshk->bhts", q_eff, k_eff)
        A = jnp.where(mask[None, None], A, 0.0)
        y = jnp.einsum("bhts,bshv->bthv", A, vc)
        if mode == "rwkv" and bonus is not None:
            coef = jnp.einsum("bthk,hk->bth", qc * kc, bonus.astype(f32))
            y = y + coef[..., None] * vc
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("bthk,bhkv->bthv", q_eff, state)
        # state update: S' = exp(L_end)*S + sum_s exp(L_end - L_s) k_s v_s^T
        k_dec = kc * jnp.exp(L_end[:, None] - L)
        state_new = jnp.exp(L_end)[..., None] * state + jnp.einsum("bshk,bshv->bhkv", k_dec, vc)
        return state_new, y

    state_f, ys = jax.lax.scan(body, state0, (qs, ks, vs, gs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S_pad, H, V)[:, :S].astype(out_dtype)
    if return_state:
        return y, state_f
    return y


def linear_attention_step(q, k, v, log_decay, state, *, mode: str,
                          bonus: Optional[jnp.ndarray] = None):
    """Single-token recurrence for decode. q,k [B,H,K]; v [B,H,V];
    log_decay [B,H,K] or [B,H,1]; state [B,H,K,V]. Returns (y [B,H,V], state)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    g = jnp.clip(log_decay.astype(f32), LOG_DECAY_MIN, 0.0)
    g = jnp.broadcast_to(g, k.shape)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    if mode == "mamba":
        state = jnp.exp(g)[..., None] * state + kv
        y = jnp.einsum("bhk,bhkv->bhv", q, state)
    else:
        eff = state + (bonus.astype(f32)[None, ..., None] * kv if bonus is not None else kv)
        y = jnp.einsum("bhk,bhkv->bhv", q, eff)
        state = jnp.exp(g)[..., None] * state + kv
    return y, state


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------


def rwkv6_params(b: ParamBuilder, cfg):
    D = cfg.d_model
    H, K = cfg.ssm_heads, cfg.ssm_state
    inner = H * K
    lora = max(32, D // 16)
    with b.scope("rwkv"):
        p = {
            "w_r": b.param("w_r", (D, inner), ("embed", "ssm_inner")),
            "w_k": b.param("w_k", (D, inner), ("embed", "ssm_inner")),
            "w_v": b.param("w_v", (D, inner), ("embed", "ssm_inner")),
            "w_g": b.param("w_g", (D, inner), ("embed", "ssm_inner")),
            "w_o": b.param("w_o", (inner, D), ("ssm_inner", "embed")),
            # data-dependent decay (low-rank, "Finch")
            "wd1": b.param("wd1", (D, lora), ("embed", None)),
            "wd2": b.param("wd2", (lora, inner), (None, "ssm_inner"), init="zeros"),
            "w0": b.param("w0", (inner,), ("ssm_inner",), init="zeros"),
            "u": b.param("u", (H, K), ("ssm_heads", "ssm_state"), init="zeros"),
            # token-shift mix coefficients
            "mu": b.param("mu", (5, D), (None, "embed"), init="zeros"),
            "ln_x": b.param("ln_x", (inner,), ("ssm_inner",), init="ones"),
        }
    return p


def _token_shift(x, prev: Optional[jnp.ndarray] = None):
    """shift(x)[t] = x[t-1]; position 0 gets `prev` (decode state) or 0."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def rwkv6_time_mix(p, x, cfg, *, prev_x=None, state=None, chunk=None,
                   return_state=False):
    """x [B,S,D]. Returns y [B,S,D] (and (last_x, state) if return_state)."""
    B, S, D = x.shape
    H, K = cfg.ssm_heads, cfg.ssm_state
    dtype = x.dtype
    xs = _token_shift(x, prev_x)
    mu = p["mu"].astype(dtype)
    xr, xk, xv, xg, xw = [x + (xs - x) * jax.nn.sigmoid(mu[i]) for i in range(5)]
    r = (xr @ p["w_r"].astype(dtype)).reshape(B, S, H, K)
    k = (xk @ p["w_k"].astype(dtype)).reshape(B, S, H, K)
    v = (xv @ p["w_v"].astype(dtype)).reshape(B, S, H, K)
    gate = jax.nn.silu(xg @ p["w_g"].astype(dtype))
    # per-channel data-dependent log decay: -exp(w0 + tanh(x wd1) wd2)
    wlog = p["w0"].astype(jnp.float32) + (jnp.tanh(xw.astype(jnp.float32) @ p["wd1"].astype(jnp.float32))
                                          @ p["wd2"].astype(jnp.float32))
    log_decay = (-jnp.exp(wlog)).reshape(B, S, H, K)
    if return_state:
        y, state_f = _chunked_linear_attention(
            r, k, v, log_decay, chunk=chunk or cfg.ssm_chunk, mode="rwkv",
            bonus=p["u"], init_state=state, return_state=True)
    else:
        y = _chunked_linear_attention(r, k, v, log_decay, chunk=chunk or cfg.ssm_chunk,
                                      mode="rwkv", bonus=p["u"], init_state=state)
    y = y.reshape(B, S, H * K)
    y = rmsnorm({"scale": p["ln_x"]}, y, 1e-5) * gate.astype(y.dtype)
    out = (y.astype(dtype) @ p["w_o"].astype(dtype))
    out = logical(out, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        return out, (x[:, -1], state_f)
    return out


def rwkv6_time_mix_step(p, x, cfg, prev_x, state):
    """Single-token decode. x [B,D]; prev_x [B,D]; state [B,H,K,K]."""
    y, (last_x, state_f) = rwkv6_time_mix(p, x[:, None], cfg, prev_x=prev_x,
                                          state=state, chunk=1, return_state=True)
    return y[:, 0], (last_x, state_f)


def rwkv6_channel_mix_params(b: ParamBuilder, cfg):
    D, F = cfg.d_model, cfg.d_ff
    with b.scope("cmix"):
        return {
            "w_k": b.param("w_k", (D, F), ("embed", "mlp")),
            "w_v": b.param("w_v", (F, D), ("mlp", "embed")),
            "mu": b.param("mu", (D,), ("embed",), init="zeros"),
        }


def rwkv6_channel_mix(p, x, *, prev_x=None, return_state=False):
    dtype = x.dtype
    xs = _token_shift(x, prev_x)
    xk = x + (xs - x) * jax.nn.sigmoid(p["mu"].astype(dtype))
    h = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dtype)))
    h = logical(h, "act_batch", "act_seq", "act_ff")
    out = h @ p["w_v"].astype(dtype)
    out = logical(out, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        return out, x[:, -1]
    return out


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------


def mamba2_params(b: ParamBuilder, cfg):
    D = cfg.d_model
    H, N = cfg.ssm_heads, cfg.ssm_state
    d_inner = 2 * D
    assert d_inner % H == 0
    with b.scope("mamba"):
        return {
            "w_in": b.param("w_in", (D, 2 * d_inner + 2 * N + H), ("embed", "ssm_inner")),
            "conv_w": b.param("conv_w", (cfg.ssm_conv, d_inner), ("conv", "ssm_inner"),
                              init="normal", scale=0.1),
            "a_log": b.param("a_log", (H,), ("ssm_heads",), init="zeros"),
            "dt_bias": b.param("dt_bias", (H,), ("ssm_heads",), init="zeros"),
            "d_skip": b.param("d_skip", (H,), ("ssm_heads",), init="ones"),
            "w_out": b.param("w_out", (d_inner, D), ("ssm_inner", "embed"), fan_in=d_inner),
            "ln_y": b.param("ln_y", (d_inner,), ("ssm_inner",), init="ones"),
        }


def _causal_conv(x, w, *, conv_state=None):
    """Depthwise causal conv. x [B,S,C]; w [W,C]; conv_state [B,W-1,C]."""
    W = w.shape[0]
    pad = conv_state if conv_state is not None else jnp.zeros(
        (x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(pad)
    return out, new_state


def mamba2_apply(p, x, cfg, *, conv_state=None, ssm_state=None, return_state=False):
    """x [B,S,D] -> [B,S,D]."""
    B, S, D = x.shape
    H, N = cfg.ssm_heads, cfg.ssm_state
    d_inner = 2 * D
    P_dim = d_inner // H
    dtype = x.dtype
    zxbcdt = x @ p["w_in"].astype(dtype)
    z, xin, Bmat, Cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    xin, new_conv = _causal_conv(xin, p["conv_w"], conv_state=conv_state)
    xin = jax.nn.silu(xin)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H] (negative)
    log_decay = (dt * a)[..., None]  # [B,S,H,1]
    xh = xin.reshape(B, S, H, P_dim)
    v = xh * dt[..., None].astype(dtype)  # dt-scaled input is the "value"
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N))
    if return_state:
        y, state_f = _chunked_linear_attention(q, k, v, log_decay, chunk=min(cfg.ssm_chunk, S),
                                               mode="mamba", init_state=ssm_state,
                                               return_state=True)
    else:
        y = _chunked_linear_attention(q, k, v, log_decay, chunk=min(cfg.ssm_chunk, S),
                                      mode="mamba", init_state=ssm_state)
    y = y + xh.astype(y.dtype) * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm({"scale": p["ln_y"]}, y, 1e-5)
    y = y * jax.nn.silu(z.astype(y.dtype))
    out = y.astype(dtype) @ p["w_out"].astype(dtype)
    out = logical(out, "act_batch", "act_res_seq", "act_embed")
    if return_state:
        return out, (new_conv, state_f)
    return out


def mamba2_step(p, x, cfg, conv_state, ssm_state):
    """Single-token decode. x [B,D]; conv_state [B,W-1,d_inner];
    ssm_state [B,H,N,P]."""
    y, (new_conv, state_f) = mamba2_apply(p, x[:, None], cfg, conv_state=conv_state,
                                          ssm_state=ssm_state, return_state=True)
    return y[:, 0], (new_conv, state_f)
