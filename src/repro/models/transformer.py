"""The composable transformer: parameter construction, full-sequence forward
(train / prefill), and single-token decode (serve) for every assigned family:
dense GQA, MoE (+MLA), RWKV6, Mamba2 hybrid with shared attention, enc-dec,
and VLM/audio embedding inputs.

Everything is a pure function of (cfg, params, batch); distribution enters
only through logical sharding annotations and the MoE shard_map path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import active_mesh, active_rules, logical, spec_for
from repro.models import ssm as ssm_lib
from repro.models.kvcache import num_attn_applications
from repro.models.layers import (
    ParamBuilder,
    attention_apply,
    attention_out,
    attention_qkv,
    decode_attention,
    flash_decode_sharded,
    mla_apply,
    mla_decode,
    mla_params,
    attention_params,
    mlp_apply,
    mlp_params,
    repeat_kv,
    rmsnorm,
    rmsnorm_params,
)
from repro.models.moe import moe_apply, moe_params

from repro.compat import shard_map


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    window: int = 0  # sliding window for dense long-context variants
    seq_sharded_cache: bool = False  # long_500k: KV cache seq-sharded over 'data'


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _block_params(b: ParamBuilder, cfg, *, moe: bool, decoder_cross: bool):
    p: Dict[str, Any] = {}
    p["ln1"] = rmsnorm_params(b, "ln1", cfg.d_model)
    p["ln2"] = rmsnorm_params(b, "ln2", cfg.d_model)
    if cfg.ssm_kind == "rwkv6":
        p["tmix"] = ssm_lib.rwkv6_params(b, cfg)
        p["cmix"] = ssm_lib.rwkv6_channel_mix_params(b, cfg)
        return p
    if cfg.ssm_kind == "mamba2":
        p["mixer"] = ssm_lib.mamba2_params(b, cfg)
        p["mlp"] = mlp_params(b, cfg)
        return p
    p["attn"] = mla_params(b, cfg) if cfg.use_mla else attention_params(b, cfg)
    if decoder_cross:
        p["ln_x"] = rmsnorm_params(b, "ln_x", cfg.d_model)
        p["xattn"] = attention_params(b, cfg, name="xattn", cross=True)
    if moe:
        p["moe"] = moe_params(b, cfg)
    else:
        p["mlp"] = mlp_params(b, cfg)
    return p


def build_params(cfg, b: ParamBuilder):
    params: Dict[str, Any] = {}
    params["embed"] = b.param("embed", (cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), init="normal", scale=0.02)
    if not cfg.tie_embeddings:
        params["lm_head"] = b.param("lm_head", (cfg.d_model, cfg.vocab_size),
                                    ("embed", "vocab"))
    params["final_norm"] = rmsnorm_params(b, "final_norm", cfg.d_model)
    cross = cfg.is_encoder_decoder
    if cfg.is_encoder_decoder:
        with b.scope("encoder"), b.stacked(cfg.encoder_layers):
            params["enc_blocks"] = _block_params(b, cfg, moe=False, decoder_cross=False)
        params["enc_norm"] = rmsnorm_params(b, "enc_norm", cfg.d_model)
    n_dense = cfg.first_k_dense if cfg.num_experts else 0
    if n_dense:
        with b.scope("head_blocks"), b.stacked(n_dense):
            params["head_blocks"] = _block_params(b, cfg, moe=False, decoder_cross=cross)
    with b.scope("blocks"), b.stacked(cfg.num_layers - n_dense):
        params["blocks"] = _block_params(b, cfg, moe=bool(cfg.num_experts), decoder_cross=cross)
    if cfg.ssm_kind and cfg.attn_every > 0:
        with b.scope("shared_attn"):
            params["shared_attn"] = {
                "ln1": rmsnorm_params(b, "ln1", cfg.d_model),
                "attn": attention_params(b, cfg),
                "ln2": rmsnorm_params(b, "ln2", cfg.d_model),
                "mlp": mlp_params(b, cfg),
            }
    return params


def init_params(cfg, key: jax.Array, param_dtype=None):
    pd = jnp.dtype(param_dtype or cfg.param_dtype)
    return build_params(cfg, ParamBuilder("init", key, pd))


def param_logical_axes(cfg):
    return build_params(cfg, ParamBuilder("axes"))


def abstract_params(cfg, param_dtype=None):
    pd = jnp.dtype(param_dtype or cfg.param_dtype)
    return build_params(cfg, ParamBuilder("shape", param_dtype=pd))


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill / encoder)
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "minimal":
        # save only the residual-stream carry (checkpoint default saves
        # nothing inside the body); measured on llama3.2-1b/train_4k this is
        # the difference between 30GiB and ~5GiB of temps per device —
        # dots_with_no_batch_dims_saveable keeps every [B,S,F] projection.
        return jax.checkpoint(fn)
    if policy == "save_tp_gather":
        # manual-TP: keep the gathered activations so backward skips the
        # re-gather collectives (trades ~2x[B,S,D] bf16 per layer of HBM)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("tp_gather"))
    return jax.checkpoint(fn)  # 'full': save nothing


def _manual_tp_on() -> bool:
    r = active_rules()
    return bool(r and r.get("_manual_tp"))


def _attn_block_seq(cfg, p, h, positions, *, enc_out, window, causal, collect_kv=False):
    hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
    kv = None
    if (_manual_tp_on() and not cfg.use_mla and not collect_kv
            and enc_out is None and not cfg.qkv_bias or
            (_manual_tp_on() and cfg.qkv_bias and not cfg.use_mla
             and not collect_kv and enc_out is None)):
        from repro.models.tp_manual import attention_tp

        h = h + attention_tp(p["attn"], hn, positions, cfg, causal=causal,
                             window=window)
        return h, None
    if cfg.use_mla:
        attn = mla_apply(p["attn"], hn, positions, cfg, causal=causal, window=window)
        if collect_kv:
            from repro.models.layers import mla_compress

            c, kr = mla_compress(p["attn"], hn, positions, cfg)
            kv = (c.astype(jnp.bfloat16), kr.astype(jnp.bfloat16))
    else:
        if collect_kv:
            q, k, v = attention_qkv(p["attn"], hn, cfg, positions=positions)
            kv = (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
            n_rep = cfg.num_heads // cfg.num_kv_heads
            from repro.models.layers import chunked_attention

            y = chunked_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                                  causal=causal, window=window,
                                  softcap=cfg.attn_logit_softcap)
            attn = attention_out(p["attn"], y, h.dtype)
        else:
            attn = attention_apply(p["attn"], hn, positions, cfg, causal=causal, window=window)
    h = h + attn
    if enc_out is not None and "xattn" in p:
        hx = rmsnorm(p["ln_x"], h, cfg.norm_eps)
        h = h + attention_apply(p["xattn"], hx, positions, cfg, kv_x=enc_out, causal=False)
    return h, kv


def _ffn_block_seq(cfg, p, h):
    hn = rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_apply(p["moe"], hn, cfg)
        return h + y, aux
    if _manual_tp_on():
        from repro.models.tp_manual import mlp_tp

        return h + mlp_tp(p["mlp"], hn, cfg), jnp.zeros((), jnp.float32)
    return h + mlp_apply(p["mlp"], hn), jnp.zeros((), jnp.float32)


def _std_block_seq(cfg, p, h, positions, *, enc_out=None, window=0, causal=True,
                   collect_kv=False):
    h, kv = _attn_block_seq(cfg, p, h, positions, enc_out=enc_out, window=window,
                            causal=causal, collect_kv=collect_kv)
    h, aux = _ffn_block_seq(cfg, p, h)
    return h, aux, kv


def _rwkv_block_seq(cfg, p, h, collect_state=False):
    if collect_state:
        y, (tm_x, s_f) = ssm_lib.rwkv6_time_mix(
            p["tmix"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg, return_state=True)
        h = h + y
        y2, cm_x = ssm_lib.rwkv6_channel_mix(
            p["cmix"], rmsnorm(p["ln2"], h, cfg.norm_eps), return_state=True)
        h = h + y2
        return h, (tm_x.astype(jnp.bfloat16), cm_x.astype(jnp.bfloat16), s_f)
    h = h + ssm_lib.rwkv6_time_mix(p["tmix"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
    h = h + ssm_lib.rwkv6_channel_mix(p["cmix"], rmsnorm(p["ln2"], h, cfg.norm_eps))
    return h, None


def _mamba_block_seq(cfg, p, h, collect_state=False):
    if collect_state:
        y, (conv, s_f) = ssm_lib.mamba2_apply(
            p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg, return_state=True)
        h = h + y
        h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, (conv.astype(jnp.bfloat16), s_f)
    h = h + ssm_lib.mamba2_apply(p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
    h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
    return h, None


def _scan_blocks(cfg, blocks, h, positions, *, enc_out=None, window=0, causal=True,
                 collect_kv=False, collect_state=False):
    """lax.scan over a stacked block pytree. Returns (h, aux_sum, kv_stack)."""

    def body(carry, p_layer):
        hh = carry
        if cfg.ssm_kind == "rwkv6":
            hh, st = _rwkv_block_seq(cfg, p_layer, hh, collect_state)
            return hh, (jnp.zeros((), jnp.float32), st)
        if cfg.ssm_kind == "mamba2":
            hh, st = _mamba_block_seq(cfg, p_layer, hh, collect_state)
            return hh, (jnp.zeros((), jnp.float32), st)
        hh, aux, kv = _std_block_seq(cfg, p_layer, hh, positions, enc_out=enc_out,
                                     window=window, causal=causal, collect_kv=collect_kv)
        return hh, (aux, kv)

    body = _remat(body, cfg.remat_policy)
    h, (auxs, kvs) = jax.lax.scan(body, h, blocks)
    return h, auxs.sum(), kvs


def _hybrid_segments(cfg) -> List[Tuple[int, int, bool]]:
    segs, start = [], 0
    for i in range(cfg.num_layers):
        if cfg._layer_has_attn(i):
            segs.append((start, i + 1, True))
            start = i + 1
    if start < cfg.num_layers:
        segs.append((start, cfg.num_layers, False))
    return segs


def _tree_slice(tree, s, e):
    return jax.tree_util.tree_map(lambda a: a[s:e], tree)


def _shared_attn_apply(cfg, p, h, positions, *, window=0, collect_kv=False):
    hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
    kv = None
    if collect_kv:
        q, k, v = attention_qkv(p["attn"], hn, cfg, positions=positions)
        kv = (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
        from repro.models.layers import chunked_attention

        n_rep = cfg.num_heads // cfg.num_kv_heads
        y = chunked_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep), causal=True,
                              window=window)
        h = h + attention_out(p["attn"], y, h.dtype)
    else:
        h = h + attention_apply(p["attn"], hn, positions, cfg, causal=True, window=window)
    h = h + mlp_apply(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
    return h, kv


def embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    return logical(h, "act_batch", "act_res_seq", "act_embed")


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def encoder_forward(cfg, params, enc_embeds):
    B, S, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = enc_embeds.astype(_dtype(cfg))
    h, _, _ = _scan_blocks(cfg, params["enc_blocks"], h, positions, causal=False)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def forward(cfg, params, batch, *, window: int = 0, collect_kv: bool = False,
            collect_state: bool = False):
    """Full-sequence forward. batch keys: 'tokens' [B,S] or 'embeds' [B,S,D];
    'positions' [B,S] (or [3,B,S] for mrope); optional 'enc_embeds'.
    Returns (h_final [B,S,D], aux, (kv_or_state_stacks, enc_out))."""
    if "embeds" in batch:
        h = batch["embeds"].astype(_dtype(cfg))
        h = logical(h, "act_batch", "act_seq", "act_embed")
    else:
        h = embed_tokens(cfg, params, batch["tokens"])
    positions = batch["positions"]
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encoder_forward(cfg, params, batch["enc_embeds"])
    kv_head = None
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.ssm_kind and cfg.attn_every > 0:
        kvs_apps, st_segs = [], []
        for (s, e, has_attn) in _hybrid_segments(cfg):
            h, aux, st = _scan_blocks(cfg, _tree_slice(params["blocks"], s, e), h, positions,
                                      window=window, collect_state=collect_state)
            aux_total += aux
            if collect_state:
                st_segs.append(st)
            if has_attn:
                h, kv = _shared_attn_apply(cfg, params["shared_attn"], h, positions,
                                           window=window, collect_kv=collect_kv)
                if collect_kv:
                    kvs_apps.append(kv)
        kvs = None
        if collect_kv and kvs_apps:
            kvs = (jnp.stack([a for a, _ in kvs_apps]), jnp.stack([b for _, b in kvs_apps]))
        if collect_state:
            states = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *st_segs)
            kvs = (kvs, states)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return h, aux_total, (kvs, enc_out)
    if cfg.ssm_kind:
        h, aux, states = _scan_blocks(cfg, params["blocks"], h, positions,
                                      collect_state=collect_state)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return h, aux, (states, enc_out)
    if "head_blocks" in params:
        h, aux, kv_head = _scan_blocks(cfg, params["head_blocks"], h, positions,
                                       enc_out=enc_out, window=window, collect_kv=collect_kv)
        aux_total += aux
    h, aux, kvs = _scan_blocks(cfg, params["blocks"], h, positions, enc_out=enc_out,
                               window=window, collect_kv=collect_kv)
    aux_total += aux
    if collect_kv and kv_head is not None and kvs is not None:
        kvs = jax.tree_util.tree_map(lambda a, b: jnp.concatenate([a, b], 0), kv_head, kvs)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux_total, (kvs, enc_out)


def lm_head(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_softmax_xent(cfg, h, head, labels, chunk: int = 1024):
    """Cross-entropy without materializing [B,S,V]: scan over seq chunks."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    hr = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        hc, lc = inp
        logits = jnp.einsum("bcd,dv->bcv", hc, head.astype(hc.dtype),
                            preferred_element_type=jnp.float32)
        logits = logical(logits, "act_batch", "act_seq", "act_vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + (lse - ll).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hr, lr))
    return total / (B * S)


def loss_fn(cfg, params, batch, *, window: int = 0):
    h, aux, _ = forward(cfg, params, batch, window=window)
    loss = chunked_softmax_xent(cfg, h, lm_head(cfg, params), batch["labels"])
    return loss + cfg.router_aux_coef * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(cfg, params, batch, *, window: int = 0):
    """Process the prompt; return (last-token logits [B,V], cache dict)."""
    h, _, (kvs, enc_out) = forward(cfg, params, batch, window=window, collect_kv=True,
                                   collect_state=bool(cfg.ssm_kind))
    logits = jnp.einsum("bd,dv->bv", h[:, -1], lm_head(cfg, params).astype(h.dtype),
                        preferred_element_type=jnp.float32)
    cache: Dict[str, Any] = {}
    if cfg.ssm_kind == "rwkv6":
        tm_x, cm_x, s_f = kvs
        return logits, {"tm_x": tm_x, "cm_x": cm_x, "s": s_f}
    if cfg.ssm_kind == "mamba2":
        if cfg.attn_every > 0:
            kv_apps, states = kvs
            conv, s_f = states
            cache = {"conv": conv, "s": s_f}
            if kv_apps is not None:
                cache["ak"], cache["av"] = kv_apps
            return logits, cache
        conv, s_f = kvs
        return logits, {"conv": conv, "s": s_f}
    if kvs is not None:
        if cfg.use_mla:
            cache["c"], cache["kr"] = kvs
        else:
            cache["k"], cache["v"] = kvs
    if cfg.is_encoder_decoder and enc_out is not None:
        xks, xvs = [], []
        # cross-attn KV per decoder layer, precomputed once
        def collect(p_layer):
            _, k, v = attention_qkv(p_layer["xattn"], enc_out, cfg, positions=None, rope=False)
            return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

        kv = jax.lax.map(lambda p_l: collect(p_l), params["blocks"])
        cache["xk"], cache["xv"] = kv
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def _decode_self_attention(cfg, q, k_cache, v_cache, pos, opts: ServeOptions):
    n_rep = cfg.num_heads // cfg.num_kv_heads
    if opts.seq_sharded_cache and active_mesh() is not None:
        mesh = active_mesh()
        kf = repeat_kv(k_cache, n_rep)
        vf = repeat_kv(v_cache, n_rep)
        kf = logical(kf, "act_batch", "act_kv_seq", "act_heads", None)
        vf = logical(vf, "act_batch", "act_kv_seq", "act_heads", None)
        q_spec = spec_for(("act_batch", None, "act_heads", None))
        kv_spec = spec_for(("act_batch", "act_kv_seq", "act_heads", None))
        fn = shard_map(
            partial(flash_decode_sharded, axis="data"),
            mesh=mesh,
            in_specs=(q_spec, kv_spec, kv_spec, P()),
            out_specs=q_spec,
            check_vma=False,
        )
        return fn(q, kf, vf, pos + 1)
    kf = repeat_kv(k_cache, n_rep)
    vf = repeat_kv(v_cache, n_rep)
    return decode_attention(q, kf, vf, pos + 1, window=opts.window,
                            softcap=cfg.attn_logit_softcap)


def _attn_block_decode(cfg, p, h, k_l, v_l, pos, opts: ServeOptions, xk=None, xv=None):
    B = h.shape[0]
    hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = attention_qkv(p["attn"], hn, cfg, positions=positions)
    k_l = jax.lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype), pos, axis=1)
    v_l = jax.lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype), pos, axis=1)
    y = _decode_self_attention(cfg, q, k_l.astype(h.dtype), v_l.astype(h.dtype), pos, opts)
    h = h + attention_out(p["attn"], y, h.dtype)
    if xk is not None:
        hx = rmsnorm(p["ln_x"], h, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["xattn"]["wq"].astype(h.dtype))
        n_rep = cfg.num_heads // cfg.num_kv_heads
        yx = decode_attention(qx, repeat_kv(xk.astype(h.dtype), n_rep),
                              repeat_kv(xv.astype(h.dtype), n_rep), xk.shape[1])
        h = h + attention_out(p["xattn"], yx, h.dtype)
    return h, k_l, v_l


def _mla_block_decode(cfg, p, h, c_l, kr_l, pos):
    hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
    rules = active_rules() or {}
    if rules.get("act_kv_seq") and active_mesh() is not None:
        from repro.models.layers import mla_decode_seqsharded

        y, c_l, kr_l = mla_decode_seqsharded(p["attn"], hn, c_l, kr_l, pos, cfg)
        return h + y, c_l, kr_l
    y, c_l, kr_l = mla_decode(p["attn"], hn, c_l.astype(h.dtype), kr_l.astype(h.dtype), pos, cfg)
    return h + y, c_l.astype(jnp.bfloat16), kr_l.astype(jnp.bfloat16)


def _ffn_decode(cfg, p, h):
    hn = rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_apply(p["moe"], hn, cfg)
        return h + y
    return h + mlp_apply(p["mlp"], hn)


def serve_step(cfg, params, cache, tokens, pos, opts: ServeOptions = ServeOptions()):
    """One decode step. tokens [B,1] int32; pos scalar int32 (current length).
    Returns (logits [B,V], new cache)."""
    B = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)
    if cfg.ssm_kind == "rwkv6":
        def body(hh, xs):
            p_l, tm_x, cm_x, s = xs
            hn = rmsnorm(p_l["ln1"], hh[:, 0], cfg.norm_eps)
            y, (tm_x2, s2) = ssm_lib.rwkv6_time_mix_step(p_l["tmix"], hn, cfg, tm_x, s)
            hh = hh + y[:, None]
            hn2 = rmsnorm(p_l["ln2"], hh, cfg.norm_eps)
            y2, cm_x2 = ssm_lib.rwkv6_channel_mix(p_l["cmix"], hn2, prev_x=cm_x,
                                                  return_state=True)
            hh = hh + y2
            return hh, (tm_x2.astype(tm_x.dtype), cm_x2.astype(cm_x.dtype), s2)

        h, (tm, cm, s) = jax.lax.scan(
            body, h, (params["blocks"], cache["tm_x"], cache["cm_x"], cache["s"]))
        new_cache = {"tm_x": tm, "cm_x": cm, "s": s}
    elif cfg.ssm_kind == "mamba2":
        app_idx = 0
        new_conv, new_s = [], []
        ak, av = cache.get("ak"), cache.get("av")
        for (s_i, e_i, has_attn) in _hybrid_segments(cfg):
            def body(hh, xs):
                p_l, conv_l, s_l = xs
                hn = rmsnorm(p_l["ln1"], hh[:, 0], cfg.norm_eps)
                y, (conv2, s2) = ssm_lib.mamba2_step(p_l["mixer"], hn, cfg, conv_l, s_l)
                hh = hh + y[:, None]
                hh = hh + mlp_apply(p_l["mlp"], rmsnorm(p_l["ln2"], hh, cfg.norm_eps))
                return hh, (conv2.astype(conv_l.dtype), s2)

            h, (conv_seg, s_seg) = jax.lax.scan(
                body, h,
                (_tree_slice(params["blocks"], s_i, e_i),
                 cache["conv"][s_i:e_i], cache["s"][s_i:e_i]))
            new_conv.append(conv_seg)
            new_s.append(s_seg)
            if has_attn and ak is not None:
                p_sh = dict(params["shared_attn"])
                h, k_l, v_l = _attn_block_decode(cfg, p_sh, h, ak[app_idx], av[app_idx],
                                                 pos, ServeOptions())
                h = _ffn_decode(cfg, {"ln2": p_sh["ln2"], "mlp": p_sh["mlp"]}, h)
                ak = ak.at[app_idx].set(k_l)
                av = av.at[app_idx].set(v_l)
                app_idx += 1
        new_cache = {"conv": jnp.concatenate(new_conv, 0), "s": jnp.concatenate(new_s, 0)}
        if ak is not None:
            new_cache["ak"], new_cache["av"] = ak, av
    elif cfg.use_mla:
        blocks = [params["head_blocks"], params["blocks"]] if "head_blocks" in params else [params["blocks"]]
        offs = 0
        cs, krs = [], []
        for blk in blocks:
            n_l = jax.tree_util.tree_leaves(blk)[0].shape[0]

            def body(hh, xs):
                p_l, c_l, kr_l = xs
                hh, c2, kr2 = _mla_block_decode(cfg, p_l, hh, c_l, kr_l, pos)
                hh = _ffn_decode(cfg, p_l, hh)
                return hh, (c2, kr2)

            h, (c_new, kr_new) = jax.lax.scan(
                body, h, (blk, cache["c"][offs : offs + n_l], cache["kr"][offs : offs + n_l]))
            cs.append(c_new)
            krs.append(kr_new)
            offs += n_l
        new_cache = {"c": jnp.concatenate(cs, 0), "kr": jnp.concatenate(krs, 0)}
    else:
        blocks_list = [params["head_blocks"], params["blocks"]] if "head_blocks" in params else [params["blocks"]]
        offs = 0
        ks, vs = [], []
        has_cross = cfg.is_encoder_decoder
        for blk in blocks_list:
            n_l = jax.tree_util.tree_leaves(blk)[0].shape[0]
            xs = [blk, cache["k"][offs : offs + n_l], cache["v"][offs : offs + n_l]]
            if has_cross:
                xs += [cache["xk"][offs : offs + n_l], cache["xv"][offs : offs + n_l]]

            def body(hh, inp):
                if has_cross:
                    p_l, k_l, v_l, xk_l, xv_l = inp
                else:
                    p_l, k_l, v_l = inp
                    xk_l = xv_l = None
                hh, k2, v2 = _attn_block_decode(cfg, p_l, hh, k_l, v_l, pos, opts,
                                                xk=xk_l, xv=xv_l)
                hh = _ffn_decode(cfg, p_l, hh)
                return hh, (k2, v2)

            h, (k_new, v_new) = jax.lax.scan(body, h, tuple(xs))
            ks.append(k_new)
            vs.append(v_new)
            offs += n_l
        new_cache = {"k": jnp.concatenate(ks, 0), "v": jnp.concatenate(vs, 0)}
        if has_cross:
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], lm_head(cfg, params).astype(h.dtype),
                        preferred_element_type=jnp.float32)
    logits = logical(logits, "act_batch", "act_vocab")
    return logits, new_cache


def serve_step_vec(cfg, params, cache, tokens, pos_vec, opts: ServeOptions = ServeOptions()):
    """Per-slot-position decode for continuous batching (dense GQA families).
    tokens [B,1]; pos_vec [B] int32 — each batch lane writes its KV at its own
    position and attends to its own prefix length."""
    assert not cfg.ssm_kind and not cfg.use_mla and not cfg.is_encoder_decoder, (
        "serve_step_vec currently supports the dense GQA families")
    B = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)
    blocks_list = [params["head_blocks"], params["blocks"]] if "head_blocks" in params else [params["blocks"]]
    offs = 0
    ks, vs = [], []
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(pos_vec[None, :, None], (3, B, 1)).astype(jnp.int32)
    else:
        positions = pos_vec[:, None].astype(jnp.int32)
    for blk in blocks_list:
        n_l = jax.tree_util.tree_leaves(blk)[0].shape[0]

        def body(hh, inp):
            p_l, k_l, v_l = inp
            hn = rmsnorm(p_l["ln1"], hh, cfg.norm_eps)
            q, k, v = attention_qkv(p_l["attn"], hn, cfg, positions=positions)
            lane = jnp.arange(B)
            k_l = k_l.at[lane, pos_vec].set(k[:, 0].astype(k_l.dtype))
            v_l = v_l.at[lane, pos_vec].set(v[:, 0].astype(v_l.dtype))
            n_rep = cfg.num_heads // cfg.num_kv_heads
            y = decode_attention(q, repeat_kv(k_l.astype(hh.dtype), n_rep),
                                 repeat_kv(v_l.astype(hh.dtype), n_rep),
                                 pos_vec + 1, window=opts.window,
                                 softcap=cfg.attn_logit_softcap)
            hh = hh + attention_out(p_l["attn"], y, hh.dtype)
            hh = _ffn_decode(cfg, p_l, hh)
            return hh, (k_l, v_l)

        h, (k_new, v_new) = jax.lax.scan(
            body, h, (blk, cache["k"][offs : offs + n_l], cache["v"][offs : offs + n_l]))
        ks.append(k_new)
        vs.append(v_new)
        offs += n_l
    new_cache = {"k": jnp.concatenate(ks, 0), "v": jnp.concatenate(vs, 0)}
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], lm_head(cfg, params).astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return logits, new_cache
