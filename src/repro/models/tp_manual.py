"""Manual tensor-parallel blocks (beyond-paper §Perf optimization).

Hypothesis (EXPERIMENTS.md §Perf, iteration 3): GSPMD's auto-partitioning of
the Megatron pattern on this toolchain (a) keeps f32 pre-cast tensors on the
wire and (b) lowers the output partial-sum as all-reduce (2x bytes) plus an
extra gather under sequence sharding. Writing the block with EXPLICIT
collectives — bf16 all_gather of the seq-sharded residual in, bf16
psum_scatter of the partial output — moves exactly one [B,S,D] bf16 payload
each way per projection pair, the Megatron-SP minimum.

Enabled by the '_manual_tp' rules flag (dryrun --opt mtp); the residual
stream must be seq-sharded over 'model' (act_res_seq rule).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from jax.ad_checkpoint import checkpoint_name

from repro.launch.sharding import active_mesh, spec_for
from repro.models.layers import apply_rope, chunked_attention, repeat_kv


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _model_size(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def mlp_tp(p, x, cfg):
    """x [B, S, D] with S sharded over 'model' (residual layout).
    Explicit AG(seq) -> local SwiGLU on the F shard -> RS(seq)."""
    mesh = active_mesh()
    ba = _batch_axes(mesh)
    xs = P(ba if ba else None, "model", None)

    def local(wi, wg, wo, h_loc):
        xg = jax.lax.all_gather(h_loc, "model", axis=1, tiled=True)  # bf16 [B,S,D]
        xg = checkpoint_name(xg, "tp_gather")
        dt = h_loc.dtype
        a = jnp.einsum("bsd,df->bsf", xg, wi.astype(dt))
        b = jnp.einsum("bsd,df->bsf", xg, wg.astype(dt))
        h_mid = jax.nn.silu(b) * a
        out = jnp.einsum("bsf,fd->bsd", h_mid, wo.astype(dt))  # partial over F
        return jax.lax.psum_scatter(out, "model", scatter_dimension=1, tiled=True)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model"), P(None, "model"), P("model", None), xs),
        out_specs=xs, check_vma=False,
    )(p["wi"], p["wg"], p["wo"], x)


def attention_tp(p, x, positions, cfg, *, causal=True, window=0):
    """Manual-TP GQA attention on a seq-sharded residual.
    Heads shard over 'model' when divisible; KV weights replicate when the KV
    head count is below the model-axis size (each shard computes the full
    small KV projection — cheaper than any reshard)."""
    mesh = active_mesh()
    ba = _batch_axes(mesh)
    msize = _model_size(mesh)
    heads_shard = cfg.num_heads % msize == 0
    xs = P(ba if ba else None, "model", None)
    wq_spec = P(None, "model", None) if heads_shard else P(None, None, None)
    pos_spec = P(None, ba if ba else None, None) if cfg.rope_style == "mrope" else P(ba if ba else None, None)

    def local(wq, wk, wv, wo, bq, bk, bv, h_loc, pos):
        dt = h_loc.dtype
        xg = jax.lax.all_gather(h_loc, "model", axis=1, tiled=True)  # [B,S,D]
        xg = checkpoint_name(xg, "tp_gather")
        q = jnp.einsum("bsd,dhk->bshk", xg, wq.astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", xg, wk.astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", xg, wv.astype(dt))
        if bq is not None:
            q = q + bq.astype(dt)  # bias views match the local head slice
            k = k + bk.astype(dt)
            v = v + bv.astype(dt)
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_style)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_style)
        # align KV heads to the local q-head slice (KV weights replicated)
        H_l = q.shape[2]
        G = cfg.num_heads // cfg.num_kv_heads
        me_h = jax.lax.axis_index("model") if heads_shard else 0
        kv_sel = (me_h * H_l + jnp.arange(H_l)) // G
        k = jnp.take(k, kv_sel, axis=2)
        v = jnp.take(v, kv_sel, axis=2)
        y = chunked_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap)
        out = jnp.einsum("bshk,hkd->bsd", y, wo.astype(dt))  # partial over heads
        if not heads_shard:
            # fully replicated attention: no partial sum; just scatter rows
            me = jax.lax.axis_index("model")
            ns = out.shape[1] // msize
            return jax.lax.dynamic_slice_in_dim(out, me * ns, ns, axis=1)
        return jax.lax.psum_scatter(out, "model", scatter_dimension=1, tiled=True)

    # bias handling: slice per shard for q when heads shard
    bq = p.get("bq")
    if bq is not None and heads_shard:
        bq_spec = P("model", None)
    else:
        bq_spec = P(None, None) if bq is not None else P()
    args = (p["wq"], p["wk"], p["wv"], p["wo"],
            p.get("bq"), p.get("bk"), p.get("bv"), x, positions)
    in_specs = (wq_spec, P(None, None, None), P(None, None, None),
                (P("model", None, None) if heads_shard else P(None, None, None)),
                (bq_spec if bq is not None else None),
                (P(None, None) if bq is not None else None),
                (P(None, None) if bq is not None else None),
                xs, pos_spec)
    # shard_map cannot take None leaves: drop absent biases from the call
    if bq is None:
        def local_nb(wq, wk, wv, wo, h_loc, pos):
            return local(wq, wk, wv, wo, None, None, None, h_loc, pos)

        return shard_map(local_nb, mesh=mesh,
                         in_specs=(wq_spec, P(None, None, None), P(None, None, None),
                                   P("model", None, None) if heads_shard else P(None, None, None),
                                   xs, pos_spec),
                         out_specs=xs, check_vma=False)(
            p["wq"], p["wk"], p["wv"], p["wo"], x, positions)
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=xs,
                     check_vma=False)(*args)
