"""chatglm3-6b [dense] — RoPE 2d (half-rotary), GQA kv=2 [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",  # ChatGLM applies rotary to half the head dims (2d RoPE)
    qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        rope_style="half",
        qkv_bias=True,
    )
