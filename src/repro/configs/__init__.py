from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_shape,
    get_smoke_config,
    supports_shape,
)

#: the 10 assigned architectures (excludes the paper's own GCN workload id)
ASSIGNED_ARCHS = tuple(a for a in ARCH_IDS if a != "gcn-paper")

__all__ = [
    "ARCH_IDS",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "get_smoke_config",
    "supports_shape",
]
