"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B].

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    remat_policy="full",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=8,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        qkv_bias=True,
    )
