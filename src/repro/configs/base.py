"""Config system: architecture configs, input-shape configs, and the registry.

Every assigned architecture has one module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (the full production config, exact numbers from the
assignment table) and ``smoke_config()`` (a reduced same-family variant used
by CPU smoke tests: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation from the assignment table

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # --- attention flavour ---
    rope_theta: float = 1e4
    rope_style: str = "full"  # full | half (chatglm 2d-rope) | mrope (qwen2-vl)
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0  # >0 enables sliding-window attention variant

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64  # decoupled rope dims for MLA

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_chunk: int = 4096  # tokens per dispatch chunk (memory lever)
    moe_group_limit: int = 0  # >0: route each token to experts on <= this many
    #   model shards (DeepSeek-style group-limited routing) and DEDUPLICATE the
    #   dispatch (one copy per destination shard, not per expert) — §Perf lever
    router_aux_coef: float = 0.01

    # --- SSM (rwkv6 / mamba2) ---
    ssm_kind: str = ""  # "" | rwkv6 | mamba2
    ssm_state: int = 0  # state dim N (mamba2) / head key dim (rwkv6)
    ssm_heads: int = 0
    ssm_conv: int = 4  # mamba2 depthwise conv width
    ssm_chunk: int = 64  # chunked-scan chunk length
    attn_every: int = 0  # hybrid: shared attention block every N layers

    # --- encoder-decoder (seamless) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # --- modality frontend stub ---
    input_mode: str = "tokens"  # tokens | embeddings (audio frames / vision patches)

    # --- numerics / training ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat_policy: str = "minimal"  # none | minimal | full
    optimizer: str = "adamw"  # adamw | adafactor | sgdm

    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.family == "moe":
            assert self.num_experts > 0 and self.moe_top_k > 0
        if self.ssm_kind:
            assert self.ssm_kind in ("rwkv6", "mamba2")
            assert self.ssm_state > 0 and self.ssm_heads > 0
        if self.use_mla:
            assert self.kv_lora_rank > 0
        if self.num_heads and not self.ssm_kind:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # -- derived -----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D roofline)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        embed = V * D * (1 if self.tie_embeddings else 2)
        total = embed + D  # final norm
        enc_layers = self.encoder_layers if self.is_encoder_decoder else 0
        for layer in range(L + enc_layers):
            total += 2 * D  # norms
            is_enc = layer >= L
            # attention
            if self.ssm_kind and not self._layer_has_attn(layer if not is_enc else 0):
                pass
            elif not self.ssm_kind or self._layer_has_attn(layer):
                if self.use_mla:
                    total += D * (self.kv_lora_rank + self.rope_head_dim)
                    total += self.kv_lora_rank * self.num_heads * self.head_dim * 2
                    total += D * self.num_heads * (self.head_dim + self.rope_head_dim)
                    total += self.q_dim * D
                else:
                    total += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                    if self.qkv_bias:
                        total += self.q_dim + 2 * self.kv_dim
                if self.is_encoder_decoder and not is_enc:
                    total += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D  # cross attn
            # ffn / moe / ssm
            if self.ssm_kind and not is_enc:
                H, N = self.ssm_heads, self.ssm_state
                if self.ssm_kind == "rwkv6":
                    total += 5 * D * D + D * D  # r,k,v,g,o + decay lora approx
                else:  # mamba2
                    d_inner = 2 * D
                    total += D * (2 * d_inner + 2 * H * N + H) + d_inner * D + d_inner * self.ssm_conv
                total += D * F + F * D  # channel-mix / mlp
            elif self.num_experts and layer >= self.first_k_dense and not is_enc:
                total += D * self.num_experts  # router
                total += self.num_experts * 3 * D * F
                total += self.num_shared_experts * 3 * D * F
            else:
                total += 3 * D * F
        return total

    def num_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.num_params()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense_total = self.num_params()
        all_expert = (L - self.first_k_dense) * self.num_experts * 3 * D * F
        active_expert = (L - self.first_k_dense) * (self.moe_top_k) * 3 * D * F
        return dense_total - all_expert + active_expert

    def _layer_has_attn(self, layer: int) -> bool:
        if not self.ssm_kind:
            return True
        if self.attn_every <= 0:
            return False
        return layer % self.attn_every == self.attn_every - 1


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "qwen2-vl-72b",
    "kimi-k2-1t-a32b",
    "chatglm3-6b",
    "seamless-m4t-large-v2",
    "deepseek-v2-236b",
    "qwen1.5-32b",
    "llama3.2-1b",
    "rwkv6-3b",
    "llama3.2-3b",
    "zamba2-1.2b",
    # the paper's own workload: a GCN — handled by src/repro/core, but kept
    # addressable through the same --arch flag for the launcher.
    "gcn-paper",
)


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return mod.smoke_config()


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Decode-shape policy (documented in DESIGN.md)."""
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return False, "enc-dec: 500k-token decoder target out of family scope"
    return True, ""
