"""zamba2-1.2b [hybrid] — Mamba2 + shared attention blocks [arXiv:2411.15242].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Mamba2 (SSD) backbone; a single *shared* attention block (one set of params)
is applied every 6 layers (the Zamba2 shared-block design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_heads=64,  # d_inner = 2*d_model, head dim 64
    ssm_chunk=128,
    attn_every=6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        ssm_kind="mamba2",
        ssm_state=16,
        ssm_heads=8,
        ssm_chunk=16,
        attn_every=2,
    )
