"""gcn-paper — the survey's own workload: a multi-layer GCN on a large graph.

This id routes the launcher to the distributed-GNN engine (src/repro/core)
rather than the transformer stack. The config below is the full-graph
production workload used by the GNN dry-run and the SpMM benchmarks
(ogbn-papers100M-like scale, synthetic power-law graph).
"""
import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class GNNWorkloadConfig:
    name: str = "gcn-paper"
    num_vertices: int = 1_048_576  # 2**20: divisible by 256- and 512-chip meshes
    avg_degree: int = 16
    feature_dim: int = 256
    hidden_dim: int = 256
    num_classes: int = 64
    num_layers: int = 3
    model: str = "gcn"  # gcn | sage | gat | gin
    execution_model: str = "spmm_1d"  # see core.execution.spmm_models
    protocol: str = "broadcast"  # broadcast | p2p | pipeline | async
    partition: str = "ldg"  # hash | range | ldg | block | metis_like


CONFIG = GNNWorkloadConfig()


def smoke_config() -> GNNWorkloadConfig:
    return GNNWorkloadConfig(
        name="gcn-paper-smoke",
        num_vertices=256,
        avg_degree=8,
        feature_dim=32,
        hidden_dim=32,
        num_classes=8,
        num_layers=2,
    )


# keep a ModelConfig-shaped alias so generic tooling that only prints names
# does not special-case; the launcher dispatches on isinstance.
MODEL_CONFIG_PLACEHOLDER = ModelConfig(name="gcn-paper", family="dense", source="arXiv:2211.00216")
