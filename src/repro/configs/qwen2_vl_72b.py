"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. Backbone only: the
ViT vision encoder + projector are stubbed; ``input_specs`` supplies patch
embeddings of shape (B, S, d_model) plus (3, B, S) M-RoPE position triplets.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope_style="mrope",
    rope_theta=1e6,
    qkv_bias=True,  # Qwen2 family uses QKV bias
    input_mode="embeddings",
    remat_policy="full",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke",
        family="vlm",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        rope_style="mrope",
        qkv_bias=True,
        input_mode="embeddings",
    )
