"""kimi-k2-1t-a32b [moe] — trillion-param MoE [arXiv:2501.kimi2 (paper-table)].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840,
MoE 384 experts top-8, 1 shared expert, first layer dense.
Optimizer: adafactor (fp32 Adam for 1T params does not fit 256x16GB; see
EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    rope_theta=5e5,
    num_experts=384,
    num_shared_experts=1,
    moe_top_k=8,
    first_k_dense=1,
    capacity_factor=1.25,
    moe_dispatch_chunk=2048,
    optimizer="adafactor",
    param_dtype="bfloat16",
    remat_policy="full",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        num_shared_experts=1,
        moe_top_k=2,
        first_k_dense=1,
        moe_dispatch_chunk=64,
        optimizer="adafactor",
    )
