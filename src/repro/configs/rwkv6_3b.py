"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
RWKV6 head size 64 => 40 heads, per-channel data-dependent decay; trained and
prefilled with the chunked WKV scan (TPU-native chunk matmuls), decoded with
the O(1)-state recurrence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=8960,
    vocab_size=65536,
    ssm_kind="rwkv6",
    ssm_state=64,  # head key dim
    ssm_heads=40,
    ssm_chunk=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=256,
        vocab_size=512,
        ssm_kind="rwkv6",
        ssm_state=32,
        ssm_heads=4,
        ssm_chunk=16,
    )
