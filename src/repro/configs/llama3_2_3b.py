"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=192,
        num_heads=6,
        num_kv_heads=2,
        head_dim=32,
        d_ff=384,
        vocab_size=512,
        rope_theta=5e5,
        tie_embeddings=True,
    )
