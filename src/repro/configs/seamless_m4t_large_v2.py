"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

24L(+24 encoder) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Backbone only: mel-spectrogram + conv feature extractor are stubbed;
``input_specs`` supplies frame embeddings (B, S_enc, d_model).
Training shape splits seq_len into encoder/decoder halves; decode shapes cache
decoder self-attn KV plus precomputed cross-attn KV over the encoder output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=24,
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    input_mode="embeddings",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-smoke",
        family="audio",
        source=CONFIG.source,
        num_layers=2,
        encoder_layers=2,
        is_encoder_decoder=True,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        input_mode="embeddings",
    )
