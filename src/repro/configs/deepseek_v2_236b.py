"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H (kv=128) d_ff=1536(expert) vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    num_experts=160,
    num_shared_experts=2,
    moe_top_k=6,
    first_k_dense=1,
    capacity_factor=1.25,
    moe_dispatch_chunk=2048,
    optimizer="adafactor",
    param_dtype="bfloat16",
    remat_policy="full",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        use_mla=True,
        kv_lora_rank=32,
        rope_head_dim=16,
        num_experts=4,
        num_shared_experts=1,
        moe_top_k=2,
        first_k_dense=1,
        moe_dispatch_chunk=64,
        optimizer="adafactor",
    )
