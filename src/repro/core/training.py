"""End-to-end distributed GNN training (the survey's Fig. 2 pipeline):

  full_graph_train   — full-graph training with a selectable execution model
                       (one-shot / chunk) and protocol (sync broadcast/p2p or
                       async historical embeddings with any staleness model).
  minibatch_train    — sampling-based training with cache + execution model.
  llcg_train         — partition-based batches + periodic global correction.

All training math is jitted; protocol state (historical embeddings) is
carried functionally. These run on one device (smoke) or under a mesh with
the spmm execution models (multi-device tests / benchmarks).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.models.gnn import (
    accuracy,
    full_graph_forward,
    gnn_layer,
    init_gnn_params,
    minibatch_forward,
    softmax_xent,
)
from repro.core.partition.edge_cut import PARTITIONERS, Partition
from repro.core.protocols.async_hist import (
    STALENESS_MODELS,
    HistoricalState,
    PipeGCNState,
    pipegcn_mix,
)
from repro.core.sampling.cache import simulate_hit_ratio, static_degree_cache
from repro.core.sampling.partition_batch import expanded_partition_minibatch, partition_minibatch
from repro.core.sampling.samplers import MiniBatch, node_wise_sample


# ---------------------------------------------------------------------------
# shared bits
# ---------------------------------------------------------------------------


def _sgd(params, grads, lr):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def boundary_mask_for(g: Graph, part: Partition) -> np.ndarray:
    """Vertices read by at least one remote partition (their embeddings cross
    the wire during GA — the only rows that can ever be stale)."""
    V = g.num_vertices
    mask = np.zeros(V, bool)
    for v in range(V):
        pv = part.assignment[v]
        for u in g.neighbors(v):
            if part.assignment[u] != pv:
                mask[u] = True
    return mask


# ---------------------------------------------------------------------------
# Full-graph training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FullGraphResult:
    losses: List[float]
    train_acc: float
    test_acc: float
    bytes_pushed: float = 0.0  # async protocols: rows refreshed * D * 4


def full_graph_train(g: Graph, *, model: str = "gcn", hidden: int = 32,
                     epochs: int = 60, lr: float = 0.5,
                     protocol: str = "sync",
                     staleness: int = 2, eps_v: float = 0.05,
                     partition: Optional[Partition] = None,
                     num_parts: int = 4, seed: int = 0) -> FullGraphResult:
    """protocol: 'sync' | 'epoch_fixed' | 'epoch_adaptive' | 'variation'.

    Async protocols reproduce the survey §7.2 semantics: the GA stage of every
    layer reads historical embeddings for boundary vertices, refreshed per the
    staleness model (bounded staleness); sync reads fresh embeddings.
    """
    A = jnp.asarray(g.to_dense_adj())
    X = jnp.asarray(g.features)
    y = jnp.asarray(g.labels.astype(np.int32))
    train_m = jnp.asarray(g.train_mask.astype(np.float32))
    test_m = jnp.asarray(g.test_mask.astype(np.float32))
    num_classes = int(g.labels.max()) + 1
    dims = [g.features.shape[1], hidden, num_classes]
    params = init_gnn_params(model, dims, jax.random.PRNGKey(seed))

    if protocol == "sync":
        def loss_fn(p):
            logits = full_graph_forward(model, p, A, X)
            return softmax_xent(logits, y, train_m), logits

        @jax.jit
        def step(p, _):
            (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
            return _sgd(p, grads, lr), loss, logits

        losses = []
        logits = None
        for e in range(epochs):
            params, loss, logits = step(params, e)
            losses.append(float(loss))
        return FullGraphResult(losses, float(accuracy(logits, y, train_m)),
                               float(accuracy(logits, y, test_m)))

    if protocol == "pipegcn":
        return _pipegcn_train(g, model=model, hidden=hidden, epochs=epochs, lr=lr,
                              partition=partition, num_parts=num_parts, seed=seed)

    # --- async with historical embeddings ---
    part = partition or PARTITIONERS["metis_like"](g, num_parts, seed=seed)
    assignment = jnp.asarray(part.assignment.astype(np.int32))
    bmask = jnp.asarray(boundary_mask_for(g, part))
    refresh_fn = STALENESS_MODELS[protocol]
    kw = {"staleness": staleness} if protocol != "variation" else {"eps": eps_v}
    L = len(dims) - 1
    states = [HistoricalState.create(g.num_vertices, d, part.num_parts)
              for d in dims[1:]]

    def forward_with_hist(p, states, step_i):
        H = X
        new_states = []
        for l, pl in enumerate(p["layers"]):
            H = gnn_layer(model, pl, A, H, last=(l == L - 1))
            H_used, st2 = refresh_fn(states[l], H, step_i, assignment, bmask, **kw)
            new_states.append(st2)
            H = H_used
        return H, new_states

    def loss_fn(p, states, step_i):
        logits, new_states = forward_with_hist(p, states, step_i)
        return softmax_xent(logits, y, train_m), (logits, new_states)

    @jax.jit
    def step(p, states, step_i):
        (loss, (logits, new_states)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, states, step_i)
        return _sgd(p, grads, lr), new_states, loss, logits

    losses = []
    logits = None
    for e in range(epochs):
        params, states, loss, logits = step(params, states, jnp.asarray(e))
        losses.append(float(loss))
    return FullGraphResult(losses, float(accuracy(logits, y, train_m)),
                           float(accuracy(logits, y, test_m)),
                           bytes_pushed=float(states[-1].bytes_pushed))


# ---------------------------------------------------------------------------
# Mini-batch training
# ---------------------------------------------------------------------------


def _pad_pow2(n: int, lo: int = 8) -> int:
    m = lo
    while m < n:
        m *= 2
    return m


def _device_batch(mb: MiniBatch) -> Tuple:
    """Pad frontiers to pow2 buckets so jit retraces stay bounded."""
    adjs, self_idx, sizes = [], [], []
    lv = mb.layer_vertices
    for l, A in enumerate(mb.layer_adj):
        rows = lv[l + 1]
        cols = lv[l]
        nr, nc = _pad_pow2(len(rows)), _pad_pow2(len(cols))
        Ap = np.zeros((nr, nc), np.float32)
        Ap[: A.shape[0], : A.shape[1]] = A
        adjs.append(jnp.asarray(Ap))
        si = np.searchsorted(cols, rows)
        si = np.clip(si, 0, len(cols) - 1)
        sip = np.zeros(nr, np.int64)
        sip[: len(si)] = si
        self_idx.append(jnp.asarray(sip))
        sizes.append((A.shape[0], A.shape[1]))
    n_in = _pad_pow2(mb.input_features.shape[0])
    X = np.zeros((n_in, mb.input_features.shape[1]), np.float32)
    X[: mb.input_features.shape[0]] = mb.input_features
    nt = _pad_pow2(len(mb.targets))
    yb = np.zeros(nt, np.int32)
    yb[: len(mb.targets)] = mb.labels
    wb = np.zeros(nt, np.float32)
    wb[: len(mb.targets)] = 1.0
    return tuple(adjs), tuple(self_idx), jnp.asarray(X), jnp.asarray(yb), jnp.asarray(wb)


@dataclasses.dataclass
class MiniBatchResult:
    losses: List[float]
    test_acc: float
    cache_hit_ratio: float


def minibatch_train(g: Graph, *, model: str = "sage", hidden: int = 32,
                    fanouts=(5, 5), batch_size: int = 32, epochs: int = 3,
                    lr: float = 0.1, cache_capacity: int = 0,
                    seed: int = 0) -> MiniBatchResult:
    rng = np.random.default_rng(seed)
    num_classes = int(g.labels.max()) + 1
    dims = [g.features.shape[1]] + [hidden] * (len(fanouts) - 1) + [num_classes]
    params = init_gnn_params(model, dims, jax.random.PRNGKey(seed))
    train = np.where(g.train_mask)[0]
    cached = set(static_degree_cache(g, cache_capacity).tolist()) if cache_capacity else set()
    hits = total = 0

    @functools.partial(jax.jit, static_argnums=())
    def step(p, adjs, self_idx, X, yb, wb):
        def lf(p):
            logits = minibatch_forward(model, p, list(adjs), list(self_idx), X)
            return softmax_xent(logits, yb, wb)

        loss, grads = jax.value_and_grad(lf)(p)
        return _sgd(p, grads, lr), loss

    losses = []
    for _ in range(epochs):
        perm = rng.permutation(train)
        for i in range(0, len(perm) - batch_size + 1, batch_size):
            mb = node_wise_sample(g, perm[i : i + batch_size], fanouts, rng)
            for v in mb.layer_vertices[0]:
                hits += int(v) in cached
                total += 1
            adjs, self_idx, X, yb, wb = _device_batch(mb)
            params, loss = step(params, adjs, self_idx, X, yb, wb)
            losses.append(float(loss))
    # full-graph eval
    A = jnp.asarray(g.to_dense_adj())
    logits = full_graph_forward(model, params, A, jnp.asarray(g.features))
    acc = float(accuracy(logits, jnp.asarray(g.labels.astype(np.int32)),
                         jnp.asarray(g.test_mask.astype(np.float32))))
    return MiniBatchResult(losses, acc, hits / max(total, 1))


# ---------------------------------------------------------------------------
# LLCG (partition-based batches + global correction)
# ---------------------------------------------------------------------------


def llcg_train(g: Graph, *, model: str = "gcn", hidden: int = 32,
               num_parts: int = 4, rounds: int = 10, local_steps: int = 5,
               server_correct: bool = True, expand_hops: int = 0,
               lr: float = 0.5, seed: int = 0) -> FullGraphResult:
    """Learn-Locally-Correct-Globally: workers train on their partition batch
    (optionally expanded); the server periodically takes one full-graph step.
    server_correct=False reproduces plain PSGD-PA (the accuracy-loss baseline
    of §5.2)."""
    part = PARTITIONERS["metis_like"](g, num_parts, seed=seed)
    num_classes = int(g.labels.max()) + 1
    dims = [g.features.shape[1], hidden, num_classes]
    params = init_gnn_params(model, dims, jax.random.PRNGKey(seed))
    make_mb = (functools.partial(expanded_partition_minibatch, hops=expand_hops)
               if expand_hops else partition_minibatch)
    local_batches = []
    for w in range(num_parts):
        mb = make_mb(g, part, w)
        owned_local = np.searchsorted(mb.layer_vertices[0], mb.targets)
        local_batches.append((jnp.asarray(mb.layer_adj[0]),
                              jnp.asarray(mb.input_features),
                              jnp.asarray(mb.labels.astype(np.int32)),
                              jnp.asarray(owned_local)))
    A = jnp.asarray(g.to_dense_adj())
    X = jnp.asarray(g.features)
    y = jnp.asarray(g.labels.astype(np.int32))
    train_m = jnp.asarray(g.train_mask.astype(np.float32))
    test_m = jnp.asarray(g.test_mask.astype(np.float32))

    @jax.jit
    def local_step(p, A_l, X_l, y_l, owned):
        def lf(p):
            logits = full_graph_forward(model, p, A_l, X_l)
            return softmax_xent(logits[owned], y_l)

        loss, grads = jax.value_and_grad(lf)(p)
        return grads, loss

    @jax.jit
    def global_step(p):
        def lf(p):
            logits = full_graph_forward(model, p, A, X)
            return softmax_xent(logits, y, train_m), logits

        (loss, logits), grads = jax.value_and_grad(lf, has_aux=True)(p)
        return _sgd(p, grads, lr), loss, logits

    losses = []
    logits = None
    for r in range(rounds):
        for _ in range(local_steps):
            grad_acc = None
            loss_sum = 0.0
            for (A_l, X_l, y_l, owned) in local_batches:
                grads, loss = local_step(params, A_l, X_l, y_l, owned)
                loss_sum += float(loss)
                grad_acc = grads if grad_acc is None else jax.tree_util.tree_map(
                    jnp.add, grad_acc, grads)
            grad_acc = jax.tree_util.tree_map(lambda x: x / num_parts, grad_acc)
            params = _sgd(params, grad_acc, lr)
            losses.append(loss_sum / num_parts)
        if server_correct:
            params, loss, logits = global_step(params)
            losses.append(float(loss))
    if logits is None:
        logits = full_graph_forward(model, params, A, X)
    return FullGraphResult(losses, float(accuracy(logits, y, train_m)),
                           float(accuracy(logits, y, test_m)))


def _pipegcn_train(g: Graph, *, model: str, hidden: int, epochs: int, lr: float,
                   partition: Optional[Partition], num_parts: int, seed: int
                   ) -> FullGraphResult:
    """PipeGCN (survey Table 3): staleness-1 boundary embeddings in GA AND
    staleness-1 boundary gradients in grad-GA, via the pipegcn_mix custom-vjp
    primitive. Communication accounting: every epoch pushes boundary rows of
    embeddings + gradients once (the overlapped pipeline payload)."""
    A = jnp.asarray(g.to_dense_adj())
    X = jnp.asarray(g.features)
    y = jnp.asarray(g.labels.astype(np.int32))
    train_m = jnp.asarray(g.train_mask.astype(np.float32))
    test_m = jnp.asarray(g.test_mask.astype(np.float32))
    num_classes = int(g.labels.max()) + 1
    dims = [g.features.shape[1], hidden, num_classes]
    L = len(dims) - 1
    params = init_gnn_params(model, dims, jax.random.PRNGKey(seed))
    part = partition or PARTITIONERS["metis_like"](g, num_parts, seed=seed)
    bmask_f = jnp.asarray(boundary_mask_for(g, part).astype(np.float32))
    V = g.num_vertices
    hist_h = [jnp.zeros((V, d), jnp.float32) for d in dims[1:]]
    hist_g = [jnp.zeros((V, d), jnp.float32) for d in dims[1:]]

    def loss_fn_mask(p, hist_h, hist_g, mask_f):
        H = X
        outs = []
        for l, pl in enumerate(p["layers"]):
            H = gnn_layer(model, pl, A, H, last=(l == L - 1))
            if l < L - 1:  # only embeddings consumed by the NEXT aggregation
                H = pipegcn_mix(H, hist_h[l], hist_g[l], mask_f)
            outs.append(H)
        return softmax_xent(H, y, train_m), outs

    def loss_fn(p, hist_h, hist_g):
        return loss_fn_mask(p, hist_h, hist_g, bmask_f)

    @jax.jit
    def step(p, hist_h, hist_g):
        (loss, outs), (grads_p, fresh_g) = jax.value_and_grad(
            loss_fn, argnums=(0, 2), has_aux=True)(p, hist_h, hist_g)
        p2 = _sgd(p, grads_p, lr)
        new_hist_h = [jax.lax.stop_gradient(o) for o in outs]
        return p2, new_hist_h, list(fresh_g), loss, outs[-1]

    losses = []
    logits = None
    zero_mask = jnp.zeros_like(bmask_f)
    for e in range(epochs):
        if e == 0:
            # PipeGCN warm-up epoch: run sync (no staleness) to initialize the
            # historical embeddings/gradients, as in the original system.
            (loss, outs), (grads_p, fresh_g) = jax.value_and_grad(
                lambda p, hh, hg: loss_fn_mask(p, hh, hg, zero_mask),
                argnums=(0, 2), has_aux=True)(params, hist_h, hist_g)
            params = _sgd(params, grads_p, lr)
            hist_h = [jax.lax.stop_gradient(o) for o in outs]
            hist_g = list(fresh_g)
            losses.append(float(loss))
            logits = outs[-1]
            continue
        params, hist_h, hist_g, loss, logits = step(params, hist_h, hist_g)
        losses.append(float(loss))
    rows = float(bmask_f.sum())
    bytes_pushed = epochs * rows * sum(dims[1:]) * 4.0 * 2  # h and g per epoch
    return FullGraphResult(losses, float(accuracy(logits, y, train_m)),
                           float(accuracy(logits, y, test_m)),
                           bytes_pushed=bytes_pushed)
