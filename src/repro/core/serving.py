"""Low-latency GNN query serving (ROADMAP item 2, latency tier).

`GNNQueryEngine` is the persistent engine that answers "embed these K target
vertices now" on top of a trained `DistGNNEngine`, riding the padded
node-wise sampler path:

  - STATIC shapes, ONE compile: every serve round is padded to the engine's
    mini-batch frontier caps (fixed fanouts), so the jitted shard_map serve
    step compiles exactly once per fanout config — the same contract as
    `launch/serve.py`'s LLM serve step (recompile-count guarded in tests);
  - REQUEST COALESCING: `submit()` queues requests, `flush()` dedupes the
    pending target set, splits it by owner (targets are sampled on the
    device that owns them, the invariant the p2p halo caps are measured
    under), and packs it into the fewest padded rounds the per-owner cap
    (cfg.batch_size) allows;
  - the RESIDENT FEATURE CACHE (the FeatureStore hot-row overlay) is the
    serving hot set: remote frontier rows it holds never touch the wire, so
    a fully cache-resident query costs zero exchange bytes (asserted by the
    serving test tier; bytes ride the engine's CommStats accounting).

The throughput tier — embeddings for EVERY vertex in O(L) layer-wise
sweeps — is `DistGNNEngine.infer_full_graph`; this module is the K-target
point-query complement ("Scalable GNN Training: The Case for Sampling"
— sampled serving is dominated by feature fetches, which the cache and
owner-local sampling keep off the wire).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.models.gnn import padded_minibatch_forward
from repro.core.sampling.samplers import node_wise_sample


@dataclasses.dataclass
class ServingStats:
    """Host-side serving counters; wire bytes live in engine.comm_stats."""
    queries: int = 0  # requests answered
    rounds: int = 0  # serve-step executions
    targets: int = 0  # deduped target vertices embedded
    latencies_s: List[float] = dataclasses.field(default_factory=list)

    def percentile_ms(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), q) * 1e3)

    def qps(self) -> float:
        wall = sum(self.latencies_s)
        return self.queries / wall if wall > 0 else 0.0


class GNNQueryEngine:
    """Persistent K-target embedding server over a DistGNNEngine.

    The engine must be built with ``batching='node_wise'`` (the fixed-fanout
    padded sampler path whose caps make the serve step static) and frozen
    features — for ``trainable_features`` models, publish the trained table
    first (``engine.publish_embeddings(state)``) and serve from a
    non-trainable engine on the same store/partition.
    """

    def __init__(self, engine, params):
        c = engine.cfg
        if c.batching != "node_wise":
            raise ValueError(
                "GNNQueryEngine rides the node-wise padded sampler path: "
                f"build the engine with batching='node_wise' "
                f"(got batching={c.batching!r})")
        if c.trainable_features:
            raise ValueError(
                "GNNQueryEngine serves FROZEN layer-0 rows: write the "
                "trained table back with engine.publish_embeddings(state) "
                "and build a non-trainable engine on the same graph/"
                "partition for serving")
        self.engine = engine
        self.params = params
        self.stats = ServingStats()
        # ride the engine's telemetry: serve spans land in the same trace
        # as training, on their own lane when called from another thread
        self.telemetry = engine.telemetry
        self._pending: List[tuple] = []  # (rid, target ids)
        self._next_rid = 0
        self._qctr = 0  # monotone round counter keying the sampling streams
        self._serve = None
        self._jit_serve = None
        self._ref_round = None

    # -- the one-compile serve step -------------------------------------
    def make_serve_step(self):
        """The jitted serve round: (params, padded batch) -> [k, cap_L, C]
        final-layer rows for each device's padded targets.  The mini-batch
        train step minus loss/grads: resident-cache gather + execution
        exchange for the frontier (`_fetch_frontier`), then the padded
        dense-block forward."""
        if self._serve is not None:
            return self._serve
        eng = self.engine
        c = eng.cfg
        ax, L = eng.axis, c.num_layers
        consts = dict(X=eng.X, cache=eng._cache_table)
        cshard = dict(X=P(ax, None), cache=P(ax, None, None))
        bspec = dict(adj=tuple(P(ax, None, None) for _ in range(L)),
                     self_idx=tuple(P(ax, None) for _ in range(L)),
                     cache_ids=P(ax, None))
        if c.execution == "broadcast":
            bspec["bc_ids"] = P(ax, None)
        elif c.execution == "ring":
            bspec["ring_ids"] = P(ax, None, None)
        else:
            bspec["send_rows"] = P(ax, None, None, None)
            bspec["tab_ids"] = P(ax, None)

        def local_serve(params, consts_local, batch_local):
            bl = {key: (tuple(a[0] for a in v) if isinstance(v, tuple)
                        else v[0]) for key, v in batch_local.items()}
            F = eng._fetch_frontier(consts_local["X"],
                                    consts_local["cache"][0], bl)
            H = padded_minibatch_forward(params, list(bl["adj"]), F,
                                         model=c.model,
                                         self_idx=list(bl["self_idx"]))
            return H[None]

        smapped = shard_map(local_serve, mesh=eng.mesh,
                            in_specs=(P(), cshard, bspec),
                            out_specs=P(ax, None, None),
                            check_vma=False)

        @jax.jit
        def serve(params, consts_, batch):
            return smapped(params, consts_, batch)

        keys = tuple(bspec)
        self._jit_serve = serve
        self._serve = lambda params, batch: serve(
            params, consts, {key: batch[key] for key in keys})
        return self._serve

    def num_compiles(self) -> int:
        """Recompile-count guard: 1 after any number of served rounds."""
        return self._jit_serve._cache_size() if self._jit_serve else 0

    # -- round construction ----------------------------------------------
    def build_round(self, round_targets: Sequence[np.ndarray]) -> Dict:
        """One padded serve round from per-device OWNED target lists (each
        <= cfg.batch_size): deterministic node-wise sampling keyed by a
        monotone round counter, then the engine's extract stage (static
        caps, cache short-circuit, exchange plan, CommStats bytes)."""
        eng, c = self.engine, self.engine.cfg
        qi = self._qctr
        self._qctr += 1
        mbs = []
        for d, tg in enumerate(round_targets):
            tg = np.asarray(tg, np.int64)
            if len(tg) > c.batch_size:
                raise ValueError(f"device {d} round has {len(tg)} targets > "
                                 f"batch_size {c.batch_size}")
            if len(tg) and np.any(eng.part.assignment[tg] != d):
                raise ValueError(f"device {d} given targets it does not own")
            rng = np.random.default_rng([c.seed, 70657, qi, d])
            mbs.append(node_wise_sample(eng.g, tg, c.fanouts, rng))
        with self.telemetry.span("serve_build", round=qi):
            return eng._make_batch(mbs, step=qi)

    def serve_round(self, batch: Dict):
        """Run one pre-built round through the jitted serve step."""
        with self.telemetry.span("serve_compute"):
            out = self.make_serve_step()(self.params, batch)
        self.stats.rounds += 1
        self.telemetry.counter("serve.rounds").add(1)
        return out

    def reference_round(self, batch: Dict):
        """Single-device oracle for the SAME padded round: features gathered
        straight from the global table (pad frontier id Vp -> zero row),
        forward vmapped over the k device blocks — the serving analog of
        `make_reference_minibatch_step`."""
        eng, c = self.engine, self.engine.cfg
        if self._ref_round is None:
            table0 = jnp.concatenate(
                [eng.X, jnp.zeros((1, eng.X.shape[1]), eng.X.dtype)], 0)

            @jax.jit
            def ref(params, frontier, adj, self_idx):
                F = jnp.take(table0, frontier, axis=0)  # [k, cap0, D]

                def one(f, a, si):
                    return padded_minibatch_forward(
                        params, list(a), f, model=c.model, self_idx=list(si))

                return jax.vmap(one)(F, adj, self_idx)

            self._ref_round = ref
        return self._ref_round(self.params, batch["frontier"],
                               batch["adj"], batch["self_idx"])

    # -- request coalescing ----------------------------------------------
    def submit(self, targets) -> int:
        """Queue one "embed these targets" request; answered at `flush`."""
        targets = np.asarray(targets, np.int64).ravel()
        if targets.size == 0:
            raise ValueError("empty query")
        V = self.engine.g.num_vertices
        if targets.min() < 0 or targets.max() >= V:
            raise ValueError("target ids out of range")
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append((rid, targets))
        return rid

    def flush(self) -> Dict[int, np.ndarray]:
        """Answer every pending request in one coalesced pass: dedupe the
        union of pending targets, split by owner, pack into ceil(max owned
        share / batch_size) padded rounds, serve, scatter rows back per
        request (shared targets are embedded once)."""
        if not self._pending:
            return {}
        tel = self.telemetry
        t0 = time.perf_counter()
        eng = self.engine
        cap = eng.cfg.batch_size
        requested = sum(len(tg) for _, tg in self._pending)
        with tel.span("serve_flush", requests=len(self._pending)) as flush_sp:
            seen = {}
            per_dev: List[List[int]] = [[] for _ in range(eng.k)]
            for _, tg in self._pending:
                for v in tg.tolist():
                    if v not in seen:
                        seen[v] = True
                        per_dev[int(eng.part.assignment[v])].append(v)
            num_rounds = max(1, max(-(-len(x) // cap) for x in per_dev))
            # per-flush coalescing facts, on the span AND as counters
            flush_sp.set(targets_requested=requested,
                         targets_unique=len(seen), rounds=num_rounds)
            emb: Dict[int, np.ndarray] = {}
            for r in range(num_rounds):
                round_tgts = [np.asarray(x[r * cap:(r + 1) * cap], np.int64)
                              for x in per_dev]
                H = np.asarray(self.serve_round(self.build_round(round_tgts)))
                for d, tg in enumerate(round_tgts):
                    for j, v in enumerate(tg.tolist()):
                        emb[v] = H[d, j]
            out = {rid: np.stack([emb[int(v)] for v in tg])
                   for rid, tg in self._pending}
        self.stats.queries += len(self._pending)
        self.stats.targets += len(emb)
        dt = time.perf_counter() - t0
        self.stats.latencies_s.append(dt)
        tel.counter("serve.queries").add(len(self._pending))
        tel.counter("serve.targets_requested").add(requested)
        tel.counter("serve.targets_unique").add(len(emb))
        tel.histogram("serve.flush_latency_s").record(dt)
        self._pending = []
        return out

    def query(self, targets) -> np.ndarray:
        """Embed these targets now (one-request submit + flush)."""
        rid = self.submit(targets)
        return self.flush()[rid]
