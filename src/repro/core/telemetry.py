"""Run-wide telemetry: span tracing, labeled metrics, imbalance profiling.

The survey names three core challenges — massive feature communication,
accuracy loss, and workload imbalance — and the repo could measure only the
first (CommStats bytes) and second (oracle tiers).  This module is the
characterization layer for the third: *which device, which stage, how
skewed, where did the step's wall time go*.

Three pieces, all stdlib-only (no jax / numpy — telemetry must be importable
and overhead-bounded everywhere, including inside the prefetch thread):

``Tracer``
    ``with tel.span("extract", step=i, device=d):`` context managers with
    monotonic ``perf_counter`` timestamps and thread-id tagging, so the
    prefetch / trainer / serving lanes interleave as distinct rows.  Spans
    record their nesting depth (per-thread stack) and never touch jitted
    code paths: they wrap host-side stage boundaries only, and a device
    fence runs only where a span explicitly opts in via ``sync=callable``
    (e.g. ``lambda: jax.block_until_ready(state)``).

``MetricRegistry``
    Labeled counters / gauges / fixed-bucket latency histograms.  Histograms
    keep the raw samples next to the bucket counts, so ``percentile(q)`` is
    EXACT — bit-identical to ``numpy.percentile`` (same virtual-index +
    symmetric-lerp arithmetic), asserted by the test tier.

Exporters
    ``chrome_trace()`` — Chrome trace-event JSON (``ph/ts/dur/pid/tid``),
    loadable in Perfetto / ``chrome://tracing``, one row per device (pid) and
    lane/thread (tid); ``write_step_log()`` — JSONL step records; and
    ``run_summary()`` — a self-describing dict (metric totals, per-stage
    span seconds, the workload-imbalance report, and any static
    per-executable collective-bytes / peak-memory facts attached via
    ``attach_executable`` from ``launch.hlo_analysis.executable_summary``).

Telemetry is off-by-default-free: a disabled ``Telemetry`` hands out
singleton no-op spans and metrics (identity-stable, so the disabled path
allocates nothing per call); the overhead bound is asserted in
``tests/test_telemetry.py``.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Telemetry",
    "Tracer",
    "MetricRegistry",
    "Span",
    "DEFAULT_LATENCY_BUCKETS",
    "exact_percentile",
]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class _NullSpan:
    """Disabled-mode span: a no-op context manager, one shared instance."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **labels):
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One recorded interval: name + labels + [t0, t0+dur) on thread `tid`.

    ``labels`` carries the structured facts (step, device, bytes, ...) that
    ride into the Chrome trace ``args`` and the imbalance report."""

    __slots__ = ("name", "labels", "t0", "dur", "tid", "depth", "seq",
                 "_tracer", "_sync")

    def __init__(self, tracer: "Tracer", name: str,
                 sync: Optional[Callable], labels: Dict):
        self._tracer = tracer
        self._sync = sync
        self.name = name
        self.labels = labels
        self.t0 = 0.0
        self.dur = 0.0
        self.tid = 0
        self.depth = 0
        self.seq = -1

    def set(self, **labels) -> "Span":
        """Attach/override labels while the span is live (e.g. counts known
        only at the end of the stage)."""
        self.labels.update(labels)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.depth = len(stack)
        stack.append(self)
        self.tid = threading.get_ident()
        self.t0 = tr.clock()  # last: exclude our own setup from the interval
        return self

    def __exit__(self, *exc):
        if self._sync is not None:
            self._sync()  # opt-in device fence INSIDE the interval
        tr = self._tracer
        self.dur = tr.clock() - self.t0
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        with tr._lock:
            self.seq = len(tr._spans)
            tr._spans.append(self)
        return False


class Tracer:
    """Span recorder with a process-wide monotonic origin."""

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = bool(enabled)
        self.clock = clock
        self.origin = clock()
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._local = threading.local()

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, sync: Optional[Callable] = None, **labels):
        """Context manager for one interval; no-op singleton when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, sync, labels)

    def instant(self, name: str, **labels) -> None:
        """Zero-duration marker (e.g. the byte accounting of an exchange
        that itself runs inside the jitted step)."""
        if not self.enabled:
            return
        sp = Span(self, name, None, labels)
        sp.tid = threading.get_ident()
        sp.t0 = self.clock()
        with self._lock:
            sp.seq = len(self._spans)
            self._spans.append(sp)

    def record_span(self, name: str, t0: float, dur: float,
                    tid=None, **labels) -> None:
        """Record an ALREADY-MEASURED interval — the replay path for spans
        timed in another process (the process-pool sampling workers ship
        (name, t0, dur, labels) tuples back with each batch).  ``t0`` must be
        on this tracer's clock; the default `time.perf_counter` is
        CLOCK_MONOTONIC on Linux, shared across processes on one host, so
        worker intervals land on the same timeline as local spans.  ``tid``
        is the trace lane key — any hashable; worker processes pass e.g.
        ``("proc", rank)`` so each gets its own Chrome-trace row."""
        if not self.enabled:
            return
        sp = Span(self, name, None, dict(labels))
        sp.tid = threading.get_ident() if tid is None else tid
        sp.t0 = float(t0)
        sp.dur = float(dur)
        with self._lock:
            sp.seq = len(self._spans)
            self._spans.append(sp)

    def spans(self) -> List[Span]:
        """All finished spans, ordered by start time (stable on record seq)."""
        with self._lock:
            out = list(self._spans)
        return sorted(out, key=lambda s: (s.t0, s.seq))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class _NullMetric:
    """Disabled-mode counter/gauge/histogram: every mutator is a no-op."""

    __slots__ = ()
    value = 0.0

    def add(self, n=1):
        return self

    def set(self, v):
        return self

    def record(self, v):
        return self

    def percentile(self, q):
        return 0.0


NULL_METRIC = _NullMetric()


def exact_percentile(samples: Sequence[float], q: float) -> float:
    """``numpy.percentile(samples, q)`` (linear interpolation) replicated in
    stdlib arithmetic — same virtual index ``(q/100)*(n-1)`` and the same
    symmetric lerp (switches to the ``b - (b-a)*(1-t)`` form at t >= 0.5),
    so results are bit-identical to numpy's."""
    xs = sorted(float(x) for x in samples)
    n = len(xs)
    if n == 0:
        return 0.0
    if n == 1:
        return xs[0]
    pos = (q / 100.0) * (n - 1)
    lo = int(math.floor(pos))
    hi = int(math.ceil(pos))
    a, b = xs[lo], xs[hi]
    t = pos - lo
    r = a + (b - a) * t
    if t >= 0.5:
        r = b - (b - a) * (1.0 - t)
    return r


# Upper bucket bounds (seconds) for latency histograms: ~1/3 decade steps
# from 0.1 ms to 10 s; the last bucket is the +inf overflow.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0)


class Counter:
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Dict, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self.value = 0
        self._lock = lock

    def add(self, n=1) -> "Counter":
        with self._lock:
            self.value += n
        return self


class Gauge:
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Dict, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self._lock = lock

    def set(self, v) -> "Gauge":
        with self._lock:
            self.value = v
        return self


class Histogram:
    """Fixed-bucket histogram that also retains the raw samples, so bucket
    counts are exportable AND percentiles are exact (not interpolated from
    bucket edges)."""

    __slots__ = ("name", "labels", "buckets", "counts", "samples", "total",
                 "_lock")

    def __init__(self, name: str, labels: Dict, lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.labels = labels
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # last = overflow
        self.samples: List[float] = []
        self.total = 0.0
        self._lock = lock

    def record(self, v) -> "Histogram":
        v = float(v)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        with self._lock:
            self.counts[i] += 1
            self.samples.append(v)
            self.total += v
        return self

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        with self._lock:
            xs = list(self.samples)
        return exact_percentile(xs, q)


class MetricRegistry:
    """Labeled metric store: ``registry.counter("comm.pull_bytes",
    device=3).add(n)`` — one object per (kind, name, label set), created on
    first use.  Disabled registries hand out the shared no-op metric."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple, object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict, **kw):
        if not self.enabled:
            return NULL_METRIC
        key = (kind, name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, dict(labels), self._lock,
                                             **kw)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, buckets: Sequence[float] = None,
                  **labels) -> Histogram:
        kw = {} if buckets is None else {"buckets": buckets}
        return self._get("histogram", Histogram, name, labels, **kw)

    # -- aggregation ------------------------------------------------------
    def _iter(self, kind: str):
        with self._lock:
            items = list(self._metrics.items())
        for (k, name, labkey), m in items:
            if k == kind:
                yield name, dict(labkey), m

    def counter_total(self, name: str):
        """Sum of a counter over every label set (e.g. across devices)."""
        return sum(m.value for n, _, m in self._iter("counter") if n == name)

    def per_device(self, name: str) -> Dict[int, float]:
        """device-label -> value for a counter or gauge family."""
        out: Dict[int, float] = {}
        for kind in ("counter", "gauge"):
            for n, labels, m in self._iter(kind):
                if n == name and "device" in labels:
                    d = int(labels["device"])
                    out[d] = out.get(d, 0) + m.value
        return out

    def as_dict(self) -> Dict:
        """Export every metric; label sets keyed as "k=v,k=v" strings."""

        def lkey(labels):
            return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))

        counters: Dict[str, Dict] = {}
        gauges: Dict[str, Dict] = {}
        hists: Dict[str, Dict] = {}
        for name, labels, m in self._iter("counter"):
            counters.setdefault(name, {})[lkey(labels)] = m.value
        for name, labels, m in self._iter("gauge"):
            gauges.setdefault(name, {})[lkey(labels)] = m.value
        for name, labels, m in self._iter("histogram"):
            hists.setdefault(name, {})[lkey(labels)] = dict(
                count=m.count, sum=m.total,
                p50=m.percentile(50.0), p99=m.percentile(99.0),
                buckets=list(m.buckets), counts=list(m.counts))
        return dict(counters=counters, gauges=gauges, histograms=hists)


# ---------------------------------------------------------------------------
# the facade + exporters
# ---------------------------------------------------------------------------

def _imbalance(per_device: Dict[int, float]) -> Dict:
    vals = list(per_device.values())
    mean = sum(vals) / len(vals)
    mx = max(vals)
    return dict(per_device={str(d): per_device[d] for d in sorted(per_device)},
                max=mx, mean=mean,
                max_over_mean=(mx / mean) if mean > 0 else 0.0)


def _jsonable(v):
    return v if isinstance(v, (int, float, str, bool, type(None))) else str(v)


class Telemetry:
    """One run's tracer + metric registry + step log, with the exporters.

    ``Telemetry(enabled=False)`` (the engine's default) is free: spans and
    metrics are shared no-op singletons, and every exporter returns empty
    structures."""

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = bool(enabled)
        self.trace = Tracer(self.enabled, clock)
        self.metrics = MetricRegistry(self.enabled)
        self._lock = threading.Lock()
        self._steps: List[Dict] = []
        self._executables: Dict[str, Dict] = {}

    # -- recording (delegates) -------------------------------------------
    def span(self, name: str, sync: Optional[Callable] = None, **labels):
        return self.trace.span(name, sync=sync, **labels)

    def instant(self, name: str, **labels) -> None:
        self.trace.instant(name, **labels)

    def record_span(self, name: str, t0: float, dur: float,
                    tid=None, **labels) -> None:
        self.trace.record_span(name, t0, dur, tid=tid, **labels)

    def counter(self, name: str, **labels):
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels):
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, buckets: Sequence[float] = None, **labels):
        return self.metrics.histogram(name, buckets=buckets, **labels)

    def log_step(self, **fields) -> None:
        """Append one JSONL step record (written by `write_step_log`)."""
        if not self.enabled:
            return
        with self._lock:
            self._steps.append({k: _jsonable(v) for k, v in fields.items()})

    def attach_executable(self, name: str, summary: Dict) -> None:
        """Record static per-executable facts (collective bytes, peak memory
        — see ``launch.hlo_analysis.executable_summary``) into the run
        summary."""
        if not self.enabled:
            return
        with self._lock:
            self._executables[name] = dict(summary)

    # -- analysis ---------------------------------------------------------
    def imbalance_report(self) -> Dict:
        """Workload imbalance per stage: anything recorded with a ``device``
        label — span seconds, byte counters, occupancy/layout gauges —
        grouped per device and reduced to max / mean / max-over-mean."""
        span_groups: Dict[str, Dict[int, float]] = {}
        for s in self.trace.spans():
            d = s.labels.get("device")
            if d is None:
                continue
            g = span_groups.setdefault(s.name, {})
            g[int(d)] = g.get(int(d), 0.0) + s.dur
        spans = {name: _imbalance(g) for name, g in span_groups.items()
                 if sum(g.values()) > 0}
        metric_groups: Dict[str, Dict[int, float]] = {}
        for kind in ("counter", "gauge"):
            for name, labels, m in self.metrics._iter(kind):
                if "device" in labels:
                    g = metric_groups.setdefault(name, {})
                    d = int(labels["device"])
                    g[d] = g.get(d, 0) + m.value
        metrics = {name: _imbalance(g) for name, g in metric_groups.items()}
        return dict(spans=spans, metrics=metrics)

    def span_seconds(self) -> Dict[str, float]:
        """Total recorded seconds per span name (the per-stage wall
        breakdown; nested spans double-count by design)."""
        out: Dict[str, float] = {}
        for s in self.trace.spans():
            out[s.name] = out.get(s.name, 0.0) + s.dur
        return out

    def run_summary(self) -> Dict:
        """The self-describing run artifact: metric totals, per-stage span
        seconds, the imbalance report, static executable facts, step log."""
        spans = self.trace.spans()
        counts: Dict[str, int] = {}
        for s in spans:
            counts[s.name] = counts.get(s.name, 0) + 1
        with self._lock:
            steps = list(self._steps)
            execs = {k: dict(v) for k, v in self._executables.items()}
        return dict(
            enabled=self.enabled,
            spans=dict(count=len(spans), count_by_name=counts,
                       seconds_by_name=self.span_seconds()),
            metrics=self.metrics.as_dict(),
            imbalance=self.imbalance_report(),
            executables=execs,
            steps=steps,
        )

    # -- exporters --------------------------------------------------------
    def chrome_trace(self) -> Dict:
        """Chrome trace-event JSON: complete ("X") events with microsecond
        ts/dur relative to the tracer origin; pid = ``device`` label (0 when
        unlabeled), tid = lane (thread) index in order of first appearance —
        one row per device/lane in Perfetto / chrome://tracing."""
        origin = self.trace.origin
        tid_of: Dict[int, int] = {}
        events: List[Dict] = []
        for s in self.trace.spans():
            d = s.labels.get("device")
            pid = int(d) if d is not None else 0
            tid = tid_of.setdefault(s.tid, len(tid_of))
            events.append(dict(
                name=s.name, ph="X",
                ts=(s.t0 - origin) * 1e6, dur=s.dur * 1e6,
                pid=pid, tid=tid,
                args={k: _jsonable(v) for k, v in s.labels.items()}))
        meta: List[Dict] = []
        for pid in sorted({e["pid"] for e in events}):
            meta.append(dict(name="process_name", ph="M", pid=pid, tid=0,
                             args={"name": f"device {pid}"}))
        for ident, tid in sorted(tid_of.items(), key=lambda kv: kv[1]):
            meta.append(dict(name="thread_name", ph="M", pid=0, tid=tid,
                             args={"name": f"lane {tid}"}))
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def write_step_log(self, path: str) -> None:
        """JSONL: one line per `log_step` record."""
        with self._lock:
            steps = list(self._steps)
        with open(path, "w") as f:
            for rec in steps:
                f.write(json.dumps(rec) + "\n")


# A process-wide disabled instance: integration points that receive
# ``telemetry=None`` can fall back to this instead of branching everywhere.
NULL_TELEMETRY = Telemetry(enabled=False)
