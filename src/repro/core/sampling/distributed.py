"""Distributed sampling (survey §5.1): pull-based vs CSP push-based sampling
with communication accounting, and skewed linear weighted sampling.

These run the *protocol logic* on the host over a partitioned graph; the
device-side compute consumes the resulting MiniBatch. Communication bytes are
measured explicitly so benchmarks can reproduce the survey's claims (CSP
reduces bytes because |sampled| << |neighbor list|; skewed sampling trades
bias for locality).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.edge_cut import Partition

ID_BYTES = 8
FEAT_BYTES = 4


@dataclasses.dataclass
class CommStats:
    pull_bytes: int = 0  # neighbor lists / features moved to the requester
    push_bytes: int = 0  # sampling requests + results (CSP)
    cache_hit_bytes: int = 0  # feature bytes served by a local cache instead
    replica_sync_bytes: int = 0  # vertex-cut partial/aggregate rows exchanged
    halo_bytes: int = 0  # edge-cut/hybrid full-graph halo exchange: neighbor
    #   rows shipped to remote consumers each layer (the layout's
    #   wire_fields_per_step accounting)
    embed_grad_bytes: int = 0  # trainable embeddings: layer-0 gradient rows
    #   routed back to their owners (+ the live cache-overlay refresh)
    inference_bytes: int = 0  # layer-wise full-graph inference sweeps: one
    #   forward-only exchange per layer (cost_models.inference_bytes_per_sweep)

    def reset(self) -> "CommStats":
        """Zero every field IN PLACE.  Engines reset rather than re-assign a
        fresh instance, so a reference a caller holds (a bench accumulating
        per-epoch deltas, a telemetry mirror) keeps observing traffic instead
        of silently detaching."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)
        return self

    def total(self) -> int:
        """Bytes that actually cross the wire (cache hits excluded)."""
        return (self.pull_bytes + self.push_bytes + self.replica_sync_bytes
                + self.halo_bytes + self.embed_grad_bytes
                + self.inference_bytes)

    def requested(self) -> int:
        """Bytes the computation asked for, whether cached or fetched."""
        return self.total() + self.cache_hit_bytes


def pull_based_sample(g: Graph, part: Partition, worker: int, targets: np.ndarray,
                      fanout: int, rng: np.random.Generator
                      ) -> Tuple[List[np.ndarray], CommStats]:
    """Baseline: the local worker pulls FULL remote neighbor lists, then
    samples locally (what a naive DistDGL sampler does)."""
    stats = CommStats()
    out = []
    for v in targets:
        nb = g.neighbors(v)
        if part.assignment[v] != worker:
            stats.pull_bytes += len(nb) * ID_BYTES  # whole list crosses the wire
        sel = nb if len(nb) <= fanout else rng.choice(nb, fanout, replace=False)
        out.append(np.asarray(sel))
    return out, stats


def csp_sample(g: Graph, part: Partition, worker: int, targets: np.ndarray,
               fanout: int, rng: np.random.Generator
               ) -> Tuple[List[np.ndarray], CommStats]:
    """Collective Sampling Primitive (DSP): push the sampling task to the
    owner; only the sampled ids return."""
    stats = CommStats()
    out = []
    for v in targets:
        nb = g.neighbors(v)
        sel = nb if len(nb) <= fanout else rng.choice(nb, fanout, replace=False)
        if part.assignment[v] != worker:
            stats.push_bytes += ID_BYTES  # the request (vertex id)
            stats.push_bytes += len(sel) * ID_BYTES  # only results return
        out.append(np.asarray(sel))
    return out, stats


def skewed_weighted_sample(g: Graph, part: Partition, worker: int,
                           targets: np.ndarray, fanout: int, s: float,
                           rng: np.random.Generator
                           ) -> Tuple[List[np.ndarray], CommStats, float]:
    """Jiang & Rumi: scale LOCAL neighbors' sampling weight by s>1. Returns
    (samples, comm stats, locality = fraction of local picks)."""
    stats = CommStats()
    out = []
    local_picks = total_picks = 0
    for v in targets:
        nb = g.neighbors(v)
        if len(nb) == 0:
            out.append(nb)
            continue
        local = part.assignment[nb] == worker
        w = np.where(local, s, 1.0)
        p = w / w.sum()
        k = min(fanout, len(nb))
        sel = rng.choice(nb, size=k, replace=False, p=p)
        remote_sel = sel[part.assignment[sel] != worker]
        stats.pull_bytes += len(remote_sel) * ID_BYTES
        local_picks += int((part.assignment[sel] == worker).sum())
        total_picks += k
        out.append(sel)
    return out, stats, local_picks / max(total_picks, 1)


def feature_fetch_bytes(part: Partition, worker: int, vertices: np.ndarray,
                        feature_dim: int, cached_ids=frozenset(),
                        stats: CommStats = None) -> int:
    """Bytes to fetch input features for a batch.  Remote vertices present in
    `cached_ids` are cache hits: they cost nothing on the wire but are tracked
    in `stats.cache_hit_bytes` when a CommStats accumulator is passed (so an
    engine's reported bytes and this standalone cost model agree exactly).
    Returns the miss (wire) bytes; local vertices are free."""
    cached = (cached_ids if isinstance(cached_ids, (set, frozenset))
              else set(int(v) for v in np.asarray(cached_ids).ravel()))
    miss = hit = 0
    for v in np.asarray(vertices).ravel():
        if part.assignment[v] != worker:
            if int(v) in cached:
                hit += feature_dim * FEAT_BYTES
            else:
                miss += feature_dim * FEAT_BYTES
    if stats is not None:
        stats.pull_bytes += miss
        stats.cache_hit_bytes += hit
    return miss


def embedding_update_bytes(part: Partition, worker: int, vertices: np.ndarray,
                           feature_dim: int, cached_ids=frozenset(),
                           overlay_rows: int = 0,
                           stats: CommStats = None) -> int:
    """Wire bytes one device adds per mini-batch step when layer-0 rows are
    TRAINABLE embeddings (cfg.trainable_features): the cotangent of every
    remote frontier MISS returns to its owner (the transpose of the feature
    fetch — same row count, same width), and the hot-row cache overlay costs
    a fixed 2 * overlay_rows rows per step (the live refresh down from the
    owners plus the hit gradients back), since cached rows can no longer be
    served by a frozen snapshot.

    Like `feature_fetch_bytes` this counts requested rows (the p2p volume),
    independent of which collective ships them — the convention the engine's
    CommStats accounting uses, so engine and model agree exactly.  Returns
    the bytes; accumulates into ``stats.embed_grad_bytes`` when given."""
    cached = (cached_ids if isinstance(cached_ids, (set, frozenset))
              else set(int(v) for v in np.asarray(cached_ids).ravel()))
    rows = 0
    for v in np.asarray(vertices).ravel():
        if part.assignment[v] != worker and int(v) not in cached:
            rows += 1
    b = (rows + 2 * int(overlay_rows)) * feature_dim * FEAT_BYTES
    if stats is not None:
        stats.embed_grad_bytes += b
    return b
