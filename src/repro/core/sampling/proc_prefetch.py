"""GIL-free batch prefetch: a process pool producing into a shared-memory
ring (survey §6.1 pipelining without the thread sampler's GIL fight).

The thread `PrefetchWorker` overlaps host sampling with the device step, but
both lanes share one GIL: whenever XLA's dispatch spin-waits, the sampler
thread starves, so the pipelined win is conditional on a spare core
(`overlap_capacity_limited` in BENCH_step_pipeline.json).  `ProcPrefetchPool`
moves the producer into worker *processes* (DGL `multiprocessing/pytorch.py`
idiom): the GIL is per-process, so sampling overlaps the trainer
unconditionally and fans out across cores.

Data never rides a pickle:

* big read-only inputs (the graph's CSR arrays, the O(V) layout arrays) go
  into POSIX shared memory ONCE — `share_graph` publishes a `Graph` and
  workers attach read-only at init (`SharedGraph.materialize`);
* finished batches land in a ring of ``depth`` shared-memory slots sized
  from the producer's static `array_layout()`; only a tiny metadata dict
  crosses the mp.Queue per batch.

Ring protocol (deadlock-free by construction): batch index ``i`` always
writes slot ``i % depth``, and a worker may write only once
``i < released + depth`` (a shared counter + Condition).  The consumer
delivers strictly in input order, copies the arrays out, and releases the
slot immediately — so release order == index order, and with any
``num_workers`` and ``depth >= 1`` the writer of the next-released index is
never blocked by a later one.

Contracts (mirroring the thread `PrefetchWorker`):

* strict in-order delivery — with deterministic producers a pooled epoch is
  bitwise-identical to a blocking one;
* a producer exception is re-raised in the consumer at the position it
  occurred (relayed across the process boundary);
* `close()` always stops workers, joins them, and closes+unlinks every shm
  segment — including when the CONSUMER dies mid-epoch while workers are
  blocked on a full ring; a GC/interpreter-exit finalizer guarantees the
  unlink even if close() is never called.

Telemetry (when a `core.telemetry.Telemetry` is attached): per-worker span
lanes (producers record spans on the shared CLOCK_MONOTONIC timeline and the
parent replays them via `Tracer.record_span` with a ``("sampler-proc", rank)``
lane key), `proc_prefetch.producer_stall`/`consumer_stall` one-event-per-
contiguous-stall counters with `*_seconds` companions, ready-queue depth and
shm-slot occupancy gauges.
"""
from __future__ import annotations

import contextlib
import dataclasses
import multiprocessing as mp
import os
import pickle
import queue
import time
import traceback
import uuid
import weakref
from collections import OrderedDict
from multiprocessing import shared_memory
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph

_ALIGN = 64  # slot-internal array alignment (cache line)


# ---------------------------------------------------------------------------
# shared-memory plumbing
# ---------------------------------------------------------------------------


def _attach_shm(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment.  Python <= 3.12 re-registers attached
    segments with the resource tracker as if the attacher owned them — but
    every process in this pool (any start method) shares the PARENT's tracker
    process, whose per-type cache is a set: the child's register is a
    duplicate no-op, and the single unregister fired by the parent's
    `unlink()` leaves the set clean.  So: no child-side unregister (that
    would steal the parent's registration and make the later unlink
    KeyError inside the tracker), and no "leaked shared_memory" warnings
    as long as the owning arena really unlinks — which tests assert."""
    return shared_memory.SharedMemory(name=name)


def _shm_name(tag: str) -> str:
    return f"repro-{tag}-{os.getpid():x}-{uuid.uuid4().hex[:12]}"


@dataclasses.dataclass(frozen=True)
class SharedArrayRef:
    """Picklable handle to one numpy array living in a shm segment."""

    name: str
    shape: Tuple[int, ...]
    dtype: str


class _ShmArena:
    """Owner-side registry of created segments: close+unlink exactly once,
    from close() or the GC finalizer."""

    def __init__(self):
        self.segments: List[shared_memory.SharedMemory] = []

    def share(self, arr: np.ndarray, tag: str) -> SharedArrayRef:
        arr = np.ascontiguousarray(arr)
        shm = shared_memory.SharedMemory(
            create=True, size=max(int(arr.nbytes), 1), name=_shm_name(tag))
        self.segments.append(shm)
        view = np.ndarray(arr.shape, arr.dtype, buffer=shm.buf)
        view[...] = arr
        return SharedArrayRef(shm.name, tuple(arr.shape), str(arr.dtype))

    def create(self, nbytes: int, tag: str) -> shared_memory.SharedMemory:
        shm = shared_memory.SharedMemory(
            create=True, size=max(int(nbytes), 1), name=_shm_name(tag))
        self.segments.append(shm)
        return shm

    def close(self):
        segs, self.segments = self.segments, []
        for shm in segs:
            try:
                shm.close()
            except Exception:
                pass
            try:
                shm.unlink()
            except FileNotFoundError:
                pass
            except Exception:
                pass


class SharedGraph:
    """Picklable handle to a `Graph`'s host arrays in POSIX shared memory.

    Workers call `materialize()` once at init to attach read-only views and
    rebuild a `Graph` around them — the CSR arrays are mapped, not copied,
    so k workers cost one graph, not k.  Features are deliberately absent:
    the host stages never read them (byte accounting needs only the feature
    DIM, carried by `HostBatchBuilder.feature_dim`)."""

    def __init__(self, refs: Dict[str, Optional[SharedArrayRef]],
                 num_vertices: int):
        self._refs = refs
        self._num_vertices = int(num_vertices)

    def __getstate__(self):
        return {"refs": self._refs, "num_vertices": self._num_vertices}

    def __setstate__(self, state):
        self._refs = state["refs"]
        self._num_vertices = state["num_vertices"]

    def materialize(self) -> Graph:
        handles = []

        def attach(ref: Optional[SharedArrayRef]):
            if ref is None:
                return None
            shm = _attach_shm(ref.name)
            handles.append(shm)  # keep the mapping alive with the Graph
            a = np.ndarray(ref.shape, np.dtype(ref.dtype), buffer=shm.buf)
            a.flags.writeable = False
            return a

        g = Graph(indptr=attach(self._refs["indptr"]),
                  indices=attach(self._refs["indices"]),
                  num_vertices=self._num_vertices,
                  labels=attach(self._refs["labels"]),
                  train_mask=attach(self._refs["train_mask"]))
        g._shm_handles = handles  # noqa: SLF001 — lifetime anchor
        return g


def share_graph(g: Graph) -> Tuple[SharedGraph, _ShmArena]:
    """Publish the host-stage-relevant arrays of ``g`` into shared memory.
    Returns (picklable handle, owner arena) — the caller owns the arena and
    must `close()` it (the pool does, when built via its ``shared_inputs``)."""
    arena = _ShmArena()

    def share(arr, tag):
        return None if arr is None else arena.share(np.asarray(arr), tag)

    refs = dict(indptr=share(g.indptr, "csr"),
                indices=share(g.indices, "csr"),
                labels=share(g.labels, "lab"),
                train_mask=share(g.train_mask, "msk"))
    return SharedGraph(refs, g.num_vertices), arena


def _slot_layout(layout: Dict[str, Tuple[Tuple[int, ...], np.dtype]]
                 ) -> Tuple[int, Dict[str, Tuple[int, Tuple[int, ...],
                                                 np.dtype]]]:
    """(slot_nbytes, name -> (offset, shape, dtype)) for one ring slot."""
    off = 0
    table = {}
    for name in sorted(layout):
        shape, dtype = layout[name]
        dtype = np.dtype(dtype)
        table[name] = (off, tuple(int(s) for s in shape), dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        off += (nbytes + _ALIGN - 1) // _ALIGN * _ALIGN
    return max(off, 1), table


def _slot_views(buf, table) -> Dict[str, np.ndarray]:
    return {name: np.ndarray(shape, dtype, buffer=buf, offset=off)
            for name, (off, shape, dtype) in table.items()}


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------


class WorkerFailure(RuntimeError):
    """Raised in the consumer when a producer exception could not itself be
    pickled across the process boundary; carries the remote traceback."""


def _relayable(exc: BaseException, tb: str) -> BaseException:
    """The exception object itself when it pickles, else a WorkerFailure
    wrapping the remote traceback (the relay queue must never die trying)."""
    try:
        pickle.loads(pickle.dumps(exc))
        return exc
    except Exception:
        return WorkerFailure(
            f"unpicklable producer exception {type(exc).__name__}: {exc}\n"
            f"--- remote traceback ---\n{tb}")


def _produce_one(rank, produce, views, depth, idx, item, released, cond,
                 stop, ready_q) -> None:
    """Produce one batch into slot ``idx % depth`` and post its metadata."""
    pool_meta = dict(worker=rank, stall_events=0, stall_seconds=0.0)
    try:
        arrays, meta = produce(item)
    except BaseException as exc:  # noqa: BLE001 — relayed
        ready_q.put(("exc", idx, item,
                     _relayable(exc, traceback.format_exc())))
        return
    # ring backpressure: slot i % depth is ours once i < released + depth;
    # released advances in index order, so the wait is FIFO
    stalled_at = None
    with cond:
        while not stop.is_set() and idx - released.value >= depth:
            if stalled_at is None:
                stalled_at = time.perf_counter()
                pool_meta["stall_events"] = 1
            cond.wait(timeout=0.05)
    if stalled_at is not None:
        pool_meta["stall_seconds"] = time.perf_counter() - stalled_at
    if stop.is_set():
        return
    slot = views[idx % depth]
    for name, a in arrays.items():
        np.copyto(slot[name], a, casting="no")
    meta = dict(meta)
    meta["_pool"] = pool_meta
    ready_q.put(("ok", idx, item, meta))


def _worker_main(rank: int, produce: Callable, slot_names: Sequence[str],
                 table, task_q, ready_q, released, cond, stop) -> None:
    """One sampling worker: pull chunks of (idx, item) tasks, produce each,
    wait for slot ``idx % depth``'s turn, write arrays, post metadata.

    Tasks arrive as CHUNKS (lists of (idx, item) pairs) so an epoch costs
    O(chunks) queue round-trips, not O(batches)."""
    depth = len(slot_names)
    slots = [_attach_shm(n) for n in slot_names]
    views = [_slot_views(s.buf, table) for s in slots]
    try:
        while not stop.is_set():
            try:
                chunk = task_q.get(timeout=0.1)
            except queue.Empty:
                continue
            if chunk is None:
                break
            for idx, item in chunk:
                if stop.is_set():
                    break
                _produce_one(rank, produce, views, depth, idx, item,
                             released, cond, stop, ready_q)
    finally:
        for s in slots:
            s.close()


# ---------------------------------------------------------------------------
# the pool
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _suppress_main_fixup():
    """Stop forkserver/spawn children from re-running ``__main__``.

    `spawn.get_preparation_data` ships the parent's main-module spec/path so
    the child can recreate it — pointless here (workers run the importable
    `_worker_main` and the pickled `produce`; nothing resolves against
    ``__mp_main__``) and actively harmful: it crashes under stdin-driven
    parents (``__file__ == '<stdin>'``) and re-imports the whole test
    harness under pytest.  Hiding ``__spec__``/``__file__`` for the brief
    single-threaded Process.start() window makes preparation skip the main
    fixup entirely."""
    import __main__ as main_mod

    saved = {}
    for attr in ("__spec__", "__file__"):
        if hasattr(main_mod, attr):
            saved[attr] = getattr(main_mod, attr)
            setattr(main_mod, attr, None) if attr == "__spec__" else \
                delattr(main_mod, attr)
    try:
        yield
    finally:
        for attr, val in saved.items():
            setattr(main_mod, attr, val)


def _default_context() -> mp.context.BaseContext:
    """forkserver when the platform has it, else spawn.  Never fork: the
    parent that owns the pool also owns an XLA runtime, and forking a
    multithreaded process can deadlock the child on a lock some other
    thread held at fork time.  The forkserver process is itself
    spawn-started single-threaded, so the per-worker forks it serves are
    safe AND cheap (no jax re-import — workers inherit the server's
    numpy-only image; `produce` must pickle, which `HostBatchBuilder`
    guarantees by carrying a `SharedGraph` handle instead of the graph)."""
    try:
        return mp.get_context("forkserver")
    except ValueError:  # pragma: no cover — non-POSIX
        return mp.get_context("spawn")


def _shutdown(procs, stop, cond, task_q, ready_q, arena, extra_arenas):
    """The one shutdown path (close() and the GC finalizer): wake everyone,
    drain, join, terminate stragglers, then unlink every owned segment."""
    stop.set()
    try:
        with cond:
            cond.notify_all()
    except Exception:
        pass
    for _ in procs:
        try:
            task_q.put_nowait(None)
        except Exception:
            break
    deadline = time.perf_counter() + 5.0
    for p in procs:
        try:
            # keep the ready queue drained so a worker blocked on its feeder
            # thread (queue full) can exit
            while True:
                try:
                    ready_q.get_nowait()
                except queue.Empty:
                    break
            p.join(timeout=max(0.05, deadline - time.perf_counter()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        except Exception:
            pass
    for q_ in (task_q, ready_q):
        try:
            # never join the feeder: undrained tasks mean a full pipe with
            # no reader left, and join_thread() would wait on it forever
            q_.cancel_join_thread()
            q_.close()
        except Exception:
            pass
    arena.close()
    for a in extra_arenas:
        a.close()


class ProcPrefetchPool:
    """Persistent sampling-process pool over a shared-memory batch ring.

    ``produce(item) -> (arrays, meta)`` runs in the workers; ``layout`` is
    the static name -> (shape, dtype) contract sizing the ring slots (e.g.
    `HostBatchBuilder.array_layout()`).  The callable must pickle (default
    forkserver/spawn contexts — see `_default_context`).  ``shared_inputs``
    takes ownership of arenas whose segments (e.g. `share_graph`'s) must
    outlive the workers — they are unlinked on close().

    One epoch = ``run(items)``: an iterator of (item, arrays, meta) in input
    order.  The pool survives across runs (workers and shm are reused), so
    process startup is paid once, not per epoch.

    ``cache_items`` bounds an LRU of finished batches keyed by item.  The
    engine's sampling is DETERMINISTIC in (seed, step, device) — a batch is
    a pure function of its item — so serving a repeat item from the cache
    is bitwise-identical to reproducing it, and a repeat epoch skips both
    the sampling work and the IPC round-trip (the epoch-to-epoch sample
    reuse that arXiv:2105.02315 argues sampled training should exploit).
    Set 0 for producers that are NOT pure functions of their item."""

    def __init__(self, produce: Callable, layout, depth: int = 2,
                 num_workers: int = 2, telemetry=None,
                 mp_context: Optional[str] = None,
                 shared_inputs: Sequence[_ShmArena] = (),
                 cache_items: int = 64):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        if num_workers < 1:
            raise ValueError(
                f"num_sample_workers must be >= 1, got {num_workers}")
        if cache_items < 0:
            raise ValueError(
                f"cache_items must be >= 0, got {cache_items}")
        self._tel = (telemetry if telemetry is not None
                     and getattr(telemetry, "enabled", False) else None)
        ctx = (mp.get_context(mp_context) if mp_context
               else _default_context())
        self.depth = depth
        self.num_workers = num_workers
        self.cache_items = cache_items
        self._cache: "OrderedDict" = OrderedDict()
        nbytes, self._table = _slot_layout(layout)
        self._arena = _ShmArena()
        self._slots = [self._arena.create(nbytes, f"ring{i}")
                       for i in range(depth)]
        self._slot_views = [_slot_views(s.buf, self._table)
                            for s in self._slots]
        self._task_q = ctx.Queue()
        self._ready_q = ctx.Queue()
        self._stop = ctx.Event()
        self._released = ctx.Value("l", 0, lock=False)
        self._cond = ctx.Condition()
        self._next_idx = 0  # global monotone batch index across runs
        self._run_active = False
        self._procs = [
            ctx.Process(
                target=_worker_main,
                args=(r, produce, [s.name for s in self._slots], self._table,
                      self._task_q, self._ready_q, self._released, self._cond,
                      self._stop),
                name=f"proc-prefetch-{r}", daemon=True)
            for r in range(num_workers)]
        with _suppress_main_fixup():
            for p in self._procs:
                p.start()
        # guaranteed cleanup: shm segments are system-global, so unlinking
        # must not depend on close() being reached on every path
        self._finalizer = weakref.finalize(
            self, _shutdown, self._procs, self._stop, self._cond,
            self._task_q, self._ready_q, self._arena, tuple(shared_inputs))

    # -- epoch driver ------------------------------------------------------

    def run(self, items: Sequence) -> "_RunIterator":
        if not self.alive:
            raise RuntimeError("ProcPrefetchPool is closed")
        if self._run_active:
            raise RuntimeError("one run() at a time per pool")
        self._run_active = True
        return _RunIterator(self, list(items))

    def _release_through(self, idx: int) -> None:
        with self._cond:
            self._released.value = idx + 1
            self._cond.notify_all()

    # -- the finished-batch LRU (see class docstring) ----------------------

    def _cache_get(self, item) -> Optional[Tuple[Dict, Dict]]:
        if self.cache_items <= 0:
            return None
        try:
            hit = self._cache.get(item)
        except TypeError:  # unhashable items are simply never cached
            return None
        if hit is not None:
            self._cache.move_to_end(item)
        return hit

    def _cache_put(self, item, arrays: Dict, meta: Dict) -> None:
        if self.cache_items <= 0:
            return
        try:
            hash(item)
        except TypeError:
            return
        # private copies; lane seconds zeroed — a future hit does NO
        # sampling work, and its meta should say so
        m = {k: v for k, v in meta.items() if k not in ("spans", "_pool")}
        for k in ("sample_seconds", "extract_seconds"):
            if k in m:
                m[k] = 0.0
        m["cache_hit"] = True
        self._cache[item] = ({k: v.copy() for k, v in arrays.items()}, m)
        while len(self._cache) > self.cache_items:
            self._cache.popitem(last=False)

    # -- lifecycle ---------------------------------------------------------

    def close(self):
        """Idempotent: stop + join workers, close + UNLINK all shm."""
        self._finalizer()

    @property
    def alive(self) -> bool:
        return self._finalizer.alive

    @property
    def workers_alive(self) -> bool:
        return any(p.is_alive() for p in self._procs)


class _RunIterator:
    """In-order consumer for one epoch: reorder-buffers ready metadata,
    copies arrays out of the slot, releases it, yields (item, arrays, meta).

    The copy is deliberate: the engine hands the arrays to ``jnp.asarray``,
    which on CPU may ALIAS host numpy buffers — a view into a ring slot
    would be overwritten two batches later.  One memcpy per batch is orders
    of magnitude cheaper than the pickle round-trip it replaces."""

    def __init__(self, pool: ProcPrefetchPool, items: List):
        self._pool = pool
        self._items = items
        self._pos = 0
        self._pending: Dict[int, Tuple] = {}
        self._failed = False
        # per-epoch plan: a cache HIT pins its payload here (immune to LRU
        # eviction by this epoch's own misses) and gets no ring index;
        # misses take the next CONSECUTIVE indices (the released-counter
        # protocol needs a gap-free index sequence — slot = idx % depth)
        self._plan: List[Tuple[Optional[int], Optional[Tuple]]] = []
        tasks = []
        for item in items:
            hit = pool._cache_get(item)
            if hit is not None:
                self._plan.append((None, hit))
            else:
                idx = pool._next_idx
                pool._next_idx += 1
                self._plan.append((idx, None))
                tasks.append((idx, item))
        self._expected = tasks[0][0] if tasks else pool._next_idx
        self._end = pool._next_idx
        # chunked submission: ~2 chunks per worker costs O(workers) queue
        # round-trips per epoch instead of O(batches); the ring still paces
        # item-by-item, so depth and in-order delivery are unaffected
        step = max(1, -(-len(tasks) // max(1, 2 * pool.num_workers)))
        for lo in range(0, len(tasks), step):
            pool._task_q.put(tasks[lo:lo + step])

    def __iter__(self):
        return self

    def _poll(self, block: bool) -> bool:
        """Pull one ready message into the reorder buffer. False on timeout."""
        try:
            kind, idx, item, payload = self._pool._ready_q.get(
                timeout=0.1 if block else 0.0)
        except queue.Empty:
            return False
        self._pending[idx] = (kind, item, payload)
        return True

    def __next__(self):
        pool = self._pool
        if self._pos >= len(self._plan):
            self._finish()
            raise StopIteration
        tel = pool._tel
        item = self._items[self._pos]
        plan_idx, pinned = self._plan[self._pos]
        if plan_idx is None:  # cache hit: no ring round-trip
            self._pos += 1
            arrays, meta = pinned
            if tel is not None:
                tel.counter("proc_prefetch.cache_hit").add(1)
            if self._pos >= len(self._plan):
                self._finish()
            # consumers may mutate delivered arrays — hand out copies
            return item, {k: v.copy() for k, v in arrays.items()}, dict(meta)
        stalled_at = None
        dead_since = None
        while self._expected not in self._pending:
            got = self._poll(block=True)
            if got:
                continue
            if tel is not None and stalled_at is None:
                stalled_at = time.perf_counter()
                tel.counter("proc_prefetch.consumer_stall").add(1)
            if not pool.workers_alive or pool._stop.is_set():
                # grace window: final messages may still be in the queue's
                # feeder pipe after the last worker exited
                dead_since = dead_since or time.perf_counter()
                if time.perf_counter() - dead_since > 5.0:
                    self._failed = True
                    pool._run_active = False
                    raise RuntimeError(
                        "proc-prefetch workers exited without delivering "
                        f"batch {self._pos}")
        if tel is not None and stalled_at is not None:
            tel.counter("proc_prefetch.consumer_stall_seconds").add(
                time.perf_counter() - stalled_at)
        idx = self._expected
        kind, w_item, payload = self._pending.pop(idx)
        self._expected += 1
        self._pos += 1
        if kind == "exc":
            pool._release_through(idx)  # no slot write; keep order invariant
            self._failed = True
            pool._run_active = False
            raise payload
        # copy out, then free the slot for index idx + depth
        slot = pool._slot_views[idx % pool.depth]
        arrays = {name: slot[name].copy() for name in slot}
        pool._release_through(idx)
        meta = payload
        pool._cache_put(item, arrays, meta)
        if tel is not None:
            self._record(tel, meta)
        if self._pos >= len(self._plan):
            self._finish()
        return item, arrays, meta

    def _record(self, tel, meta: Dict) -> None:
        pm = meta.get("_pool", {})
        rank = pm.get("worker", 0)
        if pm.get("stall_events"):
            tel.counter("proc_prefetch.producer_stall",
                        worker=rank).add(pm["stall_events"])
            tel.counter("proc_prefetch.producer_stall_seconds",
                        worker=rank).add(pm["stall_seconds"])
        tel.gauge("proc_prefetch.ready_depth").set(len(self._pending))
        tel.gauge("proc_prefetch.shm_slots_occupied").set(
            min(self._pool.depth, len(self._pending)))
        for name, t0, dur, labels in meta.get("spans", ()):
            tel.record_span(name, t0, dur, tid=("sampler-proc", rank),
                            **labels)

    def _finish(self):
        self._pool._run_active = False

    def close(self):
        """Abort this run without killing the pool: drain every outstanding
        index (releasing slots in order) so the NEXT run starts clean.  If
        workers stopped responding, the pool is closed instead."""
        if self._expected >= self._end and not self._pending:
            self._pool._run_active = False
            return
        pool = self._pool
        deadline = time.perf_counter() + 10.0
        while self._expected < self._end:
            if self._expected in self._pending:
                kind, _, _ = self._pending.pop(self._expected)
                pool._release_through(self._expected)
                self._expected += 1
                continue
            if not self._poll(block=True):
                if not pool.workers_alive or \
                        time.perf_counter() > deadline:
                    pool.close()  # unresponsive: fail safe, unlink shm
                    return
        pool._run_active = False


# ---------------------------------------------------------------------------
# one-shot wrapper (the thread-PrefetchWorker-shaped surface)
# ---------------------------------------------------------------------------


class ProcPrefetchWorker:
    """One-epoch convenience mirroring the thread `PrefetchWorker` contract:
    iterate (item, arrays, meta) in order; `close()` tears the whole pool
    down (processes joined, shm unlinked).  For reuse across epochs hold a
    `ProcPrefetchPool` instead."""

    def __init__(self, items: Sequence, produce: Callable, layout,
                 depth: int = 2, num_workers: int = 2, telemetry=None,
                 mp_context: Optional[str] = None,
                 shared_inputs: Sequence[_ShmArena] = ()):
        self._pool = ProcPrefetchPool(
            produce, layout, depth=depth, num_workers=num_workers,
            telemetry=telemetry, mp_context=mp_context,
            shared_inputs=shared_inputs)
        self._it = self._pool.run(items)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            return next(self._it)
        except StopIteration:
            self._pool.close()
            raise

    def close(self):
        self._pool.close()

    @property
    def alive(self) -> bool:
        return self._pool.alive and self._pool.workers_alive
