"""Feature-cache policies (survey §5.1) and a hit-ratio simulator.

Policies:
  StaticDegreeCache   — PaGraph: cache highest out-degree vertices.
  ImportanceCache     — AliGraph: cache vertices with Imp^l(v) = D_in/D_out
                        above a threshold (capped at capacity).
  PreSamplingCache    — GNNLab: run K sampling epochs, cache hottest.
  AnalysisCache       — SALIENT++: propagate sampled-probability through the
                        graph analytically, cache highest-probability.
  FIFOCache           — BGL: dynamic FIFO with proximity-aware ordering.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Iterable, List, Sequence

import numpy as np

from repro.core.graph import Graph
from repro.core.sampling.samplers import node_wise_sample


def simulate_hit_ratio(cached_ids: np.ndarray, access_stream: Iterable[np.ndarray]) -> float:
    cached = set(np.asarray(cached_ids).tolist())
    hits = total = 0
    for batch in access_stream:
        for v in np.asarray(batch).ravel():
            hits += int(v) in cached
            total += 1
    return hits / max(total, 1)


def static_degree_cache(g: Graph, capacity: int) -> np.ndarray:
    """PaGraph: high OUT-degree vertices are accessed most by samplers."""
    return np.argsort(-g.out_degree())[:capacity]


def importance_cache(g: Graph, capacity: int, l: int = 1) -> np.ndarray:
    """AliGraph Imp^l(v) = D_in^l / D_out^l (1-hop approximation for l=1)."""
    d_in = g.degree().astype(np.float64)
    d_out = g.out_degree().astype(np.float64)
    imp = d_in / np.maximum(d_out, 1.0)
    # among high-importance, prefer frequently accessed (high out-degree):
    order = np.lexsort((-d_out, -imp))
    return order[:capacity]


def presampling_cache(g: Graph, capacity: int, *, fanouts=(5, 5), batch_size=32,
                      epochs: int = 3, seed: int = 0) -> np.ndarray:
    """GNNLab: K pre-sampling epochs measure empirical hotness."""
    rng = np.random.default_rng(seed)
    train = np.where(g.train_mask)[0] if g.train_mask is not None else np.arange(g.num_vertices)
    counts = np.zeros(g.num_vertices, np.int64)
    for _ in range(epochs):
        perm = rng.permutation(train)
        for i in range(0, len(perm), batch_size):
            mb = node_wise_sample(g, perm[i : i + batch_size], fanouts, rng)
            np.add.at(counts, mb.layer_vertices[0], 1)
    return np.argsort(-counts)[:capacity]


def analysis_propagation(g: Graph, *, fanouts=(5, 5)) -> tuple:
    """SALIENT++ propagation model: p_0 = uniform over train set; each hop
    ships p[v] * min(fanout/deg, 1) of v's mass SPLIT EVENLY across its
    in-neighbors (a sampler visits each neighbor with probability ~fanout/deg,
    and the per-vertex mass is a probability, so it divides — it doesn't
    replicate).  Duplicate neighbor entries (parallel edges) accumulate via
    np.add.at; fancy-index `+=` would silently keep only one of them.

    Returns ``(total, per_hop)`` — total [V] is the cache-ranking score,
    per_hop[h] the mass vector after hop h.  Because scale <= 1 and the split
    sums to one, each hop's mass is conserved: per_hop[h].sum() <= the
    previous hop's mass (the regression tier asserts this)."""
    V = g.num_vertices
    train = np.where(g.train_mask)[0] if g.train_mask is not None else np.arange(V)
    p = np.zeros(V)
    p[train] = 1.0 / max(len(train), 1)
    total = p.copy()
    deg = g.degree().astype(np.float64)
    per_hop: List[np.ndarray] = []
    for fanout in fanouts:
        nxt = np.zeros(V)
        scale = np.minimum(fanout / np.maximum(deg, 1.0), 1.0)
        for v in range(V):
            if p[v] > 0 and deg[v] > 0:
                nb = g.neighbors(v)
                np.add.at(nxt, nb, p[v] * scale[v] / len(nb))
        total += nxt
        per_hop.append(nxt)
        p = nxt
    return total, per_hop


def analysis_cache(g: Graph, capacity: int, *, fanouts=(5, 5)) -> np.ndarray:
    """SALIENT++: cache the highest analytically-propagated access probability."""
    total, _ = analysis_propagation(g, fanouts=fanouts)
    return np.argsort(-total)[:capacity]


# Static (build-once) policies usable as a device-resident feature cache:
# input features never change during training, so a static cache is exact —
# hits are free reads, never stale.
CACHE_POLICIES = {
    "static_degree": static_degree_cache,
    "importance": importance_cache,
    "presampling": presampling_cache,
    "analysis": analysis_cache,
}


def device_cache_ids(g: Graph, assignment: np.ndarray, worker: int,
                     policy: str, capacity: int, **policy_kw) -> np.ndarray:
    """Per-device resident feature cache: the policy's global hotness ranking
    filtered to vertices REMOTE to `worker` (local features are already
    resident), truncated to `capacity`."""
    if policy in ("none", None) or capacity <= 0:
        return np.zeros(0, np.int64)
    ranked = CACHE_POLICIES[policy](g, g.num_vertices, **policy_kw)
    remote = ranked[np.asarray(assignment)[ranked] != worker]
    return remote[:capacity].astype(np.int64)


@dataclasses.dataclass
class FIFOCache:
    """BGL dynamic FIFO cache; feed access batches in (proximity-aware) order."""
    capacity: int

    def __post_init__(self):
        self._set = OrderedDict()

    def access(self, v: int) -> bool:
        hit = v in self._set
        if not hit and self.capacity > 0:
            # capacity <= 0: nothing can be resident (the old popitem on an
            # empty OrderedDict raised KeyError); everything misses
            if len(self._set) >= self.capacity:
                self._set.popitem(last=False)
            self._set[v] = True
        return hit

    def run(self, stream: Iterable[np.ndarray]) -> float:
        hits = total = 0
        for batch in stream:
            for v in np.asarray(batch).ravel():
                hits += self.access(int(v))
                total += 1
        return hits / max(total, 1)


def proximity_ordering(g: Graph, train: np.ndarray, *, seed: int = 0,
                       shift: bool = True) -> np.ndarray:
    """BGL: BFS-ordered training sequence (+ random shift for convergence)."""
    rng = np.random.default_rng(seed)
    train_set = set(train.tolist())
    order: List[int] = []
    seen = set()
    q = deque()
    # Restart source: a pre-shuffled pass over the train vertices with a
    # monotone cursor.  Each restart advances past already-seen vertices, so
    # the total restart work is O(|train|) across the whole traversal — the
    # old `[t for t in train_set if t not in set(order)]` rebuilt the emitted
    # set every restart, turning many-component graphs quadratic.  (When the
    # queue drains, every seen train vertex has been popped into `order`, so
    # "unseen" == "not yet emitted".)
    restart = rng.permutation(np.asarray(train, np.int64))
    cursor = 0
    start = int(rng.choice(train))
    q.append(start)
    seen.add(start)
    while q:
        v = q.popleft()
        if v in train_set:
            order.append(v)
        for u in g.neighbors(v):
            if int(u) not in seen:
                seen.add(int(u))
                q.append(int(u))
        if not q:
            while cursor < len(restart) and int(restart[cursor]) in seen:
                cursor += 1
            if cursor < len(restart):
                nxt = int(restart[cursor])
                q.append(nxt)
                seen.add(nxt)
    arr = np.asarray(order, np.int64)
    if shift and len(arr):
        k = int(rng.integers(0, len(arr)))
        arr = np.roll(arr, k)
    return arr
