"""Partition-based mini-batch generation (survey §5.2): the local partition IS
the batch (PSGD-PA), subgraph expansion to restore boundary context, and LLCG
(Learn Locally, Correct Globally).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.edge_cut import Partition
from repro.core.partition.vertex_cut import edge_endpoints
from repro.core.sampling.samplers import MiniBatch


def partition_targets(g: Graph, part: Partition, worker: int, batch_size: int,
                      rng: np.random.Generator, train_only: bool = True
                      ) -> np.ndarray:
    """Draw up to `batch_size` mini-batch target (or walk-root) vertices from
    `worker`'s owned partition block — the same ownership rule as
    `partition_minibatch`, but subsampled so samplers can expand them into
    layered computation graphs.  Falls back to all owned vertices when the
    block has no train vertices; returns fewer than `batch_size` ids when the
    pool is smaller (callers pad to static shapes)."""
    owned = np.where(part.assignment == worker)[0]
    pool = owned
    if train_only and g.train_mask is not None:
        train = owned[g.train_mask[owned]]
        if len(train):
            pool = train
    if len(pool) <= batch_size:
        return np.sort(pool).astype(np.int64)
    return np.sort(rng.choice(pool, size=batch_size, replace=False)).astype(np.int64)


def p2p_frontier_halo_cap(g: Graph, part: Partition, hops: int,
                          cap0: int) -> int:
    """Tight static cap on the p2p mini-batch halo: the most rows any single
    source partition can ever ship to one destination's sampled frontier.

    Every sampler expands targets drawn from the destination's OWNED block by
    at most `hops` in-neighbor hops (node/layer-wise: num_layers; subgraph:
    walk_length), so the frontier rows remote-from-one-owner are bounded by
    that owner's share of the destination's `hops`-hop in-neighborhood — the
    measured edge-cut halo — never by the worst case `cap0` (every frontier
    row remote from one owner).  Always a TRUE upper bound: shrinking the
    all_to_all buffer by it can never overflow a sampled batch."""
    V = g.num_vertices
    e_src, e_dst = edge_endpoints(g)
    assign = part.assignment
    best = 1
    for d in range(part.num_parts):
        cur = assign == d
        reached = cur.copy()
        for _ in range(hops):
            nxt = np.zeros(V, bool)
            nxt[e_src[cur[e_dst]]] = True
            cur = nxt & ~reached
            reached |= nxt
            if not cur.any():
                break
        remote = reached & (assign != d)
        if remote.any():
            counts = np.bincount(assign[remote], minlength=part.num_parts)
            best = max(best, int(counts.max()))
    return max(1, min(int(cap0), best))


def partition_minibatch(g: Graph, part: Partition, worker: int,
                        num_layers: int = 2) -> MiniBatch:
    """PSGD-PA: ignore cross edges; train on the induced local subgraph."""
    verts = np.where(part.assignment == worker)[0]
    sub, _ = g.subgraph(verts)
    A = sub.to_dense_adj(normalized=True)
    return MiniBatch(
        targets=verts,
        layer_vertices=[verts] * (num_layers + 1),
        layer_adj=[A] * num_layers,
        input_features=g.features[verts] if g.features is not None else None,
        labels=g.labels[verts] if g.labels is not None else None,
    )


def expanded_partition_minibatch(g: Graph, part: Partition, worker: int,
                                 hops: int = 1, num_layers: int = 2) -> MiniBatch:
    """Subgraph expansion (Xue/Angerd): add `hops` rings of remote neighbors so
    boundary vertices keep their local structure; loss only on owned targets."""
    owned = np.where(part.assignment == worker)[0]
    verts = set(owned.tolist())
    frontier = set(owned.tolist())
    for _ in range(hops):
        nxt = set()
        for v in frontier:
            for u in g.neighbors(v):
                if int(u) not in verts:
                    nxt.add(int(u))
        verts |= nxt
        frontier = nxt
    all_verts = np.asarray(sorted(verts), np.int64)
    sub, remap = g.subgraph(all_verts)
    A = sub.to_dense_adj(normalized=True)
    return MiniBatch(
        targets=owned,  # loss restricted to owned vertices
        layer_vertices=[all_verts] * (num_layers + 1),
        layer_adj=[A] * num_layers,
        input_features=g.features[all_verts] if g.features is not None else None,
        labels=g.labels[owned] if g.labels is not None else None,
    )


@dataclasses.dataclass
class LLCGSchedule:
    """Learn Locally, Correct Globally (Ramezani et al.): each round, workers
    take `local_steps` on their partition; a server then applies one global
    full-graph correction step."""
    local_steps: int = 5
    rounds: int = 10

    def plan(self) -> List[Tuple[str, int]]:
        out = []
        for r in range(self.rounds):
            out.extend([("local", r)] * self.local_steps)
            out.append(("global_correct", r))
        return out
