from repro.core.sampling.cache import (
    CACHE_POLICIES,
    FIFOCache,
    analysis_cache,
    device_cache_ids,
    importance_cache,
    presampling_cache,
    proximity_ordering,
    simulate_hit_ratio,
    static_degree_cache,
)
from repro.core.sampling.distributed import (
    CommStats,
    csp_sample,
    feature_fetch_bytes,
    pull_based_sample,
    skewed_weighted_sample,
)
from repro.core.sampling.prefetch import PrefetchWorker
from repro.core.sampling.partition_batch import (
    LLCGSchedule,
    expanded_partition_minibatch,
    p2p_frontier_halo_cap,
    partition_minibatch,
    partition_targets,
)
from repro.core.sampling.samplers import (
    MiniBatch,
    frontier_caps,
    layer_wise_sample,
    node_wise_sample,
    pad_minibatch,
    subgraph_sample,
)
