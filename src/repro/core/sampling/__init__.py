from repro.core.sampling.cache import (
    FIFOCache,
    analysis_cache,
    importance_cache,
    presampling_cache,
    proximity_ordering,
    simulate_hit_ratio,
    static_degree_cache,
)
from repro.core.sampling.distributed import (
    CommStats,
    csp_sample,
    feature_fetch_bytes,
    pull_based_sample,
    skewed_weighted_sample,
)
from repro.core.sampling.partition_batch import (
    LLCGSchedule,
    expanded_partition_minibatch,
    partition_minibatch,
)
from repro.core.sampling.samplers import (
    MiniBatch,
    layer_wise_sample,
    node_wise_sample,
    subgraph_sample,
)
