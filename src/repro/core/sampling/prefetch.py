"""Background batch prefetch (survey §6.1 pipelining made real).

`PrefetchWorker` runs a producer callable (the engine's host sampling +
padded-batch extraction) on a dedicated thread, buffering at most ``depth``
finished batches in a bounded queue.  While the device executes step i the
worker is already building the batch for step i+1 — the double-buffered
sampler lane of GNNLab's factored schedule, except the overlap is measured
wall-clock, not modeled.

Contracts:

* results arrive strictly in input order (host sampling is deterministic in
  (seed, step, device), so the pipelined epoch is bitwise-identical to the
  blocking one);
* a producer exception is re-raised in the consumer at the position it
  occurred, after the thread has exited;
* ``close()`` always stops and joins the thread — including when the
  CONSUMER dies mid-epoch while the worker is blocked on a full queue.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Sequence

_DONE = object()


class _Raise:
    def __init__(self, exc: BaseException):
        self.exc = exc


class PrefetchWorker:
    """Iterate produced items: ``for out in PrefetchWorker(items, produce)``.

    The producer thread starts immediately and works ahead of the consumer,
    bounded by ``depth`` buffered results."""

    def __init__(self, items: Sequence, produce: Callable, depth: int = 2,
                 telemetry=None):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        # telemetry (core.telemetry.Telemetry, optional): queue-depth gauge
        # + stall counters, recorded from both lanes (thread-safe registry)
        self._tel = (telemetry if telemetry is not None
                     and getattr(telemetry, "enabled", False) else None)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._items = list(items)
        self._produce = produce
        self._done = False
        self._thread = threading.Thread(
            target=self._run, name="prefetch-sampler", daemon=True)
        self._thread.start()

    # -- producer side -----------------------------------------------------

    def _run(self):
        try:
            for item in self._items:
                if self._stop.is_set() or not self._offer(self._produce(item)):
                    return
            self._offer(_DONE)
        except BaseException as exc:  # noqa: BLE001 — relayed to the consumer
            self._offer(_Raise(exc))

    def _offer(self, out) -> bool:
        """Bounded put that stays responsive to close(): never blocks forever
        on a consumer that stopped consuming."""
        stalled_at = None
        while not self._stop.is_set():
            try:
                self._q.put(out, timeout=0.05)
                if self._tel is not None:
                    self._tel.gauge("prefetch.queue_depth").set(
                        self._q.qsize())
                    if stalled_at is not None:
                        self._tel.counter(
                            "prefetch.producer_stall_seconds").add(
                                time.perf_counter() - stalled_at)
                return True
            except queue.Full:
                # producer ahead of the trainer by the full depth: the
                # backpressure stall the imbalance report wants to see.
                # ONE event per contiguous stall (not per 0.05s poll — a
                # count proportional to polling cadence measures the poll
                # loop, not the pipeline); duration rides the companion
                # *_seconds counter
                if self._tel is not None and stalled_at is None:
                    stalled_at = time.perf_counter()
                    self._tel.counter("prefetch.producer_stall").add(1)
                continue
        return False

    # -- consumer side -----------------------------------------------------

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        stalled_at = None
        while True:
            try:
                out = self._q.get(timeout=0.1)
                if self._tel is not None:
                    self._tel.gauge("prefetch.queue_depth").set(
                        self._q.qsize())
                    if stalled_at is not None:
                        self._tel.counter(
                            "prefetch.consumer_stall_seconds").add(
                                time.perf_counter() - stalled_at)
                break
            except queue.Empty:
                # trainer starved: the producer lane is the bottleneck.
                # ONE event per contiguous stall, duration on *_seconds
                # (see _offer for the rationale)
                if self._tel is not None and stalled_at is None:
                    stalled_at = time.perf_counter()
                    self._tel.counter("prefetch.consumer_stall").add(1)
                if not self._thread.is_alive():
                    # the thread may have enqueued its final item/sentinel
                    # between our timeout and the liveness check — drain
                    # once more before declaring it dead
                    try:
                        out = self._q.get_nowait()
                        break
                    except queue.Empty:
                        self._done = True
                        raise RuntimeError("prefetch worker exited without "
                                           "delivering a result")
        if out is _DONE:
            self._done = True
            raise StopIteration
        if isinstance(out, _Raise):
            self._done = True
            self._thread.join(timeout=5.0)
            raise out.exc
        return out

    def close(self):
        """Idempotent shutdown: signal the thread, unblock any pending put by
        draining the queue, and join."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()
