"""Mini-batch samplers (survey §5): node-wise (GraphSAGE), layer-wise
(FastGCN-style importance), and subgraph (GraphSAINT random walk).

A MiniBatch carries the layered computation graph as dense block matrices
(rows = targets of layer l, cols = sources of layer l-1) — TPU-friendly, and
exactly the "computation graph generation" stage of the survey's pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class MiniBatch:
    targets: np.ndarray  # [B] final-layer vertex ids (global)
    layer_vertices: List[np.ndarray]  # L+1 frontiers, [0]=input layer
    layer_adj: List[np.ndarray]  # L dense normalized blocks [n_l, n_{l-1}]
    input_features: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None

    @property
    def num_input_vertices(self) -> int:
        return len(self.layer_vertices[0])

    def accessed_vertices(self) -> np.ndarray:
        return np.unique(np.concatenate(self.layer_vertices))


def _block_adj(g: Graph, rows: np.ndarray, cols: np.ndarray,
               sampled_nbrs: List[np.ndarray]) -> np.ndarray:
    col_pos = {int(c): j for j, c in enumerate(cols)}
    A = np.zeros((len(rows), len(cols)), np.float32)
    for i, nbrs in enumerate(sampled_nbrs):
        for u in nbrs:
            A[i, col_pos[int(u)]] = 1.0
        # self loop
        A[i, col_pos[int(rows[i])]] += 1.0
    norm = A.sum(1, keepdims=True)
    return A / np.maximum(norm, 1.0)


def node_wise_sample(g: Graph, targets: np.ndarray, fanouts: Sequence[int],
                     rng: np.random.Generator) -> MiniBatch:
    """GraphSAGE: sample `fanout` neighbors per vertex per layer."""
    layer_vertices = [np.asarray(targets, np.int64)]
    per_layer_nbrs: List[List[np.ndarray]] = []
    frontier = layer_vertices[0]
    for fanout in fanouts:  # from top layer down
        sampled = []
        nxt = set(frontier.tolist())
        for v in frontier:
            nb = g.neighbors(v)
            if len(nb) > fanout:
                nb = rng.choice(nb, size=fanout, replace=False)
            sampled.append(np.asarray(nb))
            nxt.update(np.asarray(nb).tolist())
        per_layer_nbrs.append(sampled)
        frontier = np.asarray(sorted(nxt), np.int64)
        layer_vertices.append(frontier)
    # build blocks: layer l rows = layer_vertices[l], cols = layer_vertices[l+1]
    layer_adj = []
    for l, fanout in enumerate(fanouts):
        layer_adj.append(_block_adj(g, layer_vertices[l], layer_vertices[l + 1],
                                    per_layer_nbrs[l]))
    # reorder: MiniBatch stores [input ... output]
    layer_vertices = layer_vertices[::-1]
    layer_adj = layer_adj[::-1]
    return MiniBatch(
        targets=np.asarray(targets, np.int64),
        layer_vertices=layer_vertices,
        layer_adj=layer_adj,
        input_features=None if g.features is None else g.features[layer_vertices[0]],
        labels=None if g.labels is None else g.labels[targets],
    )


def layer_wise_sample(g: Graph, targets: np.ndarray, layer_sizes: Sequence[int],
                      rng: np.random.Generator) -> MiniBatch:
    """FastGCN-style: per layer, sample a fixed vertex set with probability
    proportional to degree; connect to the previous frontier."""
    deg = g.degree().astype(np.float64)
    p = deg / max(deg.sum(), 1)
    layer_vertices = [np.asarray(targets, np.int64)]
    per_layer_nbrs = []
    frontier = layer_vertices[0]
    for size in layer_sizes:
        pool = rng.choice(g.num_vertices, size=min(size, g.num_vertices),
                          replace=False, p=p)
        pool_set = set(pool.tolist())
        sampled = []
        used = set()
        for v in frontier:
            nb = np.asarray([u for u in g.neighbors(v) if int(u) in pool_set])
            sampled.append(nb)
            used.update(nb.tolist())
        used.update(frontier.tolist())
        nxt = np.asarray(sorted(used), np.int64)
        per_layer_nbrs.append(sampled)
        layer_vertices.append(nxt)
        frontier = nxt
    layer_adj = []
    for l in range(len(layer_sizes)):
        layer_adj.append(_block_adj(g, layer_vertices[l], layer_vertices[l + 1],
                                    per_layer_nbrs[l]))
    layer_vertices = layer_vertices[::-1]
    layer_adj = layer_adj[::-1]
    return MiniBatch(
        targets=np.asarray(targets, np.int64),
        layer_vertices=layer_vertices,
        layer_adj=layer_adj,
        input_features=None if g.features is None else g.features[layer_vertices[0]],
        labels=None if g.labels is None else g.labels[targets],
    )


def subgraph_sample(g: Graph, roots: np.ndarray, walk_length: int,
                    rng: np.random.Generator, num_layers: int = 2) -> MiniBatch:
    """GraphSAINT random-walk subgraph: induced subgraph over walk vertices;
    all layers share the same (sub)adjacency."""
    visited = set(np.asarray(roots).tolist())
    cur = np.asarray(roots)
    for _ in range(walk_length):
        nxt = []
        for v in cur:
            nb = g.neighbors(v)
            if len(nb):
                nxt.append(int(rng.choice(nb)))
        visited.update(nxt)
        cur = np.asarray(nxt) if nxt else cur
    verts = np.asarray(sorted(visited), np.int64)
    sub, remap = g.subgraph(verts)
    A = sub.to_dense_adj(normalized=True)
    layer_vertices = [verts] * (num_layers + 1)
    return MiniBatch(
        targets=verts,
        layer_vertices=layer_vertices,
        layer_adj=[A] * num_layers,
        input_features=None if g.features is None else g.features[verts],
        labels=None if g.labels is None else g.labels[verts],
    )
