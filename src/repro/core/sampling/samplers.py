"""Mini-batch samplers (survey §5): node-wise (GraphSAGE), layer-wise
(FastGCN-style importance), and subgraph (GraphSAINT random walk).

A MiniBatch carries the layered computation graph as dense block matrices
(rows = targets of layer l, cols = sources of layer l-1) — TPU-friendly, and
exactly the "computation graph generation" stage of the survey's pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class MiniBatch:
    targets: np.ndarray  # [B] final-layer vertex ids (global)
    layer_vertices: List[np.ndarray]  # L+1 frontiers, [0]=input layer
    layer_adj: List[np.ndarray]  # L dense normalized blocks [n_l, n_{l-1}]
    input_features: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None

    @property
    def num_input_vertices(self) -> int:
        return len(self.layer_vertices[0])

    def accessed_vertices(self) -> np.ndarray:
        return np.unique(np.concatenate(self.layer_vertices))

    def self_indices(self) -> List[np.ndarray]:
        """out[l][i] = position of layer_vertices[l+1][i] within
        layer_vertices[l] (valid because every sampler keeps its frontiers
        nested: each layer's vertex set contains the next layer's)."""
        out = []
        for l in range(len(self.layer_vertices) - 1):
            cols = self.layer_vertices[l]
            pos = {int(v): j for j, v in enumerate(cols)}
            out.append(np.asarray(
                [pos[int(v)] for v in self.layer_vertices[l + 1]], np.int64))
        return out

    def relabel(self) -> "MiniBatch":
        """Rewrite every frontier (and targets) as positions within the input
        frontier `layer_vertices[0]` — the batch-local id space a device
        computes in.  Round-trips: `lv0[relabeled.layer_vertices[l]] ==
        self.layer_vertices[l]` for every layer."""
        lv0 = self.layer_vertices[0]
        pos = {int(v): j for j, v in enumerate(lv0)}
        local = [np.asarray([pos[int(v)] for v in lv], np.int64)
                 for lv in self.layer_vertices]
        return MiniBatch(
            targets=np.asarray([pos[int(v)] for v in self.targets], np.int64),
            layer_vertices=local,
            layer_adj=self.layer_adj,
            input_features=self.input_features,
            labels=self.labels,
        )


def _block_adj(g: Graph, rows: np.ndarray, cols: np.ndarray,
               sampled_nbrs: List[np.ndarray]) -> np.ndarray:
    col_pos = {int(c): j for j, c in enumerate(cols)}
    A = np.zeros((len(rows), len(cols)), np.float32)
    for i, nbrs in enumerate(sampled_nbrs):
        for u in nbrs:
            A[i, col_pos[int(u)]] = 1.0
        # self loop
        A[i, col_pos[int(rows[i])]] += 1.0
    norm = A.sum(1, keepdims=True)
    return A / np.maximum(norm, 1.0)


def node_wise_sample(g: Graph, targets: np.ndarray, fanouts: Sequence[int],
                     rng: np.random.Generator) -> MiniBatch:
    """GraphSAGE: sample `fanout` neighbors per vertex per layer."""
    layer_vertices = [np.asarray(targets, np.int64)]
    per_layer_nbrs: List[List[np.ndarray]] = []
    frontier = layer_vertices[0]
    for fanout in fanouts:  # from top layer down
        sampled = []
        nxt = set(frontier.tolist())
        for v in frontier:
            nb = g.neighbors(v)
            if len(nb) > fanout:
                nb = rng.choice(nb, size=fanout, replace=False)
            sampled.append(np.asarray(nb))
            nxt.update(np.asarray(nb).tolist())
        per_layer_nbrs.append(sampled)
        frontier = np.asarray(sorted(nxt), np.int64)
        layer_vertices.append(frontier)
    # build blocks: layer l rows = layer_vertices[l], cols = layer_vertices[l+1]
    layer_adj = []
    for l, fanout in enumerate(fanouts):
        layer_adj.append(_block_adj(g, layer_vertices[l], layer_vertices[l + 1],
                                    per_layer_nbrs[l]))
    # reorder: MiniBatch stores [input ... output]
    layer_vertices = layer_vertices[::-1]
    layer_adj = layer_adj[::-1]
    return MiniBatch(
        targets=np.asarray(targets, np.int64),
        layer_vertices=layer_vertices,
        layer_adj=layer_adj,
        input_features=None if g.features is None else g.features[layer_vertices[0]],
        labels=None if g.labels is None else g.labels[targets],
    )


def layer_wise_sample(g: Graph, targets: np.ndarray, layer_sizes: Sequence[int],
                      rng: np.random.Generator) -> MiniBatch:
    """FastGCN-style: per layer, sample a fixed vertex set with probability
    proportional to degree; connect to the previous frontier."""
    deg = g.degree().astype(np.float64)
    p = deg / max(deg.sum(), 1)
    layer_vertices = [np.asarray(targets, np.int64)]
    per_layer_nbrs = []
    frontier = layer_vertices[0]
    for size in layer_sizes:
        pool = rng.choice(g.num_vertices, size=min(size, g.num_vertices),
                          replace=False, p=p)
        pool_set = set(pool.tolist())
        sampled = []
        used = set()
        for v in frontier:
            nb = np.asarray([u for u in g.neighbors(v) if int(u) in pool_set])
            sampled.append(nb)
            used.update(nb.tolist())
        used.update(frontier.tolist())
        nxt = np.asarray(sorted(used), np.int64)
        per_layer_nbrs.append(sampled)
        layer_vertices.append(nxt)
        frontier = nxt
    layer_adj = []
    for l in range(len(layer_sizes)):
        layer_adj.append(_block_adj(g, layer_vertices[l], layer_vertices[l + 1],
                                    per_layer_nbrs[l]))
    layer_vertices = layer_vertices[::-1]
    layer_adj = layer_adj[::-1]
    return MiniBatch(
        targets=np.asarray(targets, np.int64),
        layer_vertices=layer_vertices,
        layer_adj=layer_adj,
        input_features=None if g.features is None else g.features[layer_vertices[0]],
        labels=None if g.labels is None else g.labels[targets],
    )


def subgraph_sample(g: Graph, roots: np.ndarray, walk_length: int,
                    rng: np.random.Generator, num_layers: int = 2) -> MiniBatch:
    """GraphSAINT random-walk subgraph: induced subgraph over walk vertices;
    all layers share the same (sub)adjacency."""
    visited = set(np.asarray(roots).tolist())
    cur = np.asarray(roots)
    for _ in range(walk_length):
        nxt = []
        for v in cur:
            nb = g.neighbors(v)
            if len(nb):
                nxt.append(int(rng.choice(nb)))
        visited.update(nxt)
        cur = np.asarray(nxt) if nxt else cur
    verts = np.asarray(sorted(visited), np.int64)
    sub, remap = g.subgraph(verts)
    A = sub.to_dense_adj(normalized=True)
    layer_vertices = [verts] * (num_layers + 1)
    return MiniBatch(
        targets=verts,
        layer_vertices=layer_vertices,
        layer_adj=[A] * num_layers,
        input_features=None if g.features is None else g.features[verts],
        labels=None if g.labels is None else g.labels[verts],
    )


# ---------------------------------------------------------------------------
# static padding (TPU/jit contract): every sampled batch of a given fanout
# config pads to the same shapes, so a jitted train step compiles once per
# config instead of once per batch.
# ---------------------------------------------------------------------------


def frontier_caps(batching: str, num_layers: int, batch_size: int, *,
                  fanouts: Sequence[int] = (), layer_sizes: Sequence[int] = (),
                  walk_length: int = 0, num_vertices: int = 0) -> List[int]:
    """Worst-case frontier sizes caps[l] for layer_vertices[l] (0 = input
    layer, num_layers = targets), clipped to the vertex count: node-wise
    frontiers grow by at most x(fanout+1) per hop, layer-wise by +layer_size,
    subgraph walks visit at most roots*(walk_length+1) vertices."""
    L = num_layers
    if batching == "node_wise":
        if len(fanouts) != L:
            raise ValueError(f"need {L} fanouts, got {fanouts}")
        caps = [batch_size]
        for f in fanouts:  # applied from the target layer down
            caps.append(caps[-1] * (int(f) + 1))
        caps = caps[::-1]  # index 0 = input layer
    elif batching == "layer_wise":
        if len(layer_sizes) != L:
            raise ValueError(f"need {L} layer sizes, got {layer_sizes}")
        caps = [batch_size]
        for s in layer_sizes:
            caps.append(caps[-1] + int(s))
        caps = caps[::-1]
    elif batching == "subgraph":
        caps = [batch_size * (int(walk_length) + 1)] * (L + 1)
    else:
        raise ValueError(f"unknown batching mode {batching!r}")
    if num_vertices:
        caps = [min(c, num_vertices) for c in caps]
    return caps


def pad_minibatch(mb: MiniBatch, caps: Sequence[int]) -> Dict[str, np.ndarray]:
    """Pad a sampled MiniBatch to the static `caps` shapes.  Pad frontier /
    target slots carry vertex id -1 and mask 0; pad adjacency rows/cols are
    zero, so padded positions stay inert through a forward pass.

    Returns dict(frontier [caps[0]], fmask, tgt [caps[-1]], tmask,
    adj = tuple of [caps[l+1], caps[l]] blocks, self_idx = tuple of
    [caps[l+1]] positions of each layer-(l+1) row within layer l — the
    resident self-feature table for sage/gin/gat; pad rows point at slot 0
    (inert: no real row reads a pad row))."""
    L = len(mb.layer_adj)
    if len(caps) != L + 1:
        raise ValueError(f"need {L + 1} caps, got {len(caps)}")
    for l, lv in enumerate(mb.layer_vertices):
        if len(lv) > caps[l]:
            raise ValueError(
                f"layer {l} frontier {len(lv)} exceeds cap {caps[l]}")
    self_idx = []
    for l, si in enumerate(mb.self_indices()):
        a = np.zeros(caps[l + 1], np.int64)
        a[: len(si)] = si
        self_idx.append(a)
    frontier = np.full(caps[0], -1, np.int64)
    frontier[: mb.num_input_vertices] = mb.layer_vertices[0]
    fmask = np.zeros(caps[0], np.float32)
    fmask[: mb.num_input_vertices] = 1.0
    tgt = np.full(caps[-1], -1, np.int64)
    tgt[: len(mb.targets)] = mb.targets
    tmask = np.zeros(caps[-1], np.float32)
    tmask[: len(mb.targets)] = 1.0
    adj = []
    for l, A in enumerate(mb.layer_adj):
        P = np.zeros((caps[l + 1], caps[l]), np.float32)
        P[: A.shape[0], : A.shape[1]] = A
        adj.append(P)
    return dict(frontier=frontier, fmask=fmask, tgt=tgt, tmask=tmask,
                adj=tuple(adj), self_idx=tuple(self_idx))
