"""Host-side mini-batch construction as a picklable, numpy-only unit.

`HostBatchBuilder` is the engine's sample + extract stages factored out of
`DistGNNEngine` so they can run OUTSIDE the engine's process: the process-pool
prefetcher (`sampling/proc_prefetch.py`) ships one builder to each sampling
worker, which then produces finished padded batches into shared-memory ring
slots.  Three properties make that work:

* **numpy-only**: nothing in this module (or its import chain) touches jax —
  a forked worker must never call into the parent's XLA runtime, and a
  spawned one should not pay the import.  The jnp conversion + CommStats /
  telemetry accounting stay engine-side (`DistGNNEngine._finish_batch`): the
  builder returns plain numpy arrays plus a small metadata dict carrying the
  per-device byte deltas and stage timings.
* **picklable**: every field is plain data (arrays, scalars, dicts); the
  graph handle may be a `Graph` or any object with a ``materialize()``
  method returning one (e.g. `proc_prefetch.SharedGraph`, which attaches to
  the parent's CSR arrays in POSIX shared memory).  Lazily-derived caches
  live outside the dataclass fields and are rebuilt after unpickling.
* **deterministic**: sampling is seeded by (seed, step, device) exactly as
  the in-engine path was, so a pooled epoch is bitwise-identical to a
  blocking one regardless of which worker produced which batch.

The static array layout (`array_layout()`) is the contract with the shm ring:
every batch has the same shapes/dtypes (the §5 padding caps are static), so
ring slots are sized once at pool construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.execution.bucketing import bucketed_send_table, halo_slot
from repro.core.feature_store import touched_rows_from_frontier
from repro.core.partition.edge_cut import Partition
from repro.core.sampling.distributed import (
    CommStats,
    embedding_update_bytes,
    feature_fetch_bytes,
)
from repro.core.sampling.partition_batch import partition_targets
from repro.core.sampling.samplers import (
    layer_wise_sample,
    node_wise_sample,
    pad_minibatch,
    subgraph_sample,
)


class _SpanRecorder:
    """Collects (name, t0, dur, labels) tuples with `time.perf_counter`
    timestamps — CLOCK_MONOTONIC on Linux, shared across processes on one
    host, so the parent can replay them onto its tracer timeline via
    `Tracer.record_span`."""

    def __init__(self):
        self.spans: List[Tuple[str, float, float, Dict]] = []

    @contextlib.contextmanager
    def span(self, name: str, **labels):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.spans.append((name, t0, time.perf_counter() - t0, labels))


@dataclasses.dataclass
class HostBatchBuilder:
    """The engine's host sampling + padded-batch extraction, self-contained.

    Built once per mini-batch plan by `DistGNNEngine._build_minibatch_plan`;
    `sample`/`extract` are the in-process path (the engine delegates), and
    `produce` is the worker-process entry point (sample + extract + timing +
    span recording in one call)."""

    # config scalars (a picklable slice of EngineConfig)
    batching: str
    execution: str
    seed: int
    batch_size: int
    fanouts: Tuple[int, ...]
    layer_sizes: Tuple[int, ...]
    walk_length: int
    num_layers: int
    trainable_features: bool
    # static plan (engine layout + fetch-plan caps)
    k: int
    nb: int
    caps: Tuple[int, ...]
    fcap: int
    fcap_widths: Optional[Tuple[int, ...]]  # p2p only
    Ccap: int
    tcap: int  # 0 when not trainable
    feature_dim: int
    # O(V) layout arrays
    assignment: np.ndarray
    new_of_old: np.ndarray
    labels: np.ndarray
    train_mask: Optional[np.ndarray]
    # per-device resident-cache plan
    cache_slots: List[Dict[int, int]]  # old global id -> overlay row
    cache_sets: List[frozenset]
    overlay_rows: Tuple[int, ...]  # len(cache_old_ids[d]) per device
    # Graph, or anything with .materialize() -> Graph (attached lazily)
    graph: object

    # -- lazy derived state (rebuilt after unpickling) ---------------------

    def __getstate__(self):
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    def __setstate__(self, state):
        for name, v in state.items():
            setattr(self, name, v)

    def _g(self):
        g = self.__dict__.get("_g_cache")
        if g is None:
            g = (self.graph.materialize()
                 if hasattr(self.graph, "materialize") else self.graph)
            self.__dict__["_g_cache"] = g
        return g

    def _part(self) -> Partition:
        p = self.__dict__.get("_part_cache")
        if p is None:
            p = Partition(assignment=self.assignment, num_parts=self.k)
            self.__dict__["_part_cache"] = p
        return p

    # -- the two stages ----------------------------------------------------

    def sample(self, step_idx: int, span_factory=None) -> List:
        """Per device, draw targets from its OWNED partition block and expand
        them with the configured §5 sampler.  Deterministic in (seed, step,
        device) so the oracle — and any rerun, in any process — regenerates
        bitwise-identical batches.  ``span_factory(name, **labels)`` is an
        optional span context factory (the engine's telemetry, or a
        `_SpanRecorder` in a worker)."""
        g = self._g()
        part = self._part()
        mbs = []
        for d in range(self.k):
            ctx = (contextlib.nullcontext() if span_factory is None
                   else span_factory("sample_device", step=step_idx, device=d))
            with ctx:
                rng = np.random.default_rng([self.seed, 7919, step_idx, d])
                targets = partition_targets(g, part, d, self.batch_size, rng)
                if self.batching == "node_wise":
                    mb = node_wise_sample(g, targets, self.fanouts, rng)
                elif self.batching == "layer_wise":
                    mb = layer_wise_sample(g, targets, self.layer_sizes, rng)
                else:  # subgraph
                    mb = subgraph_sample(g, targets, self.walk_length, rng,
                                         num_layers=self.num_layers)
                mbs.append(mb)
        return mbs

    def extract(self, mbs, step=None) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Pad each device's MiniBatch to the static caps, relabel frontiers
        into the engine's new-id space, and build the execution-model fetch
        plan (cache hits short-circuit the exchange).

        Returns ``(arrays, meta)``: ``arrays`` is the flat numpy batch
        (`array_layout()` shapes/dtypes exactly); ``meta["per_device"]``
        carries, per device, the CommStats byte DELTAS this batch costs plus
        frontier occupancy and cache hit/miss counts — the engine applies
        them inside its telemetry-accounted ingest, so pooled and in-process
        epochs account identically."""
        k, nb, L = self.k, self.nb, self.num_layers
        Vp = k * nb
        caps, fcap, Ccap = self.caps, self.fcap, self.Ccap
        D = self.feature_dim
        part = self._part()
        frontier = np.full((k, caps[0]), Vp, np.int64)
        y = np.zeros((k, caps[-1]), np.int32)
        w = np.zeros((k, caps[-1]), np.float32)
        adj = [np.zeros((k, caps[l + 1], caps[l]), np.float32)
               for l in range(L)]
        self_idx = [np.zeros((k, caps[l + 1]), np.int32) for l in range(L)]
        cache_ids = np.full((k, caps[0]), Ccap, np.int32)
        if self.execution == "broadcast":
            bc_ids = np.full((k, caps[0]), Vp, np.int64)
        elif self.execution == "ring":
            ring_ids = np.full((k, k, caps[0]), nb, np.int32)
        else:
            widths = list(self.fcap_widths)
            B, wdt = len(widths), widths[0]
            need_lists = [[np.zeros(0, np.int64) for _ in range(k)]
                          for _ in range(k)]
            tab_ids = np.full((k, caps[0]), nb + B * k * wdt, np.int32)
        per_device = []
        for d, mb in enumerate(mbs):
            padded = pad_minibatch(mb, caps)
            for l in range(L):
                adj[l][d] = padded["adj"][l]
                self_idx[l][d] = padded["self_idx"][l]
            tgt, tmask = padded["tgt"], padded["tmask"]
            safe_tgt = np.clip(tgt, 0, None)
            y[d] = np.where(tgt >= 0, self.labels[safe_tgt], 0)
            # loss only on OWNED train targets: node/layer-wise targets are
            # owned draws already, but subgraph walks visit remote vertices —
            # without this mask a boundary vertex reached by two devices'
            # walks would be double-counted in the psum'd loss/grad
            tw = tmask * np.where(
                tgt >= 0, self.assignment[safe_tgt] == d, False)
            if self.train_mask is not None:
                tw = tw * np.where(
                    tgt >= 0, self.train_mask[safe_tgt], False)
            w[d] = tw
            old = padded["frontier"]
            slot = self.cache_slots[d]
            occ = remote = cache_hits = 0
            # p2p: halo slot of each needed local src row, per source device
            need = [dict() for _ in range(k)]
            for j in range(caps[0]):
                o = int(old[j])
                if o < 0:
                    continue
                occ += 1
                fn = int(self.new_of_old[o])
                frontier[d, j] = fn
                s = fn // nb
                remote += s != d
                cslot = slot.get(o, -1)
                if s != d and cslot >= 0:
                    cache_hits += 1
                    cache_ids[d, j] = cslot
                    continue  # served by the resident cache
                if self.execution == "broadcast":
                    bc_ids[d, j] = fn
                elif self.execution == "ring":
                    ring_ids[d, s, j] = fn % nb
                else:  # p2p
                    if s == d:
                        tab_ids[d, j] = fn % nb
                    else:
                        li = fn % nb
                        pos = need[s].setdefault(li, len(need[s]))
                        tab_ids[d, j] = int(halo_slot(pos, s, wdt, k, nb))
            if self.execution == "p2p":
                for s in range(k):
                    if s != d and need[s]:
                        assert len(need[s]) <= fcap, (
                            f"p2p halo cap overflow: device {d} needs "
                            f"{len(need[s])} rows from {s}, fcap={fcap}")
                        # dict preserves insertion order == pos order
                        need_lists[s][d] = np.fromiter(
                            need[s], np.int64, len(need[s]))
            # byte accounting into a THROWAWAY CommStats: the deltas travel
            # in meta and the engine applies them inside _account_exchange,
            # so process-pooled batches hit the same counters/spans
            delta = CommStats()
            feature_fetch_bytes(part, d, mb.layer_vertices[0], D,
                                cached_ids=self.cache_sets[d], stats=delta)
            if self.trainable_features:
                embedding_update_bytes(
                    part, d, mb.layer_vertices[0], D,
                    cached_ids=self.cache_sets[d],
                    overlay_rows=self.overlay_rows[d], stats=delta)
            per_device.append(dict(
                stats={f.name: getattr(delta, f.name)
                       for f in dataclasses.fields(CommStats)
                       if getattr(delta, f.name)},
                occupancy=occ, remote=remote, cache_hits=cache_hits))
        arrays = dict(frontier=frontier.astype(np.int32), y=y, w=w,
                      cache_ids=cache_ids)
        for l in range(L):
            arrays[f"adj{l}"] = adj[l]
            arrays[f"self_idx{l}"] = self_idx[l]
        if self.execution == "broadcast":
            arrays["bc_ids"] = bc_ids.astype(np.int32)
        elif self.execution == "ring":
            arrays["ring_ids"] = ring_ids
        else:
            # the one write side matching halo_slot's read side — shared
            # with the full-graph and replica-sync plans
            arrays["send_rows"] = bucketed_send_table(need_lists, k, widths)
            arrays["tab_ids"] = tab_ids
        if self.trainable_features:
            # per-OWNER touched local rows (sorted, deterministic): the
            # sparse-AdamW id set — every row any device's frontier reads,
            # hit or miss (hits read the refreshed overlay whose gradient
            # still lands on the owner's shard)
            arrays["emb_ids"] = touched_rows_from_frontier(
                frontier, k, nb, self.tcap)
        meta = dict(per_device=per_device)
        return arrays, meta

    # -- worker-process entry point ----------------------------------------

    def produce(self, step) -> Tuple[Dict[str, np.ndarray], Dict]:
        """sample + extract for one step with stage timing and span
        recording: the `ProcPrefetchPool` producer callable.  The returned
        meta adds ``sample_seconds`` / ``extract_seconds`` (lane seconds for
        StageTimes) and ``spans`` (replayed onto the parent's tracer as this
        worker's lane)."""
        step = int(step)
        rec = _SpanRecorder()
        t0 = time.perf_counter()
        with rec.span("sample", step=step):
            mbs = self.sample(step, span_factory=rec.span)
        t1 = time.perf_counter()
        with rec.span("extract", step=step):
            arrays, meta = self.extract(mbs, step=step)
        t2 = time.perf_counter()
        meta["sample_seconds"] = t1 - t0
        meta["extract_seconds"] = t2 - t1
        meta["spans"] = rec.spans
        return arrays, meta

    # -- the shm ring contract ---------------------------------------------

    def array_layout(self) -> Dict[str, Tuple[Tuple[int, ...], np.dtype]]:
        """name -> (shape, dtype) of every array `extract` returns — static
        across batches (the padding caps), so ring slots are sized once."""
        k, caps, L = self.k, self.caps, self.num_layers
        lay: Dict[str, Tuple[Tuple[int, ...], np.dtype]] = {
            "frontier": ((k, caps[0]), np.dtype(np.int32)),
            "y": ((k, caps[-1]), np.dtype(np.int32)),
            "w": ((k, caps[-1]), np.dtype(np.float32)),
            "cache_ids": ((k, caps[0]), np.dtype(np.int32)),
        }
        for l in range(L):
            lay[f"adj{l}"] = ((k, caps[l + 1], caps[l]),
                              np.dtype(np.float32))
            lay[f"self_idx{l}"] = ((k, caps[l + 1]), np.dtype(np.int32))
        if self.execution == "broadcast":
            lay["bc_ids"] = ((k, caps[0]), np.dtype(np.int32))
        elif self.execution == "ring":
            lay["ring_ids"] = ((k, k, caps[0]), np.dtype(np.int32))
        else:
            B, wdt = len(self.fcap_widths), self.fcap_widths[0]
            lay["send_rows"] = ((k, B, k, wdt), np.dtype(np.int32))
            lay["tab_ids"] = ((k, caps[0]), np.dtype(np.int32))
        if self.trainable_features:
            lay["emb_ids"] = ((k, self.tcap), np.dtype(np.int32))
        return lay
