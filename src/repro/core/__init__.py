"""The survey's taxonomy as a working distributed-GNN engine (DESIGN.md §1):
data partition, batch generation, execution models, communication protocols,
GNN models, and end-to-end training loops.

Exports resolve LAZILY (PEP 562): `repro.core.training` pulls in jax, but the
process-pool sampling workers (`sampling/proc_prefetch.py`) import numpy-only
submodules of this package and must not pay — or under `fork`, risk — the jax
import just for touching ``repro.core``.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "Graph": "repro.core.graph",
    "er_graph": "repro.core.graph",
    "from_edges": "repro.core.graph",
    "powerlaw_graph": "repro.core.graph",
    "sbm_graph": "repro.core.graph",
    "FullGraphResult": "repro.core.training",
    "MiniBatchResult": "repro.core.training",
    "full_graph_train": "repro.core.training",
    "llcg_train": "repro.core.training",
    "minibatch_train": "repro.core.training",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


if TYPE_CHECKING:  # static analyzers see the eager imports
    from repro.core.graph import (  # noqa: F401
        Graph,
        er_graph,
        from_edges,
        powerlaw_graph,
        sbm_graph,
    )
    from repro.core.training import (  # noqa: F401
        FullGraphResult,
        MiniBatchResult,
        full_graph_train,
        llcg_train,
        minibatch_train,
    )
