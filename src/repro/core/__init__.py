"""The survey's taxonomy as a working distributed-GNN engine (DESIGN.md §1):
data partition, batch generation, execution models, communication protocols,
GNN models, and end-to-end training loops.
"""
from repro.core.graph import Graph, er_graph, from_edges, powerlaw_graph, sbm_graph
from repro.core.training import (
    FullGraphResult,
    MiniBatchResult,
    full_graph_train,
    llcg_train,
    minibatch_train,
)
