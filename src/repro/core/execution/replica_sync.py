"""Replica-sync exchange for vertex-cut execution (survey §4.2 + §7): the
Gather-ApplyEdge-Scatter dataflow over replicated vertices.

Each device computes PARTIAL aggregations over its owned edges (a local ELL
multiply in replica-slot space); this module combines those partials across
every replica of a vertex so all replicas see the full neighbor sum.  Three
collective families mirror the engine's edge-cut exchange axis:

  broadcast  all_gather every device's partial block; each device sums its
             slots' replicas out of the gathered table (CAGNET-style).
  ring       ppermute the partial blocks around the ring; each device
             accumulates the visiting block's contribution to its own slots.
  p2p        master-based two-phase GAS: replicas ship partials to each
             vertex's MASTER (all_to_all #1), the master combines, then
             scatters the finished aggregate back to the replicas
             (all_to_all #2) — only 2·Σ(r(v)−1) rows cross the wire per
             layer, the replication-factor-bounded volume that makes
             vertex-cut win on skewed graphs.

All plans are static numpy tables built once from a VertexCutLayout; the
device-side `replica_combine` is pure traced code (collectives + gathers)
with well-defined transposes, so gradients flow through the exchange and the
master-masked loss gives exact weight gradients after the engine's psum.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execution.pipeline_exchange import (
    bucketed_all_to_all,
    bucketed_cap_widths,
    bucketed_send_table,
    chunked_overlap,
    halo_slot,
    zero_pad_row,
)
from repro.core.partition.vertex_layout import VertexCutLayout

REPLICA_EXECUTIONS = ("broadcast", "ring", "p2p")


def _vertex_replica_tables(lay: VertexCutLayout):
    """Per-vertex replica tables: rep_flat[v, r] = flat slot (d*nv + slot) of
    v's r-th replica (pad k*nv), rep_part[v, r] = its device (pad -1).
    Replicas are ordered by device id — deterministic."""
    k, nv = lay.k, lay.nv
    V = lay.slot_of.shape[1]
    parts, verts = np.nonzero(lay.slot_of >= 0)
    order = np.argsort(verts, kind="stable")
    v_s, p_s = verts[order], parts[order]
    flat = p_s * nv + lay.slot_of[p_s, v_s]
    newv = np.r_[0, (np.diff(v_s) != 0).astype(np.int64)]
    first = np.r_[0, np.flatnonzero(np.diff(v_s)) + 1]
    pos = np.arange(len(v_s)) - first[np.cumsum(newv)]
    rep_flat = np.full((V, lay.Rm), k * nv, np.int64)
    rep_part = np.full((V, lay.Rm), -1, np.int64)
    rep_flat[v_s, pos] = flat
    rep_part[v_s, pos] = p_s
    return rep_flat, rep_part


def build_replica_sync_plan(lay: VertexCutLayout, masters: np.ndarray,
                            execution: str, buckets: int = 1) -> Dict:
    """Static exchange plan for one collective family.  Every returned dict
    carries ``rows_per_layer``: the TRUE number of replica rows that cross
    the wire per GNN layer (padding excluded) — the engine's CommStats
    accounting and the standalone cost model must both reproduce it.

    ``buckets`` > 1 splits the p2p send caps (c1/c2, the max pairwise need)
    into power-of-two installments so each lowered all_to_all operand is
    ~``buckets``x smaller (PR 3 follow-up); the wire rows are unchanged."""
    if execution not in REPLICA_EXECUTIONS:
        raise ValueError(f"execution must be one of {REPLICA_EXECUTIONS}")
    k, nv, Rm = lay.k, lay.nv, lay.Rm
    V = lay.slot_of.shape[1]
    vert_ids = lay.vert_ids
    rep_flat, rep_part = _vertex_replica_tables(lay)
    if execution == "broadcast":
        pad_row = np.full((1, Rm), k * nv, np.int64)
        rep_ids = np.concatenate([rep_flat, pad_row], 0)[vert_ids]
        return dict(execution=execution,
                    rep_ids=rep_ids.astype(np.int32),
                    rep_mask=(rep_ids < k * nv).astype(np.float32),
                    rows_per_layer=k * (k - 1) * nv)
    if execution == "ring":
        slot_ext = np.concatenate(
            [lay.slot_of, np.full((k, 1), -1, np.int64)], 1)  # col V = pad
        tmp = slot_ext[:, vert_ids.reshape(-1)].reshape(k, k, nv)
        ring_ids = np.where(tmp < 0, nv, tmp).transpose(1, 0, 2)
        return dict(execution=execution,
                    ring_ids=ring_ids.astype(np.int32),
                    rows_per_layer=k * (k - 1) * nv)
    # p2p: master-based two-phase GAS
    m_of = masters.astype(np.int64)
    # phase 1 (gather): src s ships partial rows of its non-master replicas
    # to each vertex's master.  pos1[s, v] = position of v in need1[s][m(v)].
    need1 = [[np.zeros(0, np.int64) for _ in range(k)] for _ in range(k)]
    pos1 = np.full((k, V), -1, np.int64)
    rows1 = 0
    for s in range(k):
        pres = vert_ids[s] < V
        vs = vert_ids[s][pres]
        sl = np.flatnonzero(pres)
        m = m_of[vs]
        rem = m != s
        for mm in np.unique(m[rem]):
            sel = rem & (m == mm)
            need1[s][mm] = sl[sel]
            pos1[s, vs[sel]] = np.arange(int(sel.sum()))
            rows1 += int(sel.sum())
    c1 = max(1, max((len(x) for row in need1 for x in row), default=1))
    w1 = bucketed_cap_widths(c1, buckets)
    send1 = bucketed_send_table(need1, k, w1)
    pad1 = nv + len(w1) * k * w1[0]
    gather_ids = np.full((k, nv, Rm), pad1, np.int32)
    gather_mask = np.zeros((k, nv, Rm), np.float32)
    for d in range(k):
        pres = vert_ids[d] < V
        vs = vert_ids[d][pres]
        slots = np.flatnonzero(pres)
        own = m_of[vs] == d
        mv, msl = vs[own], slots[own]
        for r in range(Rm):
            s = rep_part[mv, r]
            valid = s >= 0
            ssafe = np.clip(s, 0, k - 1)
            idx = np.where(s == d, msl,
                           halo_slot(pos1[ssafe, mv], ssafe, w1[0], k, nv))
            gather_ids[d, msl[valid], r] = idx[valid]
            gather_mask[d, msl[valid], r] = 1.0
    # phase 2 (scatter): each master ships the finished aggregate back to the
    # other replicas.  pos2[dst, v] = position of v in need2[m(v)][dst].
    need2 = [[np.zeros(0, np.int64) for _ in range(k)] for _ in range(k)]
    pos2 = np.full((k, V), -1, np.int64)
    rows2 = 0
    for m in range(k):
        pres = vert_ids[m] < V
        vs = vert_ids[m][pres]
        slots = np.flatnonzero(pres)
        own = m_of[vs] == m
        mv, msl = vs[own], slots[own]
        dsts, slts, vss = [], [], []
        for r in range(Rm):
            s = rep_part[mv, r]
            valid = (s >= 0) & (s != m)
            dsts.append(s[valid])
            slts.append(msl[valid])
            vss.append(mv[valid])
        dsts = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
        slts = np.concatenate(slts) if slts else np.zeros(0, np.int64)
        vss = np.concatenate(vss) if vss else np.zeros(0, np.int64)
        order = np.lexsort((slts, dsts))
        dsts, slts, vss = dsts[order], slts[order], vss[order]
        for dd in np.unique(dsts):
            sel = dsts == dd
            need2[m][dd] = slts[sel]
            pos2[dd, vss[sel]] = np.arange(int(sel.sum()))
            rows2 += int(sel.sum())
    c2 = max(1, max((len(x) for row in need2 for x in row), default=1))
    w2 = bucketed_cap_widths(c2, buckets)
    send2 = bucketed_send_table(need2, k, w2)
    pad2 = nv + len(w2) * k * w2[0]
    scatter_ids = np.full((k, nv), pad2, np.int32)
    for d in range(k):
        pres = vert_ids[d] < V
        vs = vert_ids[d][pres]
        slots = np.flatnonzero(pres)
        m = m_of[vs]
        own = m == d
        scatter_ids[d, slots[own]] = slots[own]
        rem = ~own
        scatter_ids[d, slots[rem]] = halo_slot(
            pos2[d, vs[rem]], m[rem], w2[0], k, nv).astype(np.int32)
    return dict(execution=execution, send1=send1, gather_ids=gather_ids,
                gather_mask=gather_mask, send2=send2,
                scatter_ids=scatter_ids, rows_per_layer=rows1 + rows2,
                caps=(c1, c2))  # pre-bucketing max pairwise needs


def _ring_combine(partial: jnp.ndarray, ring_ids: jnp.ndarray, axis: str,
                  k: int, combine_op: Callable) -> jnp.ndarray:
    """Double-buffered ring combine (shared by the sum and max passes): the
    ppermute for rotation r+1 is ISSUED in the same step that rotation r's
    block feeds the local gather — the two are data-independent, the pattern
    XLA's async collectives overlap (the same double-buffering as
    `pipeline_exchange.chunked_overlap`).  Exactly k-1 ppermute rounds, the
    plan's rows_per_layer = k*(k-1)*nv wire accounting: the prologue issues
    rotation 1, the scan body issues rotations 2..k-1 while consuming
    1..k-2, and the epilogue consumes rotation k-1 without rotating further.
    Accumulation order (own block, then rotations 1..k-1) is unchanged, so
    results are bitwise-identical to the serial permute-then-gather ring.

    The zero pad row is hoisted out of the loop: every device appends a zero
    row, so rotation keeps slot nv a zero row and pad ring_ids read zeros
    (the identity for the sum combine; the max combine requires all real
    values >= 0 — see `replica_combine_max`)."""
    me = jax.lax.axis_index(axis)
    table0 = jnp.concatenate([partial, zero_pad_row(partial)], 0)
    acc = jnp.take(table0, jnp.take(ring_ids, me, axis=0), axis=0)
    if k == 1:
        return acc
    perm = [(i, (i - 1) % k) for i in range(k)]
    tab1 = jax.lax.ppermute(table0, axis, perm)

    def ring_step(carry, r):
        acc, tab_cur = carry
        tab_nxt = jax.lax.ppermute(tab_cur, axis, perm)  # rotation r+1 ...
        owner = (me + r) % k  # ... flies while rotation r feeds the gather
        acc = combine_op(acc, jnp.take(
            tab_cur, jnp.take(ring_ids, owner, axis=0), axis=0))
        return (acc, tab_nxt), None

    (acc, tab_last), _ = jax.lax.scan(ring_step, (acc, tab1),
                                      jnp.arange(1, k - 1))
    owner = (me + k - 1) % k
    return combine_op(acc, jnp.take(
        tab_last, jnp.take(ring_ids, owner, axis=0), axis=0))


def replica_combine(execution: str, partial: jnp.ndarray, plan: Dict, *,
                    axis: str, k: int, ell_fn: Callable,
                    num_chunks: int = 1) -> jnp.ndarray:
    """Device-local (under shard_map) replica combine: partial [nv, D] ->
    full per-slot neighbor sums [nv, D].  ``plan`` holds this device's slice
    of the static tables; ``ell_fn(ids, mask, table)`` is the masked-gather
    reduction (the engine passes its Pallas ELL kernel).

    ``num_chunks`` > 1 feature-chunks the broadcast/p2p exchange (see
    `pipeline_exchange.chunked_overlap`): the collective for chunk c+1 is
    issued while chunk c's combine computes, and only two chunk-sized
    gathered tables are ever live."""

    if execution == "broadcast":
        def exchange(pc):
            full = jax.lax.all_gather(pc, axis, axis=0, tiled=True)
            return jnp.concatenate([full, zero_pad_row(pc)], 0)

        return chunked_overlap(
            partial, num_chunks, exchange,
            lambda table: ell_fn(plan["rep_ids"], plan["rep_mask"], table))
    if execution == "ring":
        return _ring_combine(partial, plan["ring_ids"], axis, k,
                             lambda a, b: a + b)

    # p2p: gather partials at masters, combine, scatter aggregates back.
    # Phase-1 installment all_to_alls are issued one chunk ahead of the
    # master combine; phase 2 rides inside the consumer (it depends on the
    # combined aggregate, so it cannot be hoisted ahead of it).
    def exchange(pc):
        return pc, bucketed_all_to_all(pc, plan["send1"], axis, k)

    def consume(carry):
        pc, recv = carry
        table = jnp.concatenate([pc, recv, zero_pad_row(pc)], 0)
        agg_m = ell_fn(plan["gather_ids"], plan["gather_mask"], table)
        recv_b = bucketed_all_to_all(agg_m, plan["send2"], axis, k)
        table2 = jnp.concatenate([agg_m, recv_b, zero_pad_row(pc)], 0)
        return jnp.take(table2, plan["scatter_ids"], axis=0)

    return chunked_overlap(partial, num_chunks, exchange, consume)


def replica_combine_max(execution: str, partial: jnp.ndarray, plan: Dict, *,
                        axis: str, k: int) -> jnp.ndarray:
    """Max-combine across replicas — the first pass of the distributed GAT
    segment-softmax: every replica's local max of the per-edge logits is
    combined so all replicas share ONE exact softmax stabilizer, then the
    exp-sum pass rides the ordinary `replica_combine`.

    Reuses the SAME static plan tables as the sum combine, with one invariant
    pushed onto the caller: all real values must be >= 0 (the engine floors
    its local maxima at 0 — any upper bound of the logits is a valid softmax
    shift).  Pad/absent slots then read the zero rows the plans already
    route to, and fold into the max as harmless identities."""
    if execution == "broadcast":
        full = jax.lax.all_gather(partial, axis, axis=0, tiled=True)
        table = jnp.concatenate([full, zero_pad_row(partial)], 0)
        vals = jnp.take(table, plan["rep_ids"], axis=0)  # [nv, Rm, D]
        return jnp.where(plan["rep_mask"][..., None] > 0, vals, 0.0).max(1)
    if execution == "ring":
        return _ring_combine(partial, plan["ring_ids"], axis, k, jnp.maximum)
    # p2p: max partials at masters, scatter the combined max back
    recv = bucketed_all_to_all(partial, plan["send1"], axis, k)
    table = jnp.concatenate([partial, recv, zero_pad_row(partial)], 0)
    vals = jnp.take(table, plan["gather_ids"], axis=0)  # [nv, Rm, D]
    agg_m = jnp.where(plan["gather_mask"][..., None] > 0, vals, 0.0).max(1)
    recv2 = bucketed_all_to_all(agg_m, plan["send2"], axis, k)
    table2 = jnp.concatenate([agg_m, recv2, zero_pad_row(partial)], 0)
    return jnp.take(table2, plan["scatter_ids"], axis=0)


def reference_combine(partial: jnp.ndarray, vert_ids: jnp.ndarray,
                      num_vertices: int) -> jnp.ndarray:
    """Single-device oracle combine: scatter-add every replica's partial into
    the global vertex space and gather back per slot — the same sum any of
    the three collectives computes, without a wire.  partial [k, nv, D]."""
    D = partial.shape[-1]
    G = jnp.zeros((num_vertices + 1, D), partial.dtype).at[
        vert_ids.reshape(-1)].add(partial.reshape(-1, D))
    return jnp.take(G, vert_ids, axis=0)  # pad slots read G[V] = 0


def reference_combine_max(partial: jnp.ndarray, vert_ids: jnp.ndarray,
                          num_vertices: int) -> jnp.ndarray:
    """Single-device oracle for `replica_combine_max`: scatter-MAX into the
    global vertex space and gather back.  Same >= 0 invariant — the zero
    init of the global table plays the role of the plans' zero pad rows."""
    D = partial.shape[-1]
    G = jnp.zeros((num_vertices + 1, D), partial.dtype).at[
        vert_ids.reshape(-1)].max(partial.reshape(-1, D))
    return jnp.take(G, vert_ids, axis=0)
