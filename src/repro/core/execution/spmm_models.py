"""Distributed SpMM execution models (survey §6.2.2, Table 2), as shard_map
programs over jax.lax collectives.

The survey's taxonomy {replicated, 1D, 1.5D, 2D} x {A-, H-, P-stationary}
collapses to three execution shapes:
  C   (computation-only)              : spmm_replicated
  CC  (communication-computation)     : spmm_1d_broadcast (CAGNET 1D),
                                        spmm_1d_ring (chunk-based/pipelined,
                                        SAR/ParallelGCN), spmm_1d_p2p
                                        (selective boundary exchange)
  CCR (communication-computation-     : spmm_2d_summa (CAGNET 2D),
       reduction)                       spmm_15d

All functions compute Y = A @ H for a dense (normalized) adjacency A and
feature matrix H, partitioned per the model. Dense blocks keep the collective
structure identical to the sparse case while staying oracle-checkable; the
sparse local multiply is the Pallas ELL kernel (repro.kernels).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def _axis1(mesh: Mesh) -> str:
    return mesh.axis_names[0]


def spmm_replicated(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """Computation-only (C): A replicated, H column-partitioned."""
    ax = _axis1(mesh)

    def local(A_full, H_cols):
        return A_full @ H_cols  # no communication at all

    return shard_map(local, mesh=mesh, in_specs=(P(), P(None, ax)),
                     out_specs=P(None, ax), check_vma=False)(A, H)


def spmm_1d_broadcast(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """1D P-stationary (CC), broadcast protocol (CAGNET 1D): every device owns
    a row block of A and H; H is all-gathered, Y row block stays local."""
    ax = _axis1(mesh)

    def local(A_rows, H_rows):
        H_full = jax.lax.all_gather(H_rows, ax, axis=0, tiled=True)
        return A_rows @ H_full

    return shard_map(local, mesh=mesh, in_specs=(P(ax, None), P(ax, None)),
                     out_specs=P(ax, None), check_vma=False)(A, H)


def spmm_1d_ring(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """1D CC with *sequential chunk-based execution* (survey §6.2.1) and the
    pipeline protocol (§7.1.3): H row-blocks rotate around a ppermute ring;
    each step accumulates the partial aggregation of one chunk (SAR-style;
    communication of the next chunk overlaps the current partial aggregation
    on real hardware)."""
    ax = _axis1(mesh)
    k = mesh.devices.size

    def local(A_rows, H_rows):
        n_block = H_rows.shape[0]
        me = jax.lax.axis_index(ax)

        def step(carry, r):
            acc, H_cur = carry
            # owner of the block currently held: (me + r) mod k
            owner = (me + r) % k
            A_blk = jax.lax.dynamic_slice_in_dim(A_rows, owner * n_block, n_block, axis=1)
            acc = acc + A_blk @ H_cur
            H_nxt = jax.lax.ppermute(H_cur, ax, [(i, (i - 1) % k) for i in range(k)])
            return (acc, H_nxt), None

        acc0 = jnp.zeros((A_rows.shape[0], H_rows.shape[1]), H_rows.dtype)
        (acc, _), _ = jax.lax.scan(step, (acc0, H_rows), jnp.arange(k))
        return acc

    return shard_map(local, mesh=mesh, in_specs=(P(ax, None), P(ax, None)),
                     out_specs=P(ax, None), check_vma=False)(A, H)


def p2p_plan(A_np: np.ndarray, k: int) -> Tuple[np.ndarray, int]:
    """Selective-P2P plan from block sparsity: which rows of H block j does
    device i actually need (nonzero columns of A[i,:] within block j)?
    Returns (need [k, k, cap] padded row indices within block, cap)."""
    V = A_np.shape[0]
    nb = V // k
    need_sets = [[(np.zeros(0, np.int64) if i == j else  # own block is local
                   np.unique(np.nonzero(A_np[i * nb:(i + 1) * nb, j * nb:(j + 1) * nb])[1]))
                  for j in range(k)] for i in range(k)]
    cap = max(1, max(len(s) for row in need_sets for s in row))
    need = np.zeros((k, k, cap), np.int32)
    cnt = np.zeros((k, k), np.int32)
    for i in range(k):
        for j in range(k):
            s = need_sets[i][j]
            need[i, j, : len(s)] = s
            cnt[i, j] = len(s)
    return need, cnt, cap


def spmm_1d_p2p(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray,
                plan: Tuple[np.ndarray, np.ndarray, int]) -> jnp.ndarray:
    """1D CC with selective P2P (ParallelGCN/DistGNN): only the boundary rows
    each pair actually needs are exchanged, via all_to_all of padded
    per-destination buffers. Communication ∝ cut size, not V."""
    ax = _axis1(mesh)
    k = mesh.devices.size
    need, cnt, cap = plan
    need_j = jnp.asarray(need)  # [dst, src, cap] rows of src block needed by dst
    cnt_j = jnp.asarray(cnt)

    def local(A_rows, H_rows):
        me = jax.lax.axis_index(ax)
        nb = H_rows.shape[0]
        # build send buffer: for each destination d, the rows of MY block that
        # d needs = need[d, me]
        rows_for = need_j[:, me, :]  # [k, cap]
        send = H_rows[rows_for.reshape(-1)].reshape(k, cap, H_rows.shape[1])
        recv = jax.lax.all_to_all(send, ax, split_axis=0, concat_axis=0)  # [k, cap, D]
        # scatter received rows into a sparse H view per source block
        acc = jnp.zeros((A_rows.shape[0], H_rows.shape[1]), H_rows.dtype)
        my_need = need_j[me]  # [k, cap] row ids within each source block
        my_cnt = cnt_j[me]
        for j in range(k):  # static loop over source blocks
            H_blk = jnp.zeros((nb, H_rows.shape[1]), H_rows.dtype)
            valid = (jnp.arange(cap) < my_cnt[j])[:, None]
            H_blk = H_blk.at[my_need[j]].add(jnp.where(valid, recv[j], 0.0))
            # the own block never crosses the wire: read it locally
            H_blk = jnp.where(me == j, H_rows, H_blk)
            A_blk = jax.lax.dynamic_slice_in_dim(A_rows, j * nb, nb, axis=1)
            acc = acc + A_blk @ H_blk
        return acc

    return shard_map(local, mesh=mesh, in_specs=(P(ax, None), P(ax, None)),
                     out_specs=P(ax, None), check_vma=False)(A, H)


def spmm_2d_summa(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """2D A-stationary (CCR, CAGNET 2D / SUMMA): grid (r x c) over both mesh
    axes. A block (i,j) is stationary; H row-blocks are gathered along grid
    columns, partials are reduce-scattered along grid rows."""
    ax_r, ax_c = mesh.axis_names

    def local(A_blk, H_blk):
        # H_blk: rows sharded over (r, c) jointly -> gather the column group's
        # rows: device (i,j) needs H rows of block-column j = all row chunks
        # held by column j across rows i' -> all_gather over ax_r.
        Hj = jax.lax.all_gather(H_blk, ax_r, axis=0, tiled=True)  # rows of block j
        part = A_blk @ Hj  # partial P[i, :] contribution from column j
        # reduce across the row (sum over j) and scatter rows so each (i,j)
        # ends with its chunk of P block-row i
        out = jax.lax.psum_scatter(part, ax_c, scatter_dimension=0, tiled=True)
        return out

    # H rows are laid out column-group-major: the devices of grid column j
    # jointly hold block-column j's rows, so the ax_r all-gather reassembles
    # exactly the rows A block (i,j) needs.
    return shard_map(local, mesh=mesh,
                     in_specs=(P(ax_r, ax_c), P((ax_c, ax_r), None)),
                     out_specs=P((ax_r, ax_c), None), check_vma=False)(A, H)


def spmm_15d(mesh: Mesh, A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """1.5D A-stationary (CCR): A is 2D-partitioned (r x c); H is 1D
    row-partitioned over c (replicated over r). Partials reduce over c."""
    ax_r, ax_c = mesh.axis_names

    def local(A_blk, H_blk):
        part = A_blk @ H_blk  # A block (i,j) x H rows of block j
        out = jax.lax.psum_scatter(part, ax_c, scatter_dimension=0, tiled=True)
        return out

    return shard_map(local, mesh=mesh,
                     in_specs=(P(ax_r, ax_c), P(ax_c, None)),
                     out_specs=P((ax_r, ax_c), None), check_vma=False)(A, H)


SPMM_MODELS = {
    "replicated": spmm_replicated,
    "spmm_1d": spmm_1d_broadcast,
    "spmm_1d_ring": spmm_1d_ring,
    "spmm_2d": spmm_2d_summa,
    "spmm_15d": spmm_15d,
}
