"""Mini-batch execution models (survey §6.1): conventional, factored,
operator-parallel, pipelined, and P3 pull-push — as an explicit stage
scheduler with per-stage timing, so the resource-contention/overlap claims
are measurable.

On a single host the "devices" are worker lanes; stage latencies are measured
wall-clock from the real sampler/cache/train callables.  ``conventional`` /
``factored`` / ``operator_parallel`` MODEL the overlap (they run the stages
serially and derive the overlapped wall); ``pipelined`` EXECUTES it — a
background `PrefetchWorker` thread really runs sample+extract for batch i+1
while the trainer lane consumes batch i, and ``wall`` is true measured
wall-clock including the end-of-epoch device sync.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.telemetry import NULL_TELEMETRY


@dataclasses.dataclass
class StageTimes:
    sample: float = 0.0
    extract: float = 0.0
    train: float = 0.0
    wall: float = 0.0

    def busy(self) -> float:
        return self.sample + self.extract + self.train


def run_conventional(batch_ids: List[np.ndarray], sample_fn, extract_fn,
                     train_fn, *, telemetry=None) -> StageTimes:
    """Sequential sample -> extract -> train per batch (DistDGL default)."""
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    t = StageTimes()
    t0 = time.perf_counter()
    for i, ids in enumerate(batch_ids):
        with tel.span("sample", step=i):
            s0 = time.perf_counter()
            mb = sample_fn(ids)
            t.sample += time.perf_counter() - s0
        with tel.span("extract", step=i):
            s0 = time.perf_counter()
            feats = extract_fn(mb)
            t.extract += time.perf_counter() - s0
        with tel.span("train", step=i):
            s0 = time.perf_counter()
            train_fn(mb, feats)
            t.train += time.perf_counter() - s0
    t.wall = time.perf_counter() - t0
    return t


def run_factored(batch_ids: List[np.ndarray], sample_fn, extract_fn, train_fn,
                 *, telemetry=None) -> StageTimes:
    """GNNLab factored model: dedicated sampler lane + trainer lane; the
    sampler works one batch ahead (double buffering). Wall-clock =
    max(sampler lane, trainer lane) + pipeline fill."""
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    t = StageTimes()
    t0 = time.perf_counter()
    prepared = []
    for i, ids in enumerate(batch_ids):  # sampler lane
        with tel.span("sample", step=i):
            s0 = time.perf_counter()
            mb = sample_fn(ids)
            t.sample += time.perf_counter() - s0
        prepared.append(mb)
    for i, mb in enumerate(prepared):  # trainer lane (extract+train w/ cache)
        with tel.span("extract", step=i):
            s0 = time.perf_counter()
            feats = extract_fn(mb)
            t.extract += time.perf_counter() - s0
        with tel.span("train", step=i):
            s0 = time.perf_counter()
            train_fn(mb, feats)
            t.train += time.perf_counter() - s0
    # modeled overlap: the two lanes run concurrently on separate resources
    t.wall = max(t.sample, t.extract + t.train) + min(t.sample, t.extract + t.train) / max(len(batch_ids), 1)
    return t


def run_operator_parallel(batch_ids: List[np.ndarray], sample_fn, extract_fn,
                          train_fn, lanes: int = 2, *, telemetry=None
                          ) -> StageTimes:
    """ByteGNN/DSP operator-parallel: stages of different batches overlap as a
    DAG; with L lanes the wall-clock approaches busy/L bounded by the longest
    stage chain."""
    t = run_conventional(batch_ids, sample_fn, extract_fn, train_fn,
                         telemetry=telemetry)
    per_stage = [t.sample, t.extract, t.train]
    t.wall = max(max(per_stage), t.busy() / lanes)
    return t


def run_pipelined(batch_ids: List[np.ndarray], sample_fn, extract_fn, train_fn,
                  *, prefetch_depth: int = 2,
                  finalize_fn: Optional[Callable] = None,
                  telemetry=None) -> StageTimes:
    """Measured-lanes pipelined executor: the factored model made REAL.

    A `PrefetchWorker` thread runs sample_fn + extract_fn for batch i+1
    (bounded ``prefetch_depth`` batches ahead) while the trainer lane runs
    train_fn on batch i.  train_fn should DISPATCH the device step without
    blocking on its result (no per-step ``float()``/``block_until_ready``) so
    the jitted step, the host->device transfer, and host sampling genuinely
    overlap; ``finalize_fn`` is the end-of-epoch sync barrier (e.g.
    ``jax.block_until_ready(state)``) so ``wall`` is an honest epoch time.

    Stage seconds are accumulated per lane (sample/extract on the worker
    thread, train on the trainer thread — disjoint writers, read after
    join), so ``busy() > wall`` is the direct measurement of overlap.

    With `telemetry` enabled the same lanes are recorded as spans — worker
    and trainer threads get distinct trace rows (thread-id tagging), so the
    overlap shows up as genuinely overlapping intervals; the worker's queue
    depth/stalls ride `PrefetchWorker`'s own gauges.
    """
    from repro.core.sampling.prefetch import PrefetchWorker

    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    t = StageTimes()
    prod_i = [0]  # producer-thread step counter (worker runs items in order)

    def produce(ids):
        i = prod_i[0]
        prod_i[0] += 1
        with tel.span("sample", step=i):
            s0 = time.perf_counter()
            mb = sample_fn(ids)
            t.sample += time.perf_counter() - s0
        with tel.span("extract", step=i):
            s0 = time.perf_counter()
            feats = extract_fn(mb)
            t.extract += time.perf_counter() - s0
        return mb, feats

    t0 = time.perf_counter()
    worker = PrefetchWorker(batch_ids, produce, depth=prefetch_depth,
                            telemetry=tel)
    try:
        train_i = 0
        for mb, feats in worker:
            with tel.span("train", step=train_i):
                s0 = time.perf_counter()
                train_fn(mb, feats)
                t.train += time.perf_counter() - s0
            train_i += 1
        if finalize_fn is not None:
            # the end-of-epoch device sync: the one place the trace opts
            # into a fence (finalize_fn IS the block_until_ready)
            with tel.span("finalize"):
                s0 = time.perf_counter()
                finalize_fn()
                t.train += time.perf_counter() - s0
    finally:
        worker.close()
    t.wall = time.perf_counter() - t0
    return t


def run_pipelined_process(batch_ids: List, pool, train_fn, *,
                          finalize_fn: Optional[Callable] = None,
                          telemetry=None) -> StageTimes:
    """GIL-free pipelined executor: sample+extract run in the WORKER
    PROCESSES of a `ProcPrefetchPool` (`sampling/proc_prefetch.py`), batches
    arrive through shared memory, and only train_fn runs here.

    Same lane accounting as `run_pipelined`, except the producer lane is
    measured remotely: each delivered ``meta`` carries ``sample_seconds`` /
    ``extract_seconds`` (and the already-timed spans, which the pool replays
    onto per-worker trace lanes).  Because the producers hold their own GILs,
    the overlap does not depend on the trainer releasing this process's —
    the capacity-limited caveat of the thread pipeline disappears.

    ``train_fn(item, arrays, meta)`` should dispatch without blocking;
    ``finalize_fn`` is the end-of-epoch sync, as in `run_pipelined`.  The
    pool outlives the call (workers and shm are reused across epochs) —
    closing it is the owner's job.
    """
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    t = StageTimes()
    t0 = time.perf_counter()
    it = pool.run(batch_ids)
    try:
        for train_i, (item, arrays, meta) in enumerate(it):
            t.sample += meta.get("sample_seconds", 0.0)
            t.extract += meta.get("extract_seconds", 0.0)
            with tel.span("train", step=train_i):
                s0 = time.perf_counter()
                train_fn(item, arrays, meta)
                t.train += time.perf_counter() - s0
        if finalize_fn is not None:
            with tel.span("finalize"):
                s0 = time.perf_counter()
                finalize_fn()
                t.train += time.perf_counter() - s0
    finally:
        it.close()
    t.wall = time.perf_counter() - t0
    return t


def pipelined_wall_model(t: StageTimes, num_batches: int) -> float:
    """Overlap-aware wall-clock model for the two-lane pipeline, cross-checked
    against the MEASURED lanes of `run_pipelined` (tests/bench): the lanes run
    concurrently, so steady-state wall is the slower lane, plus the pipeline
    fill of one batch on the faster lane.  A lower bound for the measured
    wall (scheduling overheads only add), and below the blocking busy sum
    whenever both lanes do real work."""
    n = max(int(num_batches), 1)
    producer = t.sample + t.extract
    trainer = t.train
    return max(producer, trainer) + min(producer, trainer) / n


# Schedule registry so drivers (e.g. DistGNNEngine.run_epoch_minibatch) can
# select a §6.1 execution model by name; every entry shares the
# (batch_ids, sample_fn, extract_fn, train_fn) -> StageTimes signature plus
# a keyword-only ``telemetry`` (``pipelined`` adds prefetch_depth /
# finalize_fn knobs).  StageTimes totals double as per-step spans when a
# Telemetry instance is passed.
SCHEDULES: Dict[str, Callable] = {
    "conventional": run_conventional,
    "factored": run_factored,
    "operator_parallel": run_operator_parallel,
    "pipelined": run_pipelined,
}


@dataclasses.dataclass
class PullPushPlan:
    """P3: the first-hop aggregation runs model-parallel over column-sharded
    features (push the tiny graph, not the fat features), then switches to
    data parallel. comm_bytes compares against feature pulling."""
    graph_bytes: int
    hidden_bytes: int
    feature_bytes_baseline: int

    @property
    def saving(self) -> float:
        return 1.0 - (self.graph_bytes + self.hidden_bytes) / max(
            self.feature_bytes_baseline, 1)


def p3_plan(num_batch_vertices: int, num_batch_edges: int, feature_dim: int,
            hidden_dim: int, num_workers: int) -> PullPushPlan:
    """Byte accounting of P3 pull-push vs conventional feature pulling for one
    mini-batch (Gandhi & Iyer §5): conventional moves D-dim input features of
    every frontier vertex; P3 moves the subgraph structure + H-dim activations."""
    id_bytes = 8
    graph = num_batch_edges * 2 * id_bytes * (num_workers - 1) // num_workers
    hidden = num_batch_vertices * hidden_dim * 4
    feats = num_batch_vertices * feature_dim * 4 * (num_workers - 1) // num_workers
    return PullPushPlan(graph, hidden, feats)
