"""Execution models (survey §6): chunked aggregation, replica sync, SpMM
strategies, the bucketed/chunked pipelined exchange, and the mini-batch stage
schedules.

Exports resolve LAZILY (PEP 562): most submodules here import jax, but the
process-pool sampling workers import the numpy-only `bucketing` submodule of
this package and must not pay — or under `fork`, risk — the jax import just
for touching ``repro.core.execution``.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "one_shot_aggregate": "repro.core.execution.chunk",
    "parallel_chunk_aggregate": "repro.core.execution.chunk",
    "sequential_chunk_aggregate": "repro.core.execution.chunk",
    "REPLICA_EXECUTIONS": "repro.core.execution.replica_sync",
    "build_replica_sync_plan": "repro.core.execution.replica_sync",
    "reference_combine": "repro.core.execution.replica_sync",
    "replica_combine": "repro.core.execution.replica_sync",
    "SCHEDULES": "repro.core.execution.minibatch_pipeline",
    "PullPushPlan": "repro.core.execution.minibatch_pipeline",
    "StageTimes": "repro.core.execution.minibatch_pipeline",
    "p3_plan": "repro.core.execution.minibatch_pipeline",
    "pipelined_wall_model": "repro.core.execution.minibatch_pipeline",
    "run_conventional": "repro.core.execution.minibatch_pipeline",
    "run_factored": "repro.core.execution.minibatch_pipeline",
    "run_operator_parallel": "repro.core.execution.minibatch_pipeline",
    "run_pipelined": "repro.core.execution.minibatch_pipeline",
    "run_pipelined_process": "repro.core.execution.minibatch_pipeline",
    "bucketed_all_to_all": "repro.core.execution.pipeline_exchange",
    "bucketed_cap_widths": "repro.core.execution.bucketing",
    "bucketed_send_table": "repro.core.execution.bucketing",
    "halo_slot": "repro.core.execution.bucketing",
    "chunked_overlap": "repro.core.execution.pipeline_exchange",
    "feature_chunks": "repro.core.execution.pipeline_exchange",
    "gathered_table_peak_bytes": "repro.core.execution.pipeline_exchange",
    "SPMM_MODELS": "repro.core.execution.spmm_models",
    "p2p_plan": "repro.core.execution.spmm_models",
    "spmm_15d": "repro.core.execution.spmm_models",
    "spmm_1d_broadcast": "repro.core.execution.spmm_models",
    "spmm_1d_p2p": "repro.core.execution.spmm_models",
    "spmm_1d_ring": "repro.core.execution.spmm_models",
    "spmm_2d_summa": "repro.core.execution.spmm_models",
    "spmm_replicated": "repro.core.execution.spmm_models",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


if TYPE_CHECKING:  # static analyzers see the eager imports
    from repro.core.execution.chunk import (  # noqa: F401
        one_shot_aggregate,
        parallel_chunk_aggregate,
        sequential_chunk_aggregate,
    )
    from repro.core.execution.minibatch_pipeline import (  # noqa: F401
        SCHEDULES,
        PullPushPlan,
        StageTimes,
        p3_plan,
        pipelined_wall_model,
        run_conventional,
        run_factored,
        run_operator_parallel,
        run_pipelined,
        run_pipelined_process,
    )
    from repro.core.execution.pipeline_exchange import (  # noqa: F401
        bucketed_all_to_all,
        bucketed_cap_widths,
        chunked_overlap,
        feature_chunks,
        gathered_table_peak_bytes,
    )
    from repro.core.execution.replica_sync import (  # noqa: F401
        REPLICA_EXECUTIONS,
        build_replica_sync_plan,
        reference_combine,
        replica_combine,
    )
    from repro.core.execution.spmm_models import (  # noqa: F401
        SPMM_MODELS,
        p2p_plan,
        spmm_15d,
        spmm_1d_broadcast,
        spmm_1d_p2p,
        spmm_1d_ring,
        spmm_2d_summa,
        spmm_replicated,
    )
