from repro.core.execution.chunk import (
    one_shot_aggregate,
    parallel_chunk_aggregate,
    sequential_chunk_aggregate,
)
from repro.core.execution.replica_sync import (
    REPLICA_EXECUTIONS,
    build_replica_sync_plan,
    reference_combine,
    replica_combine,
)
from repro.core.execution.minibatch_pipeline import (
    SCHEDULES,
    PullPushPlan,
    StageTimes,
    p3_plan,
    pipelined_wall_model,
    run_conventional,
    run_factored,
    run_operator_parallel,
    run_pipelined,
)
from repro.core.execution.pipeline_exchange import (
    bucketed_all_to_all,
    bucketed_cap_widths,
    chunked_overlap,
    feature_chunks,
    gathered_table_peak_bytes,
)
from repro.core.execution.spmm_models import (
    SPMM_MODELS,
    p2p_plan,
    spmm_15d,
    spmm_1d_broadcast,
    spmm_1d_p2p,
    spmm_1d_ring,
    spmm_2d_summa,
    spmm_replicated,
)
