"""Graph-view execution models (survey §6.2.1): one-shot vs chunk-based
aggregation, single-device reference semantics (the distributed counterparts
live in spmm_models: one-shot == 1D broadcast, sequential chunk == ring,
parallel chunk == CCR reduction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def one_shot_aggregate(A: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """Collect every neighbor feature first, aggregate in one shot."""
    return A @ H


def sequential_chunk_aggregate(A: jnp.ndarray, H: jnp.ndarray, num_chunks: int) -> jnp.ndarray:
    """Split the neighborhood into chunks; accumulate partial aggregations
    sequentially (NeuGraph/SAR) — bounded memory: one chunk live at a time."""
    V = H.shape[0]
    assert V % num_chunks == 0
    nb = V // num_chunks
    Ar = A.reshape(A.shape[0], num_chunks, nb).transpose(1, 0, 2)
    Hr = H.reshape(num_chunks, nb, H.shape[1])

    def step(acc, blk):
        A_blk, H_blk = blk
        return acc + A_blk @ H_blk, None

    acc0 = jnp.zeros((A.shape[0], H.shape[1]), H.dtype)
    acc, _ = jax.lax.scan(step, acc0, (Ar, Hr))
    return acc


def parallel_chunk_aggregate(A: jnp.ndarray, H: jnp.ndarray, num_chunks: int) -> jnp.ndarray:
    """All chunks compute partials in parallel, then one reduction
    (DeepGalois/DistGNN/FlexGraph) — on hardware the reduction is the psum."""
    V = H.shape[0]
    assert V % num_chunks == 0
    nb = V // num_chunks
    Ar = A.reshape(A.shape[0], num_chunks, nb).transpose(1, 0, 2)
    Hr = H.reshape(num_chunks, nb, H.shape[1])
    partials = jnp.einsum("krn,knd->krd", Ar, Hr)  # all chunks at once
    return partials.sum(0)
