"""Chunked communication/compute overlap for the jitted exchange (survey
§6-§7 pipelining, CAGNET-style).

The engine's broadcast/p2p exchanges used to materialize the FULL gathered
neighbor table (all rows x all feature columns) before a single ELL multiply
ran: peak per-device memory O(V*D) and zero overlap between the wire and the
MXU.  This module splits the feature dimension into C static chunks and
software-pipelines them with a double-buffered `jax.lax.scan`: the collective
for chunk c+1 is ISSUED in the same scan step that the consumer (the Pallas
ELL multiply) processes chunk c, so XLA's async collectives can hide wire
time behind compute, and at most TWO chunk-sized gathered tables are ever
live — peak O(V*D/C).

Feature columns are independent in every consumer the engine has (masked
gather-sum over K neighbors, plain row gather), so the chunked exchange is
numerically identical to the monolithic one column by column.

Also here: the power-of-two BUCKETED p2p installment schedule.  A single
all_to_all must pad every (src, dst) pair to the max pairwise need, so one
heavy pair inflates the lowered send buffer k-fold; splitting the cap into B
power-of-two installments keeps each all_to_all operand at k*w rows
(w ~ cap/B) while shipping exactly the same rows overall.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.execution.bucketing import (  # noqa: F401 — re-exported API
    bucketed_cap_widths,
    bucketed_send_table,
    halo_slot,
)
from repro.core.partition.cost_models import FEAT_BYTES


# ---------------------------------------------------------------------------
# Feature-dim chunking (double-buffered exchange/consume overlap)
# ---------------------------------------------------------------------------


def feature_chunks(D: int, num_chunks: int) -> int:
    """Effective static chunk count: clipped to [1, D]."""
    return max(1, min(int(num_chunks), int(D)))


def chunk_width(D: int, num_chunks: int) -> int:
    """Per-chunk feature width (ceil division)."""
    C = feature_chunks(D, num_chunks)
    return -(-int(D) // C)


def zero_pad_row(h: jnp.ndarray) -> jnp.ndarray:
    """The one-row zero pad every gather table appends so pad/absent ids
    read zeros — shared here so the pad-row convention lives in one place."""
    return jnp.zeros((1, h.shape[1]), h.dtype)


def chunked_overlap(h: jnp.ndarray, num_chunks: int,
                    exchange_fn: Callable, consume_fn: Callable) -> jnp.ndarray:
    """Software-pipelined per-feature-chunk exchange.

    ``h`` [rows, D] is split into C static chunks along the feature axis;
    ``exchange_fn(h_chunk [rows, Dc]) -> pytree`` issues the collective for
    one chunk (all_gather / all_to_all + table assembly) and
    ``consume_fn(pytree) -> [out_rows, Dc]`` is the chunk consumer (the ELL
    multiply / row gather).  The scan carries the prefetched chunk: per step
    the collective for chunk c+1 is issued while chunk c is consumed — the
    two are data-independent inside the step, which is exactly the pattern
    XLA's async collectives overlap.  With C == 1 this is the monolithic
    exchange, bit for bit.
    """
    rows, D = h.shape
    C = feature_chunks(D, num_chunks)
    if C <= 1:
        return consume_fn(exchange_fn(h))
    Dc = chunk_width(D, C)
    if C * Dc != D:
        h = jnp.pad(h, ((0, 0), (0, C * Dc - D)))
    hs = h.reshape(rows, C, Dc).transpose(1, 0, 2)  # [C, rows, Dc]
    g0 = exchange_fn(hs[0])

    def body(g_cur, h_next):
        g_next = exchange_fn(h_next)  # issue chunk c+1's collective ...
        out = consume_fn(g_cur)       # ... while chunk c feeds the multiply
        return g_next, out

    g_last, outs = jax.lax.scan(body, g0, hs[1:])
    out = jnp.concatenate([outs, consume_fn(g_last)[None]], axis=0)
    out = out.transpose(1, 0, 2).reshape(out.shape[1], C * Dc)
    return out[:, :D] if C * Dc != D else out


def gathered_table_peak_bytes(rows: int, D: int, num_chunks: int,
                              feat_bytes: int = FEAT_BYTES) -> int:
    """Peak bytes of the gathered neighbor table live at once on one device
    for the broadcast exchange: the monolithic path keeps the full
    rows x D table; the double-buffered chunked path keeps at most TWO
    rows x ceil(D/C) chunk tables (current + prefetched)."""
    C = feature_chunks(D, num_chunks)
    if C <= 1:
        return int(rows) * int(D) * feat_bytes
    return 2 * int(rows) * chunk_width(D, C) * feat_bytes


# ---------------------------------------------------------------------------
# Power-of-two bucketed p2p installments
# ---------------------------------------------------------------------------
# The static slot layout (bucketed_cap_widths / halo_slot /
# bucketed_send_table) lives in `bucketing.py` — numpy-only so the
# process-pool sampling workers can build fetch plans without importing jax —
# and is re-exported above.  Only the jax collective lives here.


def bucketed_all_to_all(h: jnp.ndarray, send_rows: jnp.ndarray, axis: str,
                        k: int) -> jnp.ndarray:
    """The installment all_to_alls: ``send_rows`` [B, k, w] holds, per
    installment b and destination d, the local row ids this device ships.
    Returns the received halo rows [B*k*w, D] in installment-major order
    (matching `halo_slot`).  Each round's send operand is k*w rows — the
    lowered all_to_all buffer is ``B``x smaller than the monolithic
    k*cap-row send, and the rounds are independent so they pipeline."""
    B, k2, w = send_rows.shape
    assert k2 == k, (send_rows.shape, k)
    D = h.shape[1]
    recvs = []
    for b in range(B):  # static unroll; each round's buffers die after use
        send = h[send_rows[b].reshape(-1)].reshape(k, w, D)
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0)
        recvs.append(recv.reshape(k * w, D))
    return recvs[0] if B == 1 else jnp.concatenate(recvs, axis=0)
