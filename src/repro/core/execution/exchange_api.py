"""ExchangeBackend: the execution side of the partition-family interface
(`partition/layout_api.py` owns the static tables; this module owns the
device-local traced programs that move rows over the wire under shard_map).

Two backends cover the survey's §4.2 families:

  EdgeCutBackend      halo exchange — neighbor rows cross the wire
                      (broadcast all_gather / ring ppermute scan / bucketed
                      p2p all_to_all installments), then ONE masked ELL
                      multiply over the gathered table.  GAT ships the
                      transformed rows FUSED with their attention-coefficient
                      column in a single chunked exchange (see `gat_layer`).
  ReplicaSyncBackend  partial aggregation over OWNED edges in replica-slot
                      space, then the replica-sync GAS combine
                      (execution/replica_sync.py).  Parametrized by two
                      layout flags so ONE backend serves both replica
                      families:
                        sync_active  replicas exist -> combine partials
                                     (vertex_cut: always; hybrid: only when
                                     some vertex actually replicates);
                        halo_active  the owned-edge ELL reads remote
                                     low-degree source rows through a halo
                                     table appended after the local block
                                     (hybrid only; vertex_cut keeps every
                                     source row local by construction).

A backend duck-types the engine: it reads eng.{_ell, _ell_attend, _sddmm,
_combine, _gat_softmax, axis, k, nb, cfg, playout} and nothing else.  A
fourth family either reuses one of these (the hybrid route: flags on the
layout) or adds a class here and maps it in `make_backend`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.execution.pipeline_exchange import (
    bucketed_all_to_all,
    chunked_overlap,
    feature_chunks,
    chunk_width,
    zero_pad_row,
)
from repro.core.execution.replica_sync import (
    replica_combine,
    replica_combine_max,
)


class ExchangeBackend:
    has_replicas = False

    def __init__(self, eng):
        self.eng = eng

    def aggregate(self, h_local, cl):
        """One layer's neighbor exchange + masked aggregation, normalized by
        the (global) degree: h_local [nb, D] -> agg [nb, D]."""
        raise NotImplementedError

    def gat_layer(self, p_l, H, cl, last: bool):
        """One distributed GAT layer (edge-wise attention through this
        backend's exchange)."""
        raise NotImplementedError

    def combine_rows(self, rows, cl):
        """Sum per-slot rows across replicas (identity when the family has
        none) — the trainable-embedding grad/delta path."""
        return rows


class EdgeCutBackend(ExchangeBackend):
    """Halo exchange: broadcast / ring / bucketed-p2p assembly of the
    gathered neighbor table, feature-chunked for §6-§7 overlap."""

    def exchange_fn(self, cl):
        """The broadcast/p2p table assembly as a reusable closure:
        hc [nb, Dc] -> gather table (+ the one zero pad row).
        Width-agnostic, so the GAT layer reuses it for the fused
        [s-column | Hw] payload."""
        eng = self.eng
        ax, k = eng.axis, eng.k
        if eng.cfg.execution == "broadcast":
            def exchange(hc):
                h_full = jax.lax.all_gather(hc, ax, axis=0, tiled=True)
                return jnp.concatenate([h_full, zero_pad_row(hc)], 0)
        else:
            send_rows = cl["send_rows"]  # [B, k, w]

            def exchange(hc):
                recv = bucketed_all_to_all(hc, send_rows, ax, k)
                return jnp.concatenate([hc, recv, zero_pad_row(hc)], 0)
        return exchange

    def aggregate(self, h_local, cl):
        eng = self.eng
        ax, k, nb = eng.axis, eng.k, eng.nb
        C = eng.cfg.exchange_chunks
        ids, mask, deg = cl["ids"], cl["mask"], cl["deg"]
        if eng.cfg.execution == "ring":
            me = jax.lax.axis_index(ax)

            def ring_step(carry, r):
                acc, h_cur = carry
                owner = (me + r) % k
                ids_r = jnp.take(ids, owner, axis=0)  # [nb, K]
                mask_r = jnp.take(mask, owner, axis=0)
                # pad slots carry id 0 / mask 0: no zero-row concatenate in
                # the scan, the masked reduction drops them
                part = eng._ell(ids_r, mask_r, h_cur)
                h_nxt = jax.lax.ppermute(
                    h_cur, ax, [(i, (i - 1) % k) for i in range(k)])
                return (acc + part, h_nxt), None

            acc0 = jnp.zeros((nb, h_local.shape[1]), h_local.dtype)
            (acc, _), _ = jax.lax.scan(ring_step, (acc0, h_local),
                                       jnp.arange(k))
            # normalize ONCE after the scan: deg is constant across rounds
            return acc / deg
        # broadcast / p2p: chunked double-buffered exchange + ELL multiply
        agg = chunked_overlap(h_local, C, self.exchange_fn(cl),
                              lambda table: eng._ell(ids, mask, table))
        return agg / deg

    def gat_layer(self, p_l, H, cl, last: bool):
        """Distributed edge-cut GAT: per-edge logits over the ELL structure,
        masked segment-softmax, attention-weighted gather-sum — pad slots
        stay inert and degree-0 rows fall back to their own transformed row.

        broadcast/p2p ship ONE fused exchange of [a_src.Hw | Hw] (width
        d_out + 1): the attention-coefficient column rides as column 0 of
        chunk 0 of the chunked exchange instead of a separate width-1
        pre-pass.  Same bytes (rows x (d_out+1)), one less collective
        launch per layer, and bitwise-identical output: the exchange is a
        row-wise gather and the attend reduction is column-independent, so
        fusing/chunking never mixes columns."""
        eng = self.eng
        c = eng.cfg
        ids, mask = cl["ids"], cl["mask"]
        Hw = H @ p_l["w"]
        if c.execution == "ring":
            num, den = self._gat_ring(p_l, Hw, ids, mask)
        else:
            exchange = self.exchange_fn(cl)
            s_dst = (Hw @ p_l["a_dst"])[:, None]
            F = jnp.concatenate([(Hw @ p_l["a_src"])[:, None], Hw], 1)
            rows, Dtot = F.shape  # Dtot = d_out + 1
            C = feature_chunks(Dtot, c.exchange_chunks)

            def softmax_from(tab0):
                s_nbr = jnp.take(tab0[:, :1], ids, axis=0)[..., 0]
                e = jnp.where(mask > 0,
                              jax.nn.leaky_relu(s_dst + s_nbr, 0.2), -1e30)
                return eng._gat_softmax(e)

            if C <= 1:
                tab = exchange(F)
                pw, den = softmax_from(tab)
                num = eng._ell_attend(ids, pw, tab[:, 1:])
            else:
                Dc = chunk_width(Dtot, C)
                if C * Dc != Dtot:
                    F = jnp.pad(F, ((0, 0), (0, C * Dc - Dtot)))
                hs = F.reshape(rows, C, Dc).transpose(1, 0, 2)
                g0 = exchange(hs[0])
                # the fused pre-pass: softmax weights come from chunk 0's
                # first column, BEFORE chunk 0's attend is consumed — the
                # remaining chunks double-buffer exactly as chunked_overlap
                pw, den = softmax_from(g0)

                def body(g_cur, h_next):
                    g_next = exchange(h_next)
                    return g_next, eng._ell_attend(ids, pw, g_cur)

                g_last, outs = jax.lax.scan(body, g0, hs[1:])
                out = jnp.concatenate(
                    [outs, eng._ell_attend(ids, pw, g_last)[None]], 0)
                out = out.transpose(1, 0, 2).reshape(out.shape[1], C * Dc)
                # column 0 is the shipped s-column's attend (unused); pad
                # columns attend to zero — slice the Hw columns back out
                num = out[:, 1:Dtot]
        z = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), Hw)
        return z if last else jax.nn.relu(z)

    def _gat_ring(self, p_l, Hw, ids_all, mask_all):
        """Edge-cut ring GAT: one pass of online softmax (flash-attention
        style running max + rescale) over the k rotating source blocks — the
        exact masked softmax without a second max round.  The rotating block
        carries [Hw | a_src . Hw]; rotation r+1 is issued while rotation r
        feeds the gather (same double-buffering as the replica-sync ring)."""
        eng = self.eng
        ax, k, nb = eng.axis, eng.k, eng.nb
        me = jax.lax.axis_index(ax)
        s_dst = (Hw @ p_l["a_dst"])[:, None]
        blk0 = jnp.concatenate([Hw, (Hw @ p_l["a_src"])[:, None]], 1)
        perm = [(i, (i - 1) % k) for i in range(k)]

        def consume(carry, blk, owner):
            m, num, den = carry
            ids_r = jnp.take(ids_all, owner, axis=0)
            mask_r = jnp.take(mask_all, owner, axis=0)
            s_nbr = jnp.take(blk[:, -1], ids_r, axis=0)
            e = jnp.where(mask_r > 0,
                          jax.nn.leaky_relu(s_dst + s_nbr, 0.2), -1e30)
            m_new = jax.lax.stop_gradient(
                jnp.maximum(m, jnp.max(e, axis=1, keepdims=True)))
            sc = jnp.exp(m - m_new)
            pw = jnp.exp(e - m_new) * (e > -1e29)
            num = num * sc + eng._ell_attend(ids_r, pw, blk[:, :-1])
            den = den * sc + pw.sum(1, keepdims=True)
            return m_new, num, den

        carry = (jnp.full((nb, 1), -1e30, Hw.dtype),
                 jnp.zeros_like(Hw), jnp.zeros((nb, 1), Hw.dtype))
        carry = consume(carry, blk0, me)  # round 0: own block, no rotation
        if k == 1:
            return carry[1], carry[2]
        # exactly k-1 ppermute rounds, same prologue/scan/epilogue structure
        # as replica_sync._ring_combine (the scan-every-round form issued a
        # k-th rotation whose output was never consumed)
        blk1 = jax.lax.ppermute(blk0, ax, perm)

        def ring_step(carry_blk, r):
            carry, blk = carry_blk
            blk_nxt = jax.lax.ppermute(blk, ax, perm)  # rotation r+1 flies
            carry = consume(carry, blk, (me + r) % k)  # while r is consumed
            return (carry, blk_nxt), None

        (carry, blk_last), _ = jax.lax.scan(ring_step, (carry, blk1),
                                            jnp.arange(1, k - 1))
        _, num, den = consume(carry, blk_last, (me + k - 1) % k)
        return num, den


class ReplicaSyncBackend(ExchangeBackend):
    """Owned-edge partial aggregation + replica-sync combine, with an
    optional halo table for hybrid layouts whose owned edges read remote
    (low-degree, never-replicated) source rows."""

    def __init__(self, eng):
        super().__init__(eng)
        lay = eng.playout
        self.sync_active = getattr(lay, "sync_active", True)
        self.halo_active = getattr(lay, "halo_active", False)
        self.has_replicas = self.sync_active

    def _halo_table(self, hc, cl):
        """Gather table for one feature chunk: [local block (nv rows) |
        halo rows (canonical installment-major slots) | one zero row].
        Without a halo the table is the vertex-cut [h | zero] form, bit for
        bit.  Each canonical halo slot has exactly ONE real source; under
        broadcast/ring the other reads land on zero rows (sum-identity)."""
        eng = self.eng
        ax, k = eng.axis, eng.k
        if not self.halo_active:
            return jnp.concatenate([hc, zero_pad_row(hc)], 0)
        execution = eng.cfg.execution
        if execution == "broadcast":
            h_all = jax.lax.all_gather(hc, ax, axis=0, tiled=True)
            tab = jnp.concatenate([h_all, zero_pad_row(hc)], 0)
            halo = jnp.take(tab, cl["halo_src"], axis=0)  # [Hbuf, Dc]
        elif execution == "ring":
            me = jax.lax.axis_index(ax)
            perm = [(i, (i - 1) % k) for i in range(k)]
            Hbuf = cl["halo_ring"].shape[1]

            def ring_step(carry, r):
                acc, h_cur = carry
                owner = (me + r) % k
                idx = jnp.take(cl["halo_ring"], owner, axis=0)  # [Hbuf]
                tab = jnp.concatenate([h_cur, zero_pad_row(h_cur)], 0)
                acc = acc + jnp.take(tab, idx, axis=0)
                h_nxt = jax.lax.ppermute(h_cur, ax, perm)
                return (acc, h_nxt), None

            acc0 = jnp.zeros((Hbuf, hc.shape[1]), hc.dtype)
            (halo, _), _ = jax.lax.scan(ring_step, (acc0, hc),
                                        jnp.arange(k))
        else:  # p2p: canonical order is built into the send table
            halo = bucketed_all_to_all(hc, cl["halo_send"], ax, k)
        return jnp.concatenate([hc, halo, zero_pad_row(hc)], 0)

    def aggregate(self, h_local, cl):
        eng = self.eng
        c = eng.cfg
        ax, k = eng.axis, eng.k
        ids, mask, deg = cl["ids"], cl["mask"], cl["deg"]
        if self.halo_active:
            partial = chunked_overlap(
                h_local, c.exchange_chunks,
                lambda hc: self._halo_table(hc, cl),
                lambda table: eng._ell(ids, mask, table))
        else:
            # partial aggregation over OWNED edges (replica-slot space)
            partial = eng._ell(ids, mask,
                               self._halo_table(h_local, cl))
        if self.sync_active:
            partial = replica_combine(c.execution, partial, cl, axis=ax,
                                      k=k, ell_fn=eng._ell,
                                      num_chunks=c.exchange_chunks)
        return partial / deg

    def gat_layer(self, p_l, H, cl, last: bool):
        """GAT over owned edges: a two-pass (max, then sum) replica sync
        exactifies the segment-softmax normalizer across replicas.  When
        sync is inactive (hybrid at threshold=inf: no vertex replicates)
        the local floored max IS the exact stabilizer and the partial IS
        the total — both passes degenerate to identity, matching the
        reference's single-replica scatter combine bit for bit."""
        eng = self.eng
        c = eng.cfg
        ax, k = eng.axis, eng.k
        ids, mask = cl["ids"], cl["mask"]
        Hw = H @ p_l["w"]
        table = self._halo_table(Hw, cl)
        e = eng._sddmm(ids, mask, table, p_l["a_src"], p_l["a_dst"])
        m_loc = jnp.maximum(jnp.max(e, axis=1, keepdims=True), 0.0)
        if self.sync_active:
            M = jax.lax.stop_gradient(replica_combine_max(
                c.execution, m_loc, cl, axis=ax, k=k))
        else:
            M = jax.lax.stop_gradient(m_loc)
        pw = jnp.exp(e - M) * (e > -1e29)
        part = jnp.concatenate(
            [eng._ell_attend(ids, pw, table),
             pw.sum(1, keepdims=True)], 1)
        if self.sync_active:
            comb = replica_combine(c.execution, part, cl, axis=ax, k=k,
                                   ell_fn=eng._ell,
                                   num_chunks=c.exchange_chunks)
        else:
            comb = part
        num, den = comb[:, :-1], comb[:, -1:]
        z = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), Hw)
        return z if last else jax.nn.relu(z)

    def combine_rows(self, rows, cl):
        if not self.sync_active:
            return rows
        eng, c = self.eng, self.eng.cfg
        return replica_combine(c.execution, rows, cl, axis=eng.axis,
                               k=eng.k, ell_fn=eng._ell,
                               num_chunks=c.exchange_chunks)


BACKENDS = {
    "edge_cut": EdgeCutBackend,
    "vertex_cut": ReplicaSyncBackend,
    "hybrid": ReplicaSyncBackend,
}


def make_backend(eng) -> ExchangeBackend:
    return BACKENDS[eng.playout.family](eng)
