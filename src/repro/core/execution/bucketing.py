"""Power-of-two bucketed p2p installment layout — the pure-numpy half of
`pipeline_exchange`.

These three helpers define the STATIC slot layout of the bucketed p2p halo
exchange (installment widths, the gather-table slot of a halo row, and the
matching [k, B, k, w] send table).  They are numpy-only on purpose: the
process-pool sampling workers (`sampling/proc_prefetch.py`) build p2p fetch
plans host-side and must never import jax — a forked worker may not touch the
parent's XLA runtime, and a spawned one should not pay the import.  The jax
consumer (`bucketed_all_to_all`) stays in `pipeline_exchange`, which
re-exports these names so existing imports keep working.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def bucketed_cap_widths(cap: int, buckets: int) -> List[int]:
    """Split a max-pairwise p2p cap into equal power-of-two installment
    widths whose sum covers ``cap``.

    ``buckets`` bounds the number of installments (collective rounds); the
    width is the smallest power of two with ``width * buckets >= cap``, so
    the lowered per-round all_to_all operand shrinks ~``buckets``x while at
    most ``buckets`` rounds ship the same rows.  With ``buckets <= 1`` (or a
    cap too small to split) the plan is unchanged: ``[cap]``.
    """
    cap, buckets = int(cap), int(buckets)
    if buckets <= 1 or cap <= 1:
        return [max(cap, 1)]
    w = 1
    while w * buckets < cap:
        w *= 2
    n = -(-cap // w)
    if n <= 1:
        return [cap]
    return [w] * n


def halo_slot(t, s, width: int, k: int, base: int):
    """Gather-table slot of halo row ``t`` (position in a pair's need list)
    from source ``s`` under the bucketed installment layout: the receive
    table is ``concat(recv_round_0 [k*w], recv_round_1 [k*w], ...)`` appended
    after ``base`` local rows.  Vectorizes over numpy arrays ``t``/``s``;
    with a single installment (w == cap) this is the classic
    ``base + s*cap + t`` layout."""
    b = t // width
    return base + b * (k * width) + s * width + (t % width)


def bucketed_send_table(need: Sequence[Sequence[np.ndarray]], k: int,
                        widths: List[int]) -> np.ndarray:
    """[k, B, k, w] send table from per-(src, dst) need lists under the
    power-of-two installment layout: pair (s, d)'s rows t land in installment
    t // w at offset t % w — the write side matching `halo_slot`'s read side.
    ``need[s][d]`` lists the local row ids source s ships to destination d."""
    B, w = len(widths), widths[0]
    send = np.zeros((k, k, B * w), np.int32)
    for s in range(k):
        for d in range(k):
            send[s, d, : len(need[s][d])] = need[s][d]
    return send.reshape(k, k, B, w).transpose(0, 2, 1, 3).copy()
