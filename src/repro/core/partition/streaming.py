"""Streaming partition ingest (survey §4.2 at data-loading scale): build the
engine's per-device layout from a CHUNKED edge stream instead of a resident
CSR graph.

The in-memory path (`DistGNNEngine._build_layout`) walks a fully
materialized `Graph` — fine for benchmark graphs, a non-starter when |E|
dwarfs host RAM.  Real systems (DGL's ``data_shuffle``) ingest the edge list
in chunks, shuffle each chunk to the partition that OWNS its destination,
and grow per-device structures incrementally; peak host memory is
O(E/chunks + per-device layout), never O(E).

`build_streaming_layout` reproduces that shape in two passes over a
re-iterable chunk stream:

  pass 1  per-destination degree histogram -> the global ELL width K
          (plus per-part sizes -> nb, Vp, and the contiguous relabeling,
          exactly as the in-memory builder derives them);
  pass 2  owner shuffle: each chunk's edges are stably grouped by the
          owner of their destination and scattered into that device's ELL
          block at per-vertex slot cursors.  A STABLE grouping preserves
          within-destination edge order, so a stream in edge-list order
          yields bit-identical rows to `from_edges` + `_build_layout`
          (whose CSR is a stable sort by destination of the same list).

The result is asserted identical — array for array — to the in-memory
build by tests/test_streaming_partition.py, and `peak_transient_bytes`
makes the memory claim checkable: the builder self-reports the largest
per-chunk transient footprint, which depends on ``chunk_edges`` only.

Vertex-plane inputs (features/labels/masks) are O(V) and land inside the
per-device layout anyway; they arrive as arrays, not through the stream —
the stream carries what actually scales, the edges.

numpy-only on purpose: ingest runs host-side (loader processes), never on
device, mirroring `sampling/host_batch.py`'s jax-free discipline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import Graph


class GraphEdgeChunks:
    """Re-iterable chunked edge stream over a CSR `Graph` (the test/demo
    source): yields (src, dst) int64 pairs in CSR order — which for a
    `from_edges` graph is a stable-by-destination ordering of the original
    edge list, the order the equality contract wants.  Each chunk holds at
    most ``chunk_edges`` edges; nothing references the full edge list."""

    def __init__(self, g: Graph, chunk_edges: int):
        if chunk_edges < 1:
            raise ValueError(f"chunk_edges must be >= 1, got {chunk_edges}")
        self._g = g
        self.chunk_edges = int(chunk_edges)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        g, step = self._g, self.chunk_edges
        indptr = np.asarray(g.indptr)
        E = int(indptr[-1])
        for lo in range(0, E, step):
            hi = min(lo + step, E)
            src = np.asarray(g.indices[lo:hi], np.int64)
            # destinations of CSR positions [lo, hi): dst v covers
            # [indptr[v], indptr[v+1]) — recovered per chunk via searchsorted
            # on the O(V) indptr, no O(E) expansion
            dst = np.searchsorted(indptr, np.arange(lo, hi), side="right") - 1
            yield src, dst.astype(np.int64)


@dataclasses.dataclass
class StreamingLayout:
    """The edge-cut device layout, as numpy (the jnp lift is the engine's
    business), plus the ingest's self-reported memory accounting."""

    k: int
    nb: int          # padded per-device block size
    Vp: int          # k * nb
    K: int           # global ELL width (max in-degree)
    new_of_old: np.ndarray   # [V] int64 relabeling, owner*nb + slot
    ids: np.ndarray          # [Vp, K] int64 in-neighbor ids, pad = Vp
    mask: np.ndarray         # [Vp, K] float32 slot validity
    deg: np.ndarray          # [Vp, 1] float32 max(valid slots, 1)
    X: np.ndarray            # [k, nb, D] float32 owner-sharded features
    y: np.ndarray            # [Vp] int32
    train_w: np.ndarray      # [Vp] float32
    test_w: np.ndarray       # [Vp] float32
    emb_touched: np.ndarray  # [Vp] float32: 1 on real (non-pad) rows
    bmask: np.ndarray        # [Vp] bool: rows read by >= 1 remote partition
    peak_transient_bytes: int  # largest per-chunk transient footprint
    layout_bytes: int          # persistent output footprint (the arrays above)


def _chunk_transient_bytes(*arrays: np.ndarray) -> int:
    return int(sum(a.nbytes for a in arrays))


def build_streaming_layout(stream: Iterable[Tuple[np.ndarray, np.ndarray]],
                           assignment: np.ndarray, k: int, num_vertices: int,
                           *, features: np.ndarray, labels: np.ndarray,
                           train_mask: Optional[np.ndarray] = None,
                           test_mask: Optional[np.ndarray] = None
                           ) -> StreamingLayout:
    """Two-pass chunked ingest -> owner shuffle -> incremental ELL layout.

    ``stream`` must be RE-ITERABLE (two passes) and yield (src, dst) edge
    chunks meaning "src is an in-neighbor of dst", in a fixed order; within
    each destination that order becomes the ELL slot order, so a stream in
    edge-list order reproduces the in-memory `from_edges` build exactly.
    """
    V = int(num_vertices)
    assignment = np.asarray(assignment, np.int32)
    if assignment.shape != (V,):
        raise ValueError(f"assignment must be [V]={V}, got {assignment.shape}")
    peak = 0

    # ---- pass 1: degree histogram (O(V) state, one chunk resident) -------
    deg_v = np.zeros(V, np.int64)
    for src, dst in stream:
        np.add.at(deg_v, dst, 1)
        peak = max(peak, _chunk_transient_bytes(src, dst))
    K = max(int(deg_v.max(initial=0)), 1)

    # ---- relabeling, exactly as the in-memory builder ---------------------
    sizes = np.bincount(assignment, minlength=k)
    nb = max(int(sizes.max(initial=0)), 1)
    Vp = k * nb
    new_of_old = np.full(V, -1, np.int64)
    for p in range(k):
        olds = np.where(assignment == p)[0]
        new_of_old[olds] = p * nb + np.arange(len(olds))

    # ---- vertex plane: O(V) scatter into the owner-sharded blocks ---------
    features = np.asarray(features, np.float32)
    D = features.shape[1]
    X = np.zeros((Vp, D), np.float32)
    y = np.zeros((Vp,), np.int32)
    train_w = np.zeros((Vp,), np.float32)
    test_w = np.zeros((Vp,), np.float32)
    olds = np.arange(V)
    X[new_of_old[olds]] = features[olds]
    y[new_of_old[olds]] = np.asarray(labels)[olds]
    if train_mask is not None:
        train_w[new_of_old[olds]] = np.asarray(train_mask)[olds].astype(
            np.float32)
    if test_mask is not None:
        test_w[new_of_old[olds]] = np.asarray(test_mask)[olds].astype(
            np.float32)
    emb_touched = np.zeros((Vp,), np.float32)
    emb_touched[new_of_old[olds]] = 1.0

    # ---- pass 2: owner shuffle + incremental ELL scatter ------------------
    ids = np.full((Vp, K), Vp, np.int64)
    mask = np.zeros((Vp, K), np.float32)
    bmask = np.zeros((Vp,), bool)
    cursor = np.zeros(Vp, np.int64)  # next free ELL slot per new dst id
    for src, dst in stream:
        new_src = new_of_old[src]
        new_dst = new_of_old[dst]
        owner = assignment[dst]
        # owner shuffle: stable grouping by owning device — the chunk's
        # edges routed to each device's builder, within-dst order intact
        route = np.argsort(owner, kind="stable")
        s_r, d_r, o_r = new_src[route], new_dst[route], owner[route]
        # slot index per routed edge: cursor[dst] + rank of the edge among
        # its dst's edges within this routed chunk (cumcount via sorted dst)
        order = np.argsort(d_r, kind="stable")
        d_sorted = d_r[order]
        run_start = np.r_[0, np.flatnonzero(np.diff(d_sorted)) + 1]
        within = np.arange(len(d_sorted)) - np.repeat(
            run_start, np.diff(np.r_[run_start, len(d_sorted)]))
        slot = np.empty(len(d_r), np.int64)
        slot[order] = cursor[d_sorted] + within
        ids[d_r, slot] = s_r
        mask[d_r, slot] = 1.0
        np.add.at(cursor, d_r, 1)
        # boundary marking rides the same shuffle: an edge whose source
        # lives on a different device than its destination's owner makes
        # the source a halo row
        remote = (s_r // nb) != o_r
        bmask[s_r[remote]] = True
        peak = max(peak, _chunk_transient_bytes(
            src, dst, new_src, new_dst, owner, route, s_r, d_r, o_r, order,
            d_sorted, within, slot, np.empty(0)) + remote.nbytes)
    deg = np.maximum(mask.sum(1, keepdims=True), 1.0).astype(np.float32)

    layout = StreamingLayout(
        k=k, nb=nb, Vp=Vp, K=K, new_of_old=new_of_old, ids=ids, mask=mask,
        deg=deg, X=X.reshape(k, nb, D), y=y, train_w=train_w, test_w=test_w,
        emb_touched=emb_touched, bmask=bmask, peak_transient_bytes=peak,
        layout_bytes=0)
    layout.layout_bytes = int(sum(
        getattr(layout, f.name).nbytes
        for f in dataclasses.fields(layout)
        if isinstance(getattr(layout, f.name), np.ndarray)))
    return layout
