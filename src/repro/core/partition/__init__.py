from repro.core.partition.cost_models import (
    OperatorCostModel,
    RocCostModel,
    bgl_score,
    bytegnn_score,
    edge_cut_halo_bytes_per_step,
    flexgraph_cost,
    pagraph_score,
    replica_sync_bytes_per_step,
)
from repro.core.partition.edge_cut import (
    PARTITIONERS,
    Partition,
    block_partition,
    hash_partition,
    ldg_partition,
    metis_like_partition,
    range_partition,
    range_partition_by_cost,
)
from repro.core.partition.feature_partition import (
    FeatureShards,
    column_partition,
    replicated,
    row_partition,
    row_partition_with_halo,
    twod_partition,
)
from repro.core.partition.vertex_cut import (
    VERTEX_CUTS,
    VertexCut,
    cartesian_2d_vertex_cut,
    edge_endpoints,
    grid_for,
    libra_vertex_cut,
    random_vertex_cut,
)
from repro.core.partition.vertex_layout import (
    VertexCutLayout,
    build_vertex_layout,
)
