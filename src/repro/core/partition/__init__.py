from repro.core.partition.cost_models import (
    OperatorCostModel,
    RocCostModel,
    bgl_score,
    bytegnn_score,
    flexgraph_cost,
    pagraph_score,
)
from repro.core.partition.edge_cut import (
    PARTITIONERS,
    Partition,
    block_partition,
    hash_partition,
    ldg_partition,
    metis_like_partition,
    range_partition,
    range_partition_by_cost,
)
from repro.core.partition.feature_partition import (
    FeatureShards,
    column_partition,
    replicated,
    row_partition,
    row_partition_with_halo,
    twod_partition,
)
from repro.core.partition.vertex_cut import (
    VertexCut,
    cartesian_2d_vertex_cut,
    libra_vertex_cut,
    random_vertex_cut,
)
