"""Edge-cut graph partitioners (survey §4.2): hash, range, LDG streaming with
GNN affinity scores, block-based (multi-source-BFS coarsening + greedy), and a
METIS-like multilevel partitioner with boundary refinement.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.cost_models import bgl_score, bytegnn_score, pagraph_score


@dataclasses.dataclass
class Partition:
    assignment: np.ndarray  # [V] int32 partition id
    num_parts: int

    def parts(self) -> List[np.ndarray]:
        return [np.where(self.assignment == i)[0] for i in range(self.num_parts)]

    # -- quality metrics (survey challenges #1/#3) --------------------------
    def edge_cut_fraction(self, g: Graph) -> float:
        cut = 0
        for v in range(g.num_vertices):
            pv = self.assignment[v]
            nb = g.neighbors(v)
            cut += int((self.assignment[nb] != pv).sum())
        return cut / max(g.num_edges, 1)

    def vertex_balance(self) -> float:
        sizes = np.bincount(self.assignment, minlength=self.num_parts)
        return float(sizes.max() / max(sizes.mean(), 1e-9))

    def train_balance(self, g: Graph) -> float:
        if g.train_mask is None:
            return 1.0
        counts = np.bincount(self.assignment[g.train_mask], minlength=self.num_parts)
        return float(counts.max() / max(counts.mean(), 1e-9))

    def boundary_vertices(self, g: Graph, part: int) -> np.ndarray:
        """Remote in-neighbors needed by `part` (communication volume proxy)."""
        mine = np.where(self.assignment == part)[0]
        remote = set()
        for v in mine:
            for u in g.neighbors(v):
                if self.assignment[u] != part:
                    remote.add(int(u))
        return np.asarray(sorted(remote), np.int64)

    def communication_volume(self, g: Graph) -> int:
        return sum(len(self.boundary_vertices(g, i)) for i in range(self.num_parts))


def hash_partition(g: Graph, k: int, seed: int = 0) -> Partition:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_vertices)
    return Partition((perm % k).astype(np.int32), k)


def range_partition(g: Graph, k: int) -> Partition:
    """ROC-style contiguous ranges (consecutively-numbered vertices)."""
    bounds = np.linspace(0, g.num_vertices, k + 1).astype(np.int64)
    a = np.zeros(g.num_vertices, np.int32)
    for i in range(k):
        a[bounds[i] : bounds[i + 1]] = i
    return Partition(a, k)


def range_partition_by_cost(g: Graph, k: int, vertex_cost: np.ndarray) -> Partition:
    """ROC: contiguous ranges balanced by a cost model's per-vertex cost."""
    c = np.cumsum(vertex_cost)
    total = c[-1]
    a = np.minimum((c / total * k).astype(np.int32), k - 1)
    return Partition(a, k)


def ldg_partition(g: Graph, k: int, score: str = "ldg", slack: float = 1.1,
                  seed: int = 0) -> Partition:
    """Linear Deterministic Greedy streaming partition [Stanton & Kliot],
    optionally with the GNN affinity scores of Eq. 3 ('pagraph')."""
    rng = np.random.default_rng(seed)
    V = g.num_vertices
    cap = slack * V / k
    assignment = np.full(V, -1, np.int32)
    part_sets: List[set] = [set() for _ in range(k)]
    train_sets: List[set] = [set() for _ in range(k)]
    sizes = np.zeros(k)
    train_mask = g.train_mask if g.train_mask is not None else np.zeros(V, bool)
    order = rng.permutation(V)
    n_train = train_mask.sum()
    for v in order:
        nb = g.neighbors(v)
        if score == "pagraph" and train_mask[v]:
            s = pagraph_score(nb, train_sets, sizes, n_train / k)
        else:  # classic LDG: |P_i ∩ N(v)| * (1 - |P_i|/cap)
            s = np.zeros(k)
            nbs = set(nb.tolist())
            for i in range(k):
                s[i] = len(part_sets[i] & nbs) * (1.0 - sizes[i] / cap)
        full = sizes >= cap
        s = np.where(full, -np.inf, s)
        if np.all(~np.isfinite(s)) or s.max() <= 0:
            i = int(np.argmin(sizes))
        else:
            i = int(np.argmax(s))
        assignment[v] = i
        part_sets[i].add(int(v))
        sizes[i] += 1
        if train_mask[v]:
            train_sets[i].add(int(v))
    return Partition(assignment, k)


def multi_source_bfs_blocks(g: Graph, num_blocks: int, seed: int = 0) -> np.ndarray:
    """Coarsen into blocks by multi-source BFS (BGL / ByteGNN §4.2)."""
    rng = np.random.default_rng(seed)
    V = g.num_vertices
    sources = rng.choice(V, size=min(num_blocks, V), replace=False)
    block = np.full(V, -1, np.int64)
    from collections import deque

    q = deque()
    for b, s in enumerate(sources):
        block[s] = b
        q.append(s)
    while q:
        v = q.popleft()
        for u in g.neighbors(v):
            if block[u] < 0:
                block[u] = block[v]
                q.append(u)
    # orphans (disconnected): round-robin
    orphans = np.where(block < 0)[0]
    block[orphans] = np.arange(len(orphans)) % max(num_blocks, 1)
    return block


def block_partition(g: Graph, k: int, *, blocks_per_part: int = 8,
                    score: str = "bgl", seed: int = 0) -> Partition:
    """Block-based streaming partition (BGL Eq. 4 / ByteGNN Eq. 5):
    multi-source BFS -> greedy block assignment -> uncoarsen."""
    nb_blocks = k * blocks_per_part
    block = multi_source_bfs_blocks(g, nb_blocks, seed)
    V = g.num_vertices
    train_mask = g.train_mask if g.train_mask is not None else np.zeros(V, bool)
    val_mask = g.val_mask if g.val_mask is not None else np.zeros(V, bool)
    test_mask = g.test_mask if g.test_mask is not None else np.zeros(V, bool)
    assignment = np.full(V, -1, np.int32)
    part_sets: List[set] = [set() for _ in range(k)]
    sizes = np.zeros(k)
    tr = np.zeros(k)
    va = np.zeros(k)
    te = np.zeros(k)
    order = np.argsort([-(block == b).sum() for b in range(nb_blocks)])
    for b in order:
        verts = np.where(block == b)[0]
        if len(verts) == 0:
            continue
        in_nbrs = np.unique(np.concatenate([g.neighbors(v) for v in verts])) if len(verts) else np.zeros(0, np.int64)
        if score == "bgl":
            s = bgl_score(in_nbrs, part_sets, sizes, tr, V / k, max(train_mask.sum() / k, 1))
        else:  # bytegnn
            cross = np.array([len(part_sets[i] & set(in_nbrs.tolist())) for i in range(k)], float)
            s = bytegnn_score(cross, sizes, tr, va, te,
                              (max(train_mask.sum() / k, 1), max(val_mask.sum() / k, 1),
                               max(test_mask.sum() / k, 1)))
        i = int(np.argmax(s)) if np.isfinite(s).any() and s.max() > 0 else int(np.argmin(sizes))
        assignment[verts] = i
        part_sets[i].update(verts.tolist())
        sizes[i] += len(verts)
        tr[i] += train_mask[verts].sum()
        va[i] += val_mask[verts].sum()
        te[i] += test_mask[verts].sum()
    return Partition(assignment, k)


# ---------------------------------------------------------------------------
# METIS-like multilevel partitioner
# ---------------------------------------------------------------------------


def _heavy_edge_matching(g: Graph, rng) -> np.ndarray:
    """Match each vertex with an unmatched neighbor; returns coarse ids."""
    V = g.num_vertices
    matched = np.full(V, -1, np.int64)
    order = rng.permutation(V)
    next_id = 0
    for v in order:
        if matched[v] >= 0:
            continue
        nb = g.neighbors(v)
        mate = -1
        for u in nb:
            if matched[u] < 0 and u != v:
                mate = int(u)
                break
        matched[v] = next_id
        if mate >= 0:
            matched[mate] = next_id
        next_id += 1
    return matched


def _coarsen(g: Graph, coarse_id: np.ndarray) -> Graph:
    Vc = int(coarse_id.max()) + 1
    src, dst = [], []
    for v in range(g.num_vertices):
        cv = coarse_id[v]
        for u in g.neighbors(v):
            cu = coarse_id[u]
            if cu != cv:
                src.append(cu)
                dst.append(cv)
    from repro.core.graph import from_edges

    return from_edges(np.asarray(src, np.int64) if src else np.zeros(0, np.int64),
                      np.asarray(dst, np.int64) if dst else np.zeros(0, np.int64), Vc)


def _refine_boundary(g: Graph, assignment: np.ndarray, k: int, passes: int = 2,
                     balance_slack: float = 1.05) -> np.ndarray:
    """FM-style single-vertex moves that reduce cut while keeping balance."""
    sizes = np.bincount(assignment, minlength=k).astype(np.int64)
    cap = balance_slack * g.num_vertices / k
    for _ in range(passes):
        moved = 0
        for v in range(g.num_vertices):
            nb = g.neighbors(v)
            if len(nb) == 0:
                continue
            counts = np.bincount(assignment[nb], minlength=k)
            cur = assignment[v]
            best = int(np.argmax(counts))
            if best != cur and counts[best] > counts[cur] and sizes[best] < cap:
                assignment[v] = best
                sizes[cur] -= 1
                sizes[best] += 1
                moved += 1
        if moved == 0:
            break
    return assignment


def metis_like_partition(g: Graph, k: int, *, coarsen_to: int = 256,
                         seed: int = 0) -> Partition:
    """Multilevel: heavy-edge matching coarsening -> LDG on the coarse graph ->
    uncoarsen with FM refinement at each level."""
    rng = np.random.default_rng(seed)
    graphs = [g]
    maps = []
    while graphs[-1].num_vertices > max(coarsen_to, 4 * k):
        cid = _heavy_edge_matching(graphs[-1], rng)
        if cid.max() + 1 >= graphs[-1].num_vertices:  # no progress
            break
        maps.append(cid)
        graphs.append(_coarsen(graphs[-1], cid))
    part = ldg_partition(graphs[-1], k, seed=seed)
    assignment = part.assignment
    for cid, fine_g in zip(reversed(maps), reversed(graphs[:-1])):
        assignment = assignment[cid]
        assignment = _refine_boundary(fine_g, assignment.copy(), k)
    return Partition(assignment.astype(np.int32), k)


PARTITIONERS: Dict[str, Callable] = {
    "hash": hash_partition,
    "range": lambda g, k, **kw: range_partition(g, k),
    "ldg": ldg_partition,
    "pagraph": lambda g, k, **kw: ldg_partition(g, k, score="pagraph", **kw),
    "block": block_partition,
    "bytegnn": lambda g, k, **kw: block_partition(g, k, score="bytegnn", **kw),
    "metis_like": metis_like_partition,
}
