"""Static padded device layout for vertex-cut execution (survey §4.2): the
dual of the engine's edge-cut layout.  Edges are partitioned; every endpoint
of a device's owned edges (plus each vertex's master replica) becomes a
replica SLOT on that device, and the owned edges become a device-local ELL
block whose columns index those slots.

The layout is fully static: ``k`` devices each hold ``nv`` padded slots, so
the flattened replica space ``[k * nv]`` plays exactly the role the padded
vertex space ``[k * nb]`` plays for edge-cut — state (historical embeddings),
labels/weights and the jitted shard_map step all shard its leading axis.

Key invariants (relied on by ``execution/replica_sync.py`` and the engine):
  * every vertex is present on its master partition (forced, even if the
    master owns none of its edges) — so the loss over master slots covers
    every train vertex exactly once, and the p2p scatter phase always has a
    combining site;
  * slots are sorted by global vertex id per device (with
    ``sorted_masters=True``, master slots come first as a contiguous prefix,
    each group still ascending — master-masked ops can then SLICE
    ``[:master_counts[d]]`` instead of scanning a boolean mask) — layout is
    a pure function of (graph, cut, sorted_masters), so reruns are bitwise
    deterministic;
  * pad slots (``vert_ids == V``) have no owned edges, zero features and
    zero weights, and are never referenced by any gather table.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.vertex_cut import VertexCut, edge_endpoints


@dataclasses.dataclass
class VertexCutLayout:
    k: int    # devices / partitions
    nv: int   # padded replica slots per device
    Kc: int   # ELL width: max owned in-edges of any (device, dst slot)
    Rm: int   # max replicas of any vertex (incl. the forced master)
    vert_ids: np.ndarray    # [k, nv] int64 global vertex per slot, pad = V
    slot_of: np.ndarray     # [k, V] int64 slot of vertex on device, -1 absent
    master_mask: np.ndarray  # [k, nv] f32 — 1 on the master replica slot
    rep_count: np.ndarray   # [V] replicas per vertex (incl. forced master)
    ids_owned: np.ndarray   # [k, nv, Kc] int32 local src slot, pad = nv
    mask_owned: np.ndarray  # [k, nv, Kc] f32
    deg: np.ndarray         # [k, nv, 1] f32 GLOBAL in-degree (>= 1)
    bmask: np.ndarray       # [k, nv] bool — replicated (rep_count > 1) slots
    X: np.ndarray           # [k, nv, D] f32 replica features
    y: np.ndarray           # [k, nv] int32
    train_w: np.ndarray     # [k, nv] f32 — master & train only
    test_w: np.ndarray      # [k, nv] f32 — master & test only
    sorted_masters: bool = False  # masters are the per-device slot prefix?
    master_counts: np.ndarray = None  # [k] masters per device (always set)

    def replication_factor(self) -> float:
        appears = self.rep_count
        return float(appears[appears > 0].mean()) if (appears > 0).any() else 0.0


def build_vertex_layout(g: Graph, vc: VertexCut, k: int,
                        sorted_masters: bool = False) -> VertexCutLayout:
    """Turn a VertexCut into the static padded device layout above."""
    V = g.num_vertices
    src, dst = edge_endpoints(g)
    owner = vc.edge_owner.astype(np.int64)
    masters = vc.masters.astype(np.int64)
    # presence set: endpoints of owned edges ∪ forced master replicas
    keys = np.unique(np.concatenate([
        owner * V + dst, owner * V + src,
        masters * V + np.arange(V, dtype=np.int64)]))
    part_of, vid = keys // V, keys % V
    rep_count = np.bincount(vid, minlength=V)
    sizes = np.bincount(part_of, minlength=k)
    nv = max(int(sizes.max()), 1)
    vert_ids = np.full((k, nv), V, np.int64)
    slot_of = np.full((k, V), -1, np.int64)
    master_counts = np.zeros(k, np.int64)
    for d in range(k):
        vs = vid[part_of == d]  # sorted ascending (keys are sorted)
        is_m = masters[vs] == d
        master_counts[d] = int(is_m.sum())
        if sorted_masters:
            # masters first (each group keeps its ascending-vid order) so
            # master reads are the contiguous prefix [:master_counts[d]]
            vs = np.concatenate([vs[is_m], vs[~is_m]])
        vert_ids[d, : len(vs)] = vs
        slot_of[d, vs] = np.arange(len(vs))
    # owned-edge ELL: row = dst slot, col = src slot, both on the owner device
    dslot = slot_of[owner, dst]
    sslot = slot_of[owner, src]
    cnt = np.zeros((k, nv), np.int64)
    np.add.at(cnt, (owner, dslot), 1)
    Kc = max(int(cnt.max()), 1)
    ids_owned = np.full((k, nv, Kc), nv, np.int32)
    mask_owned = np.zeros((k, nv, Kc), np.float32)
    if len(owner):
        grp = owner * nv + dslot
        order = np.argsort(grp, kind="stable")
        gs = grp[order]
        run_id = np.cumsum(np.r_[0, (np.diff(gs) != 0).astype(np.int64)])
        first = np.r_[0, np.flatnonzero(np.diff(gs)) + 1]
        pos = np.arange(len(gs)) - first[run_id]
        ids_owned[owner[order], dslot[order], pos] = sslot[order]
        mask_owned[owner[order], dslot[order], pos] = 1.0
    # per-slot tables (global degree so combine-then-normalize matches the
    # full-graph math; pad slots get degree 1 / zero everything)
    deg_g = np.maximum(g.degree(), 1).astype(np.float32)
    present = vert_ids < V
    safe = np.minimum(vert_ids, V - 1)
    deg = np.where(present, deg_g[safe], 1.0)[..., None].astype(np.float32)
    master_mask = (present & (masters[safe] == np.arange(k)[:, None])
                   ).astype(np.float32)
    bmask = present & (rep_count[safe] > 1)
    D = g.features.shape[1]
    X = np.where(present[..., None], g.features[safe], 0.0).astype(np.float32)
    y = np.where(present, g.labels[safe], 0).astype(np.int32)
    train = (g.train_mask[safe] if g.train_mask is not None
             else np.zeros((k, nv), bool))
    test = (g.test_mask[safe] if g.test_mask is not None
            else np.zeros((k, nv), bool))
    train_w = (master_mask * np.where(present, train, False)).astype(np.float32)
    test_w = (master_mask * np.where(present, test, False)).astype(np.float32)
    return VertexCutLayout(
        k=k, nv=nv, Kc=Kc, Rm=max(int(rep_count.max()), 1),
        vert_ids=vert_ids, slot_of=slot_of, master_mask=master_mask,
        rep_count=rep_count, ids_owned=ids_owned, mask_owned=mask_owned,
        deg=deg, bmask=bmask, X=X, y=y, train_w=train_w, test_w=test_w,
        sorted_masters=sorted_masters, master_counts=master_counts)
