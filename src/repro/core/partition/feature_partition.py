"""Feature partitioning (survey §4.3): row-wise (with the graph), column-wise
(P3 / GIST), replicated, and 2D — plus replication of boundary features
(DistDGL's one-hop replication cache).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.edge_cut import Partition


@dataclasses.dataclass
class FeatureShards:
    kind: str  # row | column | replicated | twod
    shards: List[np.ndarray]
    index_maps: Optional[List[np.ndarray]] = None  # row ids per shard (row kind)

    def total_bytes(self) -> int:
        return sum(s.nbytes for s in self.shards)


def row_partition(g: Graph, part: Partition) -> FeatureShards:
    """Each vertex's feature lives with its vertex (the default everywhere)."""
    shards, idx = [], []
    for i in range(part.num_parts):
        rows = np.where(part.assignment == i)[0]
        shards.append(g.features[rows])
        idx.append(rows)
    return FeatureShards("row", shards, idx)


def row_partition_with_halo(g: Graph, part: Partition) -> FeatureShards:
    """DistDGL: replicate one-hop boundary features so samplers stay local."""
    shards, idx = [], []
    for i in range(part.num_parts):
        rows = np.where(part.assignment == i)[0]
        halo = part.boundary_vertices(g, i)
        all_rows = np.concatenate([rows, halo]) if len(halo) else rows
        shards.append(g.features[all_rows])
        idx.append(all_rows)
    return FeatureShards("row", shards, idx)


def column_partition(g: Graph, k: int) -> FeatureShards:
    """P3: every partition holds a feature-column slice of ALL vertices —
    first-layer aggregation runs model-parallel on the column slice."""
    cols = np.array_split(np.arange(g.features.shape[1]), k)
    return FeatureShards("column", [g.features[:, c] for c in cols])


def replicated(g: Graph, k: int) -> FeatureShards:
    return FeatureShards("replicated", [g.features] * k)


def twod_partition(g: Graph, rows: int, cols: int) -> FeatureShards:
    rblocks = np.array_split(np.arange(g.num_vertices), rows)
    cblocks = np.array_split(np.arange(g.features.shape[1]), cols)
    shards = [g.features[np.ix_(r, c)] for r in rblocks for c in cblocks]
    return FeatureShards("twod", shards)
