"""PartitionLayout: the partition-family interface (survey §4.2 made a
first-class axis).

A *layout* owns everything a partition family decides about how a graph
lands on k devices — the engine only dispatches:

  * the slot tables (who owns which padded row, how vertices relabel or
    replicate) and the local-multiply ELL constants (`ids`/`mask`/`deg`);
  * the exchange-plan constants the execution model needs (`send_rows`,
    replica-sync tables, halo tables) via `exchange_consts()` — the engine
    derives every shard spec generically (`P(ax, None, ...)` from ndim) and
    squeezes the leading device axis off the keys named in `squeeze_keys`;
  * master masking for loss/grads (`train_w`/`test_w`/`emb_touched` are
    built HERE, already masked);
  * the reference-oracle combine: `ref_vert_ids` is None for families whose
    padded rows are globally unique, else the [k, n] global-vertex table the
    oracle scatter-adds partials over (replica families);
  * per-step byte accounting (`wire_fields_per_step`, `embed_grad_bytes`,
    `device_bytes_per_step`), telemetry gauges, and the host-side mapping
    back to original vertex ids (`global_embeddings`).

Extension policy — what a FOURTH family must implement
------------------------------------------------------
1. Subclass `PartitionLayout` (or `ReplicaLayoutBase` if the family keeps
   replica slot tables), set `family`, and implement `_build` to populate
   the engine-facing attributes listed in `ENGINE_MIRROR_ATTRS` that apply
   (at minimum: nb, Vp, K, ids_exec, ids_global, mask, deg, store, X,
   emb_touched, y, train_w, test_w, bmask).
2. Implement `exchange_consts()` (must include "ids" and "mask") and set
   `squeeze_keys` to the const keys whose LEADING axis is the device axis
   of stacked per-device tables (they arrive [1, ...] under shard_map and
   are squeezed); leading-[Vp] consts shard naturally and are not listed.
3. Implement the accounting quartet (`wire_fields_per_step` names which
   CommStats fields the family accrues per full-graph step — the engine
   adds exactly these, so the cost-model cross-check tests stay exact),
   `telemetry_gauges`, and `global_embeddings`.
4. Pick an execution backend in `execution/exchange_api.py` (edge-cut halo
   vs replica-sync GAS — or compose both, as the hybrid family does, via
   the `sync_active`/`halo_active` flags `ReplicaSyncBackend` reads).
5. Register the class in `LAYOUT_BUILDERS` and add the family string to
   `engine.PARTITION_FAMILIES`; the oracle tiers then apply unchanged
   (`ref_vert_ids` drives the reference combine automatically).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.execution.pipeline_exchange import (
    bucketed_cap_widths,
    bucketed_send_table,
    halo_slot,
)
from repro.core.execution.replica_sync import build_replica_sync_plan
from repro.core.feature_store import FeatureStore
from repro.core.partition.cost_models import (
    FEAT_BYTES,
    edge_cut_halo_device_bytes,
    model_exchange_widths,
    replica_sync_device_bytes,
)
from repro.core.partition.edge_cut import PARTITIONERS
from repro.core.partition.vertex_cut import VERTEX_CUTS
from repro.core.partition.vertex_layout import build_vertex_layout

# Engine attributes a layout may provide; DistGNNEngine mirrors every one
# that exists (hasattr) so downstream code (mini-batch planner, dryrun
# drivers, the streaming-partition equality tier) keeps reading eng.<attr>.
ENGINE_MIRROR_ATTRS = (
    "part", "new_of_old", "vcut", "layout", "nb", "nv", "Vp", "K",
    "ids_global", "mask", "mask_exec", "deg", "store", "X", "emb_touched",
    "y", "train_w", "test_w", "bmask", "ids_exec", "cap", "p2p_widths",
    "send_rows", "_halo_rows", "_vc_rows_per_layer", "_vc_p2p_caps",
    "_vc_plan",
)


class PartitionLayout:
    """Base class — see the module docstring for the extension policy."""

    family = "abstract"
    has_replicas = False          # replica slot tables + master masking?
    supports_minibatch = False    # §5 sampled batching available?
    ref_vert_ids = None           # [k, n] np global-vertex table (pad = V)
    #   for the oracle's scatter-add replica combine; None = rows unique
    squeeze_keys: tuple = ()      # exchange consts to squeeze [0] under map

    def __init__(self, g, k: int, cfg, partition=None):
        self.g = g
        self.k = k
        self.cfg = cfg
        self._build(partition)

    @classmethod
    def validate(cls, cfg, partition=None) -> None:
        """Raise ValueError for configs this family cannot run."""

    def _build(self, partition) -> None:
        raise NotImplementedError

    def exchange_consts(self) -> dict:
        """Static jnp constants the device-local exchange reads (always
        includes "ids" and "mask"; plan extras ride alongside)."""
        raise NotImplementedError

    def wire_fields_per_step(self, model: str, dims) -> dict:
        """CommStats field name -> wire bytes ONE full-graph train step
        accrues on that field.  The engine adds exactly these per step (and
        their sum per inference sweep), so each entry must mirror the
        standalone cost model for this family bit for bit."""
        raise NotImplementedError

    def embed_grad_bytes(self, dims) -> int:
        """Wire bytes/step for routing layer-0 embedding gradients home
        (trainable_features) — the transpose of one width-dims[0] pass."""
        raise NotImplementedError

    def device_bytes_per_step(self, model: str, dims) -> np.ndarray:
        """[k] per-device bytes/step, both directions — max() is the
        critical-path volume the autotuner minimizes."""
        raise NotImplementedError

    def telemetry_gauges(self, tel) -> None:
        """Seed per-device static layout gauges for the imbalance report."""
        raise NotImplementedError

    def global_embeddings(self, H: np.ndarray) -> np.ndarray:
        """Map padded per-slot rows [Vp, D] back to original ids [V, D]."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# edge_cut: a partitioner assigns VERTICES; contiguous relabeled blocks +
# halo exchange (the neighbor rows cross the wire)
# ---------------------------------------------------------------------------


class EdgeCutLayout(PartitionLayout):
    family = "edge_cut"
    supports_minibatch = True

    def _build(self, partition):
        self.part = (partition
                     or PARTITIONERS[self.cfg.partitioner](self.g, self.k))
        self._build_vertex_blocks()
        self._build_exchange_plan()
        if self.cfg.execution == "ring":
            self.squeeze_keys = ("ids", "mask")
        elif self.cfg.execution == "p2p":
            self.squeeze_keys = ("send_rows",)

    def _build_vertex_blocks(self):
        """Relabel vertices so partition p owns global rows [p*nb, (p+1)*nb).
        Pad slots are dead: no edges, zero features/weights."""
        g, k = self.g, self.k
        assign = self.part.assignment
        sizes = np.bincount(assign, minlength=k)
        self.nb = nb = max(int(sizes.max()), 1)
        self.Vp = Vp = k * nb
        old_by_part = [np.where(assign == p)[0] for p in range(k)]
        new_of_old = np.full(g.num_vertices, -1, np.int64)
        for p, olds in enumerate(old_by_part):
            new_of_old[olds] = p * nb + np.arange(len(olds))
        self.new_of_old = new_of_old
        D = g.features.shape[1]
        X = np.zeros((Vp, D), np.float32)
        y = np.zeros((Vp,), np.int32)
        train_w = np.zeros((Vp,), np.float32)
        test_w = np.zeros((Vp,), np.float32)
        olds = np.arange(g.num_vertices)
        X[new_of_old[olds]] = g.features[olds]
        y[new_of_old[olds]] = g.labels[olds]
        if g.train_mask is not None:
            train_w[new_of_old[olds]] = g.train_mask[olds].astype(np.float32)
        if g.test_mask is not None:
            test_w[new_of_old[olds]] = g.test_mask[olds].astype(np.float32)
        # ELL adjacency in new ids; pad id = Vp (zero row in gather tables)
        deg = g.degree()
        self.K = K = max(int(deg.max()), 1)
        ids = np.full((Vp, K), Vp, np.int64)
        mask = np.zeros((Vp, K), np.float32)
        for old_v in range(g.num_vertices):
            v = new_of_old[old_v]
            nbs = new_of_old[g.neighbors(old_v)]
            ids[v, : len(nbs)] = nbs
            mask[v, : len(nbs)] = 1.0
        self.ids_global = ids
        self.mask = jnp.asarray(mask)
        degp = np.maximum(mask.sum(1, keepdims=True), 1.0).astype(np.float32)
        self.deg = jnp.asarray(degp)
        # the feature plane lives in an owner-partitioned store: flat store
        # id == the relabeled vertex id (owner * nb + slot), so the exchange
        # plans move store rows without any translation
        self.store = FeatureStore(X.reshape(k, nb, D))
        self.X = self.store.device_table()
        # full-graph touched set for trainable embeddings: every REAL owned
        # row is in the batch (pads stay untouched forever)
        real = np.zeros((Vp,), np.float32)
        real[new_of_old[olds]] = 1.0
        self.emb_touched = real
        self.y = jnp.asarray(y)
        self.train_w = jnp.asarray(train_w)
        self.test_w = jnp.asarray(test_w)
        # boundary: rows read by at least one remote partition
        owner = ids // nb  # partition of each neighbor (pad -> k)
        bmask = np.zeros((Vp,), bool)
        row_part = np.repeat(np.arange(self.k), nb)
        remote = (mask > 0) & (owner != row_part[:, None])
        src = ids[remote]
        bmask[src[src < Vp]] = True
        self.bmask = jnp.asarray(bmask)

    def _build_exchange_plan(self):
        """Execution-model-specific static arrays (the §7 protocol plan)."""
        k, nb, Vp, K = self.k, self.nb, self.Vp, self.K
        ids = self.ids_global
        if self.cfg.execution == "broadcast":
            # gather table per device = all_gather(H) [Vp] + zero row at Vp
            self.ids_exec = jnp.asarray(ids.astype(np.int32))
            return
        if self.cfg.execution == "ring":
            # per (dst row, src block): neighbor ids local to the src block.
            # Pad slots carry id 0 with mask 0 — the masked ELL reduction
            # zeroes them, so the scan needs NO per-round zero-row
            # concatenate onto the rotating block.
            ids_by_src = np.zeros((Vp, k, K), np.int32)
            src_part = np.where(ids < Vp, ids // nb, -1)
            local_id = np.where(ids < Vp, ids % nb, 0)
            for s in range(k):
                sel = src_part == s  # [Vp, K]
                ids_by_src[:, s][sel] = local_id[sel]
            # reshape to [k(dev), nb, k(src), K] so P(ax) shards devices
            self.ids_exec = jnp.asarray(
                ids_by_src.reshape(k, nb, k, K).transpose(0, 2, 1, 3))
            mask_np = np.asarray(self.mask)
            mask_by_src = np.zeros((Vp, k, K), np.float32)
            for s in range(k):
                mask_by_src[:, s] = mask_np * (src_part == s)
            self.mask_exec = jnp.asarray(
                mask_by_src.reshape(k, nb, k, K).transpose(0, 2, 1, 3))
            return
        # p2p halo exchange plan: need[dst, src] = sorted local indices (within
        # src block) of src rows that dst's aggregation reads
        need_sets = [[np.zeros(0, np.int64) for _ in range(k)]
                     for _ in range(k)]
        src_part = np.where(ids < Vp, ids // nb, -1)
        local_id = np.where(ids < Vp, ids % nb, 0)
        for d in range(k):
            rows = slice(d * nb, (d + 1) * nb)
            for s in range(k):
                if s == d:
                    continue
                sel = src_part[rows] == s
                need_sets[d][s] = np.unique(local_id[rows][sel])
        cap = max(1, max((len(x) for row in need_sets for x in row),
                         default=1))
        self.cap = cap
        # true halo rows per layer-0-width pass (== part.communication_volume:
        # each need set is one partition's remote in-neighbor set) — the
        # trainable-embedding gradient transpose ships exactly these rows back
        self._halo_rows = sum(len(x) for row in need_sets for x in row)
        # power-of-two bucketed installment caps (1 bucket = the classic
        # max-pairwise-need buffer): each lowered all_to_all operand holds
        # k*w rows instead of k*cap, shipping the same rows over B rounds
        widths = bucketed_cap_widths(cap, self.cfg.p2p_buckets)
        self.p2p_widths = widths
        B, w = len(widths), widths[0]
        # send_rows[src, B, dst, w]: what each SOURCE ships per installment
        # and destination (need_sets is dst-major; the builder wants
        # src-major need[s][d])
        self.send_rows = jnp.asarray(bucketed_send_table(
            [[need_sets[d][s] for d in range(k)] for s in range(k)],
            k, widths))
        # remap ids into the local gather table:
        #   [0, nb)            own block
        #   [nb, nb + B*k*w)   halo slot (installment-major; see halo_slot)
        #   nb + B*k*w         zero row (pads + absent)
        ids_remap = np.full((Vp, K), nb + B * k * w, np.int32)
        for d in range(k):
            rows = slice(d * nb, (d + 1) * nb)
            pos_lut = {}  # (src, local_id) -> halo slot
            for s in range(k):
                for t, li in enumerate(need_sets[d][s]):
                    pos_lut[(s, int(li))] = int(halo_slot(t, s, w, k, nb))
            id_blk = ids[rows]
            sp_blk = src_part[rows]
            li_blk = local_id[rows]
            out = ids_remap[rows]
            for r in range(nb):
                for c in range(K):
                    if id_blk[r, c] >= Vp:
                        continue
                    s = sp_blk[r, c]
                    out[r, c] = (li_blk[r, c] if s == d
                                 else pos_lut[(s, int(li_blk[r, c]))])
            ids_remap[rows] = out
        self.ids_exec = jnp.asarray(ids_remap)

    # -- engine-facing interface -------------------------------------------

    def exchange_consts(self) -> dict:
        consts = dict(ids=self.ids_exec, mask=self.mask)
        if self.cfg.execution == "ring":
            consts["mask"] = self.mask_exec
        elif self.cfg.execution == "p2p":
            consts["send_rows"] = self.send_rows
        return consts

    def _halo_rows_per_pass(self) -> int:
        if self.cfg.execution in ("broadcast", "ring"):
            return self.k * (self.k - 1) * self.nb
        return self._halo_rows

    def wire_fields_per_step(self, model, dims) -> dict:
        widths = model_exchange_widths(model, dims, "edge_cut")
        return {"halo_bytes":
                self._halo_rows_per_pass() * int(sum(widths)) * FEAT_BYTES}

    def embed_grad_bytes(self, dims) -> int:
        return self._halo_rows_per_pass() * int(dims[0]) * FEAT_BYTES

    def device_bytes_per_step(self, model, dims) -> np.ndarray:
        if self.cfg.execution == "p2p":
            return edge_cut_halo_device_bytes(self.g, self.part, dims,
                                              model=model)
        widths = model_exchange_widths(model, dims, "edge_cut")
        per = 2 * (self.k - 1) * self.nb * int(sum(widths)) * FEAT_BYTES
        return np.full(self.k, per, np.int64)

    def telemetry_gauges(self, tel) -> None:
        k = self.k
        owned_v = np.bincount(self.part.assignment, minlength=k)
        owned_edges = np.asarray(self.mask).reshape(
            k, self.nb, -1).sum((1, 2))
        for d in range(k):
            tel.gauge("layout.owned_vertices", device=d).set(
                int(owned_v[d]))
            tel.gauge("layout.owned_edges", device=d).set(
                float(owned_edges[d]))

    def global_embeddings(self, H: np.ndarray) -> np.ndarray:
        return H[self.new_of_old]


# ---------------------------------------------------------------------------
# replica families: vertex_cut (and the hybrid cut, which subclasses the
# shared base in partition/hybrid_cut.py) — replica slot tables + master
# masking + the replica-sync combine
# ---------------------------------------------------------------------------


class ReplicaLayoutBase(PartitionLayout):
    """Shared engine-facing plumbing for families built on replica slot
    tables (an inner `VertexCutLayout`-shaped `self.layout` + a
    `build_replica_sync_plan` exchange plan)."""

    has_replicas = True

    def _flatten_layout(self):
        """Mirror the inner [k, nv] slot tables into the flattened replica
        space [Vp = k*nv] the engine shards, and flatten the sync plan's
        slot tables the same way."""
        lay, k = self.layout, self.k
        self.nb = self.nv = nv = lay.nv
        self.Vp = Vp = k * nv
        self.K = lay.Kc
        self.store = FeatureStore(np.asarray(lay.X, np.float32))
        self.X = self.store.device_table()
        # trainable embeddings update at MASTER slots only (replicas receive
        # the master's delta through the replica sync, so they never drift
        # and never double-update)
        self.emb_touched = np.asarray(
            lay.master_mask.reshape(Vp), np.float32)
        self.y = jnp.asarray(lay.y.reshape(Vp))
        self.train_w = jnp.asarray(lay.train_w.reshape(Vp))
        self.test_w = jnp.asarray(lay.test_w.reshape(Vp))
        self.deg = jnp.asarray(lay.deg.reshape(Vp, 1))
        self.bmask = jnp.asarray(lay.bmask.reshape(Vp))
        self.mask = jnp.asarray(lay.mask_owned.reshape(Vp, lay.Kc))
        self.ids_exec = jnp.asarray(lay.ids_owned.reshape(Vp, lay.Kc))
        self.ref_vert_ids = lay.vert_ids  # [k, nv] np, pad = V

    def _build_sync_plan(self, masters):
        c, Vp = self.cfg, self.Vp
        plan = build_replica_sync_plan(self.layout, masters, c.execution,
                                       buckets=c.p2p_buckets)
        plan.pop("execution")
        self._vc_rows_per_layer = plan.pop("rows_per_layer")
        self._vc_p2p_caps = plan.pop("caps", None)  # p2p: pre-bucket c1/c2
        self._vc_plan = {}
        slot_tables = ("rep_ids", "rep_mask", "gather_ids", "gather_mask",
                       "scatter_ids")  # [k, nv, ...] -> flatten like X/y/...
        for key, a in plan.items():
            if key in slot_tables:
                a = a.reshape((Vp,) + a.shape[2:])
            self._vc_plan[key] = jnp.asarray(a)
        self.squeeze_keys = tuple(
            key for key in ("send1", "send2", "ring_ids")
            if key in self._vc_plan)

    def exchange_consts(self) -> dict:
        return dict(ids=self.ids_exec, mask=self.mask, **self._vc_plan)

    def telemetry_gauges(self, tel) -> None:
        lay, k = self.layout, self.k
        V = self.g.num_vertices
        owned_edges = np.asarray(lay.mask_owned).reshape(k, -1).sum(1)
        replica_rows = (np.asarray(lay.vert_ids) < V).sum(1)
        masters = np.asarray(lay.master_mask).reshape(k, -1).sum(1)
        for d in range(k):
            tel.gauge("layout.owned_edges", device=d).set(
                float(owned_edges[d]))
            tel.gauge("layout.replica_rows", device=d).set(
                int(replica_rows[d]))
            tel.gauge("layout.master_rows", device=d).set(
                float(masters[d]))

    def global_embeddings(self, H: np.ndarray) -> np.ndarray:
        """Read each vertex's MASTER replica row.  With sorted_masters
        layouts the masters are a contiguous per-device prefix, so this is
        k prefix SLICES instead of a [Vp] boolean mask scan."""
        lay = self.layout
        V = self.g.num_vertices
        out = np.zeros((V, H.shape[1]), H.dtype)
        counts = getattr(lay, "master_counts", None)
        if getattr(lay, "sorted_masters", False) and counts is not None:
            for d in range(self.k):
                n = int(counts[d])
                out[lay.vert_ids[d, :n]] = H[d * self.nv: d * self.nv + n]
            return out
        flat_vid = np.asarray(lay.vert_ids).reshape(-1)  # pad slots -> V
        mm = np.asarray(lay.master_mask).reshape(-1) > 0.5
        out[flat_vid[mm]] = H[mm]
        return out


class VertexCutFamilyLayout(ReplicaLayoutBase):
    family = "vertex_cut"

    @classmethod
    def validate(cls, cfg, partition=None) -> None:
        if cfg.vertex_cut not in VERTEX_CUTS:
            raise ValueError(
                f"vertex_cut must be one of {tuple(VERTEX_CUTS)}")
        if cfg.batching != "full_graph":
            raise ValueError(
                "vertex_cut supports batching='full_graph' only "
                "(vertex-cut mini-batch sampling is a ROADMAP follow-up)")
        if partition is not None:
            raise ValueError(
                "partition= is an edge-cut Partition; vertex_cut builds "
                "its own cut from cfg.vertex_cut")

    def _build(self, partition):
        c, g, k = self.cfg, self.g, self.k
        self.vcut = VERTEX_CUTS[c.vertex_cut](g, k, seed=c.seed)
        self.layout = build_vertex_layout(
            g, self.vcut, k,
            sorted_masters=getattr(c, "sorted_masters", False))
        self._flatten_layout()
        # reference-step ELL in the flattened replica space: local slot ->
        # global flat slot d*nv + slot; pads -> Vp (the appended zero row),
        # the same pad convention as the edge-cut ids_global table
        lay, nv, Vp = self.layout, self.nv, self.Vp
        flat_off = (np.arange(k) * nv)[:, None, None]
        self.ids_global = np.where(lay.mask_owned > 0,
                                   lay.ids_owned + flat_off, Vp
                                   ).reshape(Vp, lay.Kc).astype(np.int64)
        self._build_sync_plan(self.vcut.masters)

    def wire_fields_per_step(self, model, dims) -> dict:
        # wire bytes of one distributed step: every layer's replica sync
        # ships `rows_per_layer` rows at that layer's model-dependent
        # exchange width (input width for gcn/sage/gin; transformed width
        # + attention coefficient + the max pass for gat) — the same
        # accounting as cost_models.replica_sync_bytes_per_step
        widths = model_exchange_widths(model, dims, "vertex_cut")
        return {"replica_sync_bytes":
                self._vc_rows_per_layer * int(sum(widths)) * FEAT_BYTES}

    def embed_grad_bytes(self, dims) -> int:
        # grad combine + master-delta re-broadcast: two sync passes at D0
        return 2 * self._vc_rows_per_layer * int(dims[0]) * FEAT_BYTES

    def device_bytes_per_step(self, model, dims) -> np.ndarray:
        if self.cfg.execution == "p2p":
            return replica_sync_device_bytes(self.layout, self.vcut.masters,
                                             dims, model=model)
        widths = model_exchange_widths(model, dims, "vertex_cut")
        per = 2 * (self.k - 1) * self.nv * int(sum(widths)) * FEAT_BYTES
        return np.full(self.k, per, np.int64)


LAYOUT_BUILDERS = {
    "edge_cut": EdgeCutLayout,
    "vertex_cut": VertexCutFamilyLayout,
}


def get_layout_builder(family: str):
    """Resolve a family string to its layout class.  The hybrid family
    self-registers on import (lazy, to keep partition/hybrid_cut.py free to
    import this module's base classes)."""
    if family == "hybrid" and family not in LAYOUT_BUILDERS:
        from repro.core.partition import hybrid_cut  # noqa: F401 — registers
    try:
        return LAYOUT_BUILDERS[family]
    except KeyError:
        raise ValueError(f"unknown partition family {family!r}; known: "
                         f"{tuple(LAYOUT_BUILDERS)}") from None
