"""Vertex-cut partitioning (survey §2, §4.2): edges are partitioned; vertices
replicate. Includes the 2D Cartesian vertex-cut used by CAGNET/DeepGalois and
a balance-capped Libra/PowerGraph greedy.

Edge order convention: edges are numbered in CSR order — ``for v in
range(V): for u in g.neighbors(v)`` — i.e. edge ``e`` has destination
``repeat(arange(V), deg)[e]`` and source ``g.indices[e]``.  Every function
here (and the replica layout built on top in ``vertex_layout.py``) relies on
that ordering.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.graph import Graph


def edge_endpoints(g: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """(src, dst) arrays in CSR edge order (see module docstring)."""
    dst = np.repeat(np.arange(g.num_vertices, dtype=np.int64), g.degree())
    return g.indices.astype(np.int64), dst


@dataclasses.dataclass
class VertexCut:
    edge_owner: np.ndarray  # [E] partition id per edge (CSR order)
    num_parts: int
    masters: np.ndarray  # [V] master partition per vertex

    def replica_counts(self, g: Graph, include_masters: bool = False
                       ) -> np.ndarray:
        """[V] number of partitions in which each vertex appears (as an
        endpoint of an owned edge; with ``include_masters`` also counting the
        forced master replica the execution layout materializes)."""
        V = g.num_vertices
        src, dst = edge_endpoints(g)
        owner = self.edge_owner.astype(np.int64)
        keys = [owner * V + dst, owner * V + src]
        if include_masters:
            keys.append(self.masters.astype(np.int64) * V
                        + np.arange(V, dtype=np.int64))
        uniq = np.unique(np.concatenate(keys)) if len(owner) or include_masters \
            else np.zeros(0, np.int64)
        return np.bincount(uniq % V, minlength=V)

    def replication_factor(self, g: Graph) -> float:
        """Mean number of partitions in which a vertex appears."""
        appears = self.replica_counts(g)
        return float(appears[appears > 0].mean()) if (appears > 0).any() else 0.0


def _replication_factor_loop(vc: VertexCut, g: Graph) -> float:
    """O(V·deg) Python-loop reference for ``replication_factor`` — kept as the
    oracle the vectorized version is cross-checked against in tests."""
    V = g.num_vertices
    present = np.zeros((vc.num_parts, V), bool)
    e = 0
    for v in range(V):
        for u in g.neighbors(v):
            p = vc.edge_owner[e]
            present[p, v] = True
            present[p, u] = True
            e += 1
    appears = present.sum(0)
    return float(appears[appears > 0].mean()) if (appears > 0).any() else 0.0


def random_vertex_cut(g: Graph, k: int, seed: int = 0) -> VertexCut:
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, g.num_edges).astype(np.int32)
    masters = rng.integers(0, k, g.num_vertices).astype(np.int32)
    return VertexCut(owner, k, masters)


def cartesian_2d_vertex_cut(g: Graph, rows: int, cols: int, seed: int = 0) -> VertexCut:
    """2D Cartesian: edge (u->v) owned by grid block (row(u), col(v)) — each
    vertex replicates across at most rows+cols-1 partitions (Hoang et al.);
    the master block (row(v), col(v)) sits in that same row/col cross."""
    rng = np.random.default_rng(seed)
    row_of = rng.integers(0, rows, g.num_vertices)
    col_of = rng.integers(0, cols, g.num_vertices)
    src, dst = edge_endpoints(g)
    owner = (row_of[src] * cols + col_of[dst]).astype(np.int32)
    masters = (row_of * cols + col_of).astype(np.int32)
    return VertexCut(owner, rows * cols, masters)


def libra_vertex_cut(g: Graph, k: int, seed: int = 0,
                     slack: float = 1.15) -> VertexCut:
    """Degree-aware greedy vertex-cut (Libra/PowerGraph/HDRF-style).  Per
    edge, in order: a partition already holding BOTH endpoints (no new
    replica), else one holding the LOWER-degree endpoint (HDRF rule:
    replicate the hub, keep the tail vertex local), else one holding either,
    else the globally least-loaded — always min-load within the chosen tier.
    Candidates at or above the balance cap ``slack * E / k`` are skipped,
    which bounds the owned-edge load: max_load <= slack * E / k + 1 (the
    fallback is the globally least-loaded partition, whose load is <= mean
    <= cap)."""
    V = g.num_vertices
    deg = g.degree() + g.out_degree()  # total degree: the HDRF tie-break
    loads = np.zeros(k, np.int64)
    holds = np.zeros((k, V), bool)
    cap = max(slack * g.num_edges / k, 1.0)
    owner = np.zeros(g.num_edges, np.int32)
    big = np.iinfo(np.int64).max
    e = 0
    for v in range(V):
        for u in g.neighbors(v):
            under = loads < cap
            hu, hv = holds[:, u] & under, holds[:, v] & under
            both = hu & hv
            if both.any():
                cand = both
            else:
                lo = hu if deg[u] <= deg[v] else hv  # replicate the hub
                cand = lo if lo.any() else (hu | hv)
            if cand.any():
                i = int(np.where(cand, loads, big).argmin())
            else:
                i = int(loads.argmin())
            owner[e] = i
            holds[i, u] = True
            holds[i, v] = True
            loads[i] += 1
            e += 1
    # masters: spread the replica-sync bottleneck — a master receives r(v)-1
    # partials and sends r(v)-1 aggregates per layer, so hubs mastered on one
    # partition would recreate the edge-cut hub-owner straggler.  Greedy:
    # highest-replication vertices first, each to its least-traffic-loaded
    # holding partition.
    r = holds.sum(0)
    masters = np.empty(V, np.int32)
    traffic = np.zeros(k, np.int64)
    for v in np.argsort(-r, kind="stable"):
        hs = np.flatnonzero(holds[:, v])
        if len(hs) == 0:
            masters[v] = v % k
            continue
        i = hs[np.argmin(traffic[hs])]
        masters[v] = i
        traffic[i] += max(int(r[v]) - 1, 0)
    return VertexCut(owner, k, masters)


def grid_for(k: int) -> Tuple[int, int]:
    """rows x cols = k with rows the largest divisor <= sqrt(k) — the 2D
    Cartesian grid the engine uses when only a device count is given."""
    r = max(int(np.sqrt(k)), 1)
    while k % r:
        r -= 1
    return r, k // r


VERTEX_CUTS: Dict[str, Callable] = {
    "random": random_vertex_cut,
    "cartesian2d": lambda g, k, seed=0: cartesian_2d_vertex_cut(
        g, *grid_for(k), seed=seed),
    "libra": libra_vertex_cut,
}
