"""Vertex-cut partitioning (survey §2, §4.2): edges are partitioned; vertices
replicate. Includes the 2D Cartesian vertex-cut used by CAGNET/DeepGalois.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class VertexCut:
    edge_owner: np.ndarray  # [E] partition id per edge (CSR order)
    num_parts: int
    masters: np.ndarray  # [V] master partition per vertex

    def replication_factor(self, g: Graph) -> float:
        """Mean number of partitions in which a vertex appears."""
        V = g.num_vertices
        present = np.zeros((self.num_parts, V), bool)
        e = 0
        for v in range(V):
            for u in g.neighbors(v):
                p = self.edge_owner[e]
                present[p, v] = True
                present[p, u] = True
                e += 1
        appears = present.sum(0)
        return float(appears[appears > 0].mean()) if (appears > 0).any() else 0.0


def random_vertex_cut(g: Graph, k: int, seed: int = 0) -> VertexCut:
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, g.num_edges).astype(np.int32)
    masters = rng.integers(0, k, g.num_vertices).astype(np.int32)
    return VertexCut(owner, k, masters)


def cartesian_2d_vertex_cut(g: Graph, rows: int, cols: int, seed: int = 0) -> VertexCut:
    """2D Cartesian: edge (u->v) owned by grid block (row(u), col(v)) — each
    vertex replicates across at most rows+cols-1 partitions (Hoang et al.)."""
    rng = np.random.default_rng(seed)
    row_of = rng.integers(0, rows, g.num_vertices)
    col_of = rng.integers(0, cols, g.num_vertices)
    owner = np.zeros(g.num_edges, np.int32)
    e = 0
    for v in range(g.num_vertices):
        for u in g.neighbors(v):
            owner[e] = row_of[u] * cols + col_of[v]
            e += 1
    masters = (row_of * cols + col_of).astype(np.int32)
    return VertexCut(owner, rows * cols, masters)


def libra_vertex_cut(g: Graph, k: int, seed: int = 0) -> VertexCut:
    """Degree-aware greedy vertex-cut (Libra/PowerGraph-style): assign each
    edge to the least-loaded partition among those already holding one of its
    endpoints (reduces replication of low-degree vertices)."""
    loads = np.zeros(k, np.int64)
    holds: List[set] = [set() for _ in range(k)]
    owner = np.zeros(g.num_edges, np.int32)
    e = 0
    for v in range(g.num_vertices):
        for u in g.neighbors(v):
            cands = [i for i in range(k) if (u in holds[i]) or (v in holds[i])]
            if cands:
                i = min(cands, key=lambda i: loads[i])
            else:
                i = int(np.argmin(loads))
            owner[e] = i
            holds[i].add(int(u))
            holds[i].add(int(v))
            loads[i] += 1
            e += 1
    masters = np.zeros(g.num_vertices, np.int32)
    for v in range(g.num_vertices):
        cands = [i for i in range(k) if v in holds[i]]
        masters[v] = cands[0] if cands else v % k
    return VertexCut(owner, k, masters)
