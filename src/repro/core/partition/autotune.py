"""Cost-model partition autotuner: pick (family, cut, threshold, execution,
pipeline knobs) for a graph BEFORE training, then hold the choice to account.

The survey's §4/§6 levers — edge-cut vs vertex-cut vs hybrid, the degree
threshold, the execution model, feature-chunking and p2p bucketing — trade
wire bytes against balance differently on every graph.  The repo's layouts
already carry exact per-step accounting (`PartitionLayout.wire_fields_per_step`
and `.device_bytes_per_step`, each locked to the engine's CommStats by the
oracle tiers), so the planner does not need heuristics ABOUT the cost: it
builds every candidate's real layout and reads the real numbers.

The flow:

  ``enumerate_plans``  builds one `CandidatePlan` per (family variant,
                       execution model): the candidate's ACTUAL layout is
                       constructed and its predicted step bytes / bottleneck
                       device bytes / layout-gauge balance claim recorded.
                       Pipeline knobs (exchange_chunks, p2p_buckets) come
                       from peak-buffer heuristics, not cost guesses.
  ``choose_plan``      argmin over the predictions (objective: the bottleneck
                       device's bytes, or the total).  Because every
                       candidate is scored by the SAME exact models the
                       engine accounts with, the chosen plan can never be
                       >= 1.5x worse in predicted critical-path bytes than
                       the best candidate — it IS the argmin.
  ``validate_plan``    the trust-but-verify stage: run a short traced dryrun
                       (telemetry enabled), compare the MEASURED comm.*
                       counter totals against ``steps * predicted`` and the
                       measured layout-imbalance gauges against the plan's
                       balance claim, and raise `PlanRejected` if either
                       drifts past the bound — a plan whose accounting no
                       longer matches reality must not be acted on.
  ``autotune``         enumerate -> choose -> (optionally) validate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.layout_api import get_layout_builder
from repro.core.telemetry import Telemetry


class PlanRejected(RuntimeError):
    """A validated dryrun disagreed with the plan's predictions."""


def graph_stats(g: Graph) -> Dict:
    """The degree-profile summary the planner (and its report) keys off."""
    deg = g.degree().astype(np.float64)
    if len(deg) == 0:
        return dict(num_vertices=0, num_edges=0, avg_degree=0.0,
                    max_degree=0.0, p90=0.0, p95=0.0, p99=0.0)
    return dict(
        num_vertices=int(g.num_vertices),
        num_edges=int(len(g.indices)),
        avg_degree=float(deg.mean()),
        max_degree=float(deg.max()),
        p90=float(np.percentile(deg, 90.0)),
        p95=float(np.percentile(deg, 95.0)),
        p99=float(np.percentile(deg, 99.0)),
    )


@dataclasses.dataclass
class CandidatePlan:
    """One fully-specified engine configuration plus the predictions it was
    scored by — predictions travel WITH the plan so a later validation run
    can hold the plan to exactly what enumeration claimed."""
    family: str                    # edge_cut | vertex_cut | hybrid
    execution: str                 # broadcast | ring | p2p
    k: int                         # devices the predictions were made for
    model: str = "gcn"             # the model/widths the plan was SCORED
    hidden: int = 32               #   for — engine_config() pins them so a
    num_layers: int = 2            #   validation dryrun measures the same
    #                                  exchange widths enumeration predicted
    partitioner: str = "metis_like"
    vertex_cut: str = "cartesian2d"
    hub_threshold: Optional[float] = None
    sorted_masters: bool = False
    exchange_chunks: int = 1
    p2p_buckets: int = 1
    cache_policy: str = "none"
    predicted_step_bytes: int = 0         # sum of per-step wire fields
    predicted_bottleneck_bytes: int = 0   # max over devices (critical path)
    balance_claim: Dict = dataclasses.field(default_factory=dict)
    #   gauge name -> claimed max-over-mean of the layout's per-device gauge

    def label(self) -> str:
        bits = [self.family, self.execution]
        if self.family == "edge_cut":
            bits.append(self.partitioner)
        elif self.family == "vertex_cut":
            bits.append(self.vertex_cut)
        else:
            bits.append(f"thr={self.hub_threshold}")
        return "/".join(bits)

    def engine_config(self, **overrides):
        """The EngineConfig this plan stands for (imported lazily: the
        engine imports layout_api, the planner imports both)."""
        from repro.core.engine import EngineConfig
        kw = dict(partition_family=self.family, execution=self.execution,
                  model=self.model, hidden=self.hidden,
                  num_layers=self.num_layers,
                  partitioner=self.partitioner, vertex_cut=self.vertex_cut,
                  hub_threshold=self.hub_threshold,
                  sorted_masters=self.sorted_masters,
                  exchange_chunks=self.exchange_chunks,
                  p2p_buckets=self.p2p_buckets,
                  cache_policy=self.cache_policy)
        kw.update(overrides)
        return EngineConfig(**kw)


def _gauge_imbalance(lay) -> Dict:
    """max-over-mean of every device-labeled layout gauge, read through the
    SAME telemetry_gauges path the traced dryrun populates."""
    tel = Telemetry(enabled=True)
    lay.telemetry_gauges(tel)
    out = {}
    for name, labels, m in tel.metrics._iter("gauge"):
        if "device" in labels:
            g = out.setdefault(name, {})
            g[int(labels["device"])] = float(m.value)
    claim = {}
    for name, per_dev in out.items():
        vals = np.array(list(per_dev.values()), np.float64)
        mean = vals.mean()
        claim[name] = float(vals.max() / mean) if mean > 0 else 1.0
    return claim


def _pipeline_knobs(g: Graph, k: int, dims, execution: str,
                    table_budget_bytes: int) -> Tuple[int, int]:
    """Peak-buffer heuristics for the overlap knobs: chunk the exchange when
    the gathered table would exceed the budget; bucket the p2p sends when a
    single installment would."""
    peak = g.num_vertices * max(int(d) for d in dims) * 4
    chunks = max(1, int(-(-peak // table_budget_bytes)))
    buckets = 1
    if execution == "p2p" and peak > table_budget_bytes:
        buckets = min(4, 1 << (chunks - 1).bit_length())
    return chunks, buckets


def enumerate_plans(g: Graph, k: int, dims, model: str = "gcn", *,
                    partitioners=("metis_like",),
                    vertex_cuts=("cartesian2d", "libra"),
                    hub_thresholds=None,
                    executions=("broadcast", "ring", "p2p"),
                    table_budget_bytes: int = 64 << 20,
                    ) -> List[CandidatePlan]:
    """Build every candidate's REAL layout and score it with the exact
    per-step accounting the engine itself will report.  ``dims`` is the
    engine's layer-width list [D_in, hidden..., num_classes] (hidden widths
    uniform — that is the engine's layer-width shape)."""
    L = len(dims) - 1
    hidden = int(dims[1]) if L > 1 else int(dims[-1])
    stats = graph_stats(g)
    if hub_thresholds is None:
        hub_thresholds = sorted({stats["p90"], stats["p95"], stats["p99"],
                                 float("inf")})
    plans: List[CandidatePlan] = []
    variants = ([("edge_cut", dict(partitioner=p)) for p in partitioners]
                + [("vertex_cut", dict(vertex_cut=c, sorted_masters=True))
                   for c in vertex_cuts]
                + [("hybrid", dict(hub_threshold=t)) for t in hub_thresholds])
    for family, var in variants:
        for exe in executions:
            chunks, buckets = _pipeline_knobs(g, k, dims, exe,
                                              table_budget_bytes)
            plan = CandidatePlan(family=family, execution=exe, k=k,
                                 model=model, hidden=hidden, num_layers=L,
                                 exchange_chunks=chunks, p2p_buckets=buckets,
                                 **var)
            cfg = plan.engine_config()
            lay = get_layout_builder(family)(g, k, cfg)
            wf = lay.wire_fields_per_step(model, list(dims))
            db = lay.device_bytes_per_step(model, list(dims))
            plan.predicted_step_bytes = int(sum(wf.values()))
            plan.predicted_bottleneck_bytes = int(np.asarray(db).max())
            plan.balance_claim = _gauge_imbalance(lay)
            plans.append(plan)
    return plans


def choose_plan(plans: List[CandidatePlan],
                objective: str = "bottleneck") -> CandidatePlan:
    """Argmin over the recorded predictions.  ``bottleneck`` minimizes the
    busiest device's wire bytes (the critical path); ``total`` minimizes the
    summed step bytes.  The loser metric breaks ties, then enumeration order
    keeps the choice deterministic."""
    if not plans:
        raise ValueError("choose_plan: no candidate plans")
    if objective not in ("bottleneck", "total"):
        raise ValueError("objective must be 'bottleneck' or 'total'")
    if objective == "bottleneck":
        key = lambda ip: (ip[1].predicted_bottleneck_bytes,  # noqa: E731
                          ip[1].predicted_step_bytes, ip[0])
    else:
        key = lambda ip: (ip[1].predicted_step_bytes,  # noqa: E731
                          ip[1].predicted_bottleneck_bytes, ip[0])
    return min(enumerate(plans), key=key)[1]


def validate_plan(g: Graph, plan: CandidatePlan, *, steps: int = 2,
                  drift: float = 0.25, mesh=None) -> Dict:
    """Trust-but-verify: run ``steps`` traced training steps under the plan
    and hold the measurements to the plan's claims.

      * wire bytes — the summed ``comm.*`` counter totals (the telemetry
        mirror of CommStats, which the oracle tiers lock to the layouts'
        cost models) must be within ``drift`` of ``steps * predicted``;
      * balance — every layout gauge's measured max-over-mean must be within
        ``drift`` (relative) of the plan's balance claim.

    Raises `PlanRejected` on any violation; returns the measurement report
    otherwise."""
    from repro.core.engine import DistGNNEngine
    import jax
    n_dev = (len(jax.devices()) if mesh is None
             else int(np.prod(mesh.devices.shape)))
    if n_dev != plan.k:
        raise PlanRejected(
            f"plan was scored for k={plan.k} devices but the dryrun mesh has "
            f"{n_dev}: the predictions do not transfer")
    eng = DistGNNEngine(g, mesh=mesh, cfg=plan.engine_config())
    tel = eng.enable_telemetry()
    eng.train(steps)
    measured_fields = {name: int(tel.metrics.counter_total("comm." + name))
                       for name in eng._wire_fields}
    measured = sum(measured_fields.values())
    predicted = steps * plan.predicted_step_bytes
    report = dict(plan=plan.label(), steps=steps, predicted_bytes=predicted,
                  measured_bytes=measured, measured_fields=measured_fields,
                  ratio=(measured / predicted if predicted else
                         (1.0 if measured == 0 else float("inf"))),
                  balance=dict())
    if predicted == 0:
        if measured != 0:
            raise PlanRejected(
                f"{plan.label()}: predicted zero wire bytes but measured "
                f"{measured}")
    elif not (1.0 - drift <= report["ratio"] <= 1.0 + drift):
        raise PlanRejected(
            f"{plan.label()}: measured wire bytes {measured} vs predicted "
            f"{predicted} (ratio {report['ratio']:.3f}) drifts past "
            f"+/-{drift:.0%}")
    imb = tel.imbalance_report()["metrics"]
    for name, claimed in plan.balance_claim.items():
        got = imb.get(name, {}).get("max_over_mean")
        report["balance"][name] = dict(claimed=claimed, measured=got)
        if got is None or abs(got - claimed) > drift * max(claimed, 1.0):
            raise PlanRejected(
                f"{plan.label()}: balance gauge {name} measured {got} vs "
                f"claimed {claimed:.3f} drifts past +/-{drift:.0%}")
    return report


def autotune(g: Graph, k: int, dims, model: str = "gcn", *,
             objective: str = "bottleneck", validate: bool = True,
             steps: int = 2, drift: float = 0.25, mesh=None,
             **enum_kwargs) -> Tuple[CandidatePlan, Dict]:
    """enumerate -> choose -> (optionally) validate.  Returns the chosen
    plan and a report carrying the graph stats, the scored candidates and —
    when validated — the dryrun measurements."""
    plans = enumerate_plans(g, k, dims, model, **enum_kwargs)
    best = choose_plan(plans, objective=objective)
    report = dict(
        graph=graph_stats(g), objective=objective, chosen=best.label(),
        candidates=[dict(label=p.label(),
                         step_bytes=p.predicted_step_bytes,
                         bottleneck_bytes=p.predicted_bottleneck_bytes)
                    for p in plans])
    if validate:
        report["validation"] = validate_plan(g, best, steps=steps,
                                             drift=drift, mesh=mesh)
    return best, report
