"""PowerLyra-style hybrid degree-threshold cut (survey §4.2, ROADMAP item
3): low-degree vertices live edge-cut-local behind a halo exchange; hub
vertices (in-degree >= threshold) replicate vertex-cut-style with the
replica-sync GAS combine.  One layout composes the two existing dataflows
per vertex class.

Construction
------------
Start from an edge-cut master assignment (any `PARTITIONERS` entry, or a
user-supplied `Partition`).  Classify vertices: ``hub = in_degree >=
threshold``.  Each edge (src -> dst, CSR order) is then owned by

  * ``masters[dst]``  when dst is LOW-degree  — the edge computes at dst's
    home, exactly the edge-cut rule; if src is low and lives elsewhere its
    row crosses the HALO wire (no replica is materialized);
  * ``masters[src]``  when dst is a HUB       — dst's aggregation partials
    accumulate where its in-edges already live, and the replica-sync
    combine sums them across src masters (the PowerLyra insight: only hubs
    pay replication, and their fan-in never concentrates on one device).

Hub SOURCES of owned edges are also materialized as replica slots (they are
local by construction when dst is low: owner == masters[dst] only consumes
src rows through the halo when src is low).  The degenerate thresholds
recover the pure families exactly: ``inf`` -> nobody is a hub -> every
vertex has exactly its master replica and the halo carries precisely the
edge-cut `communication_volume`; ``0`` -> everybody is a hub -> edges
compute at ``masters[src]`` with zero halo — a src-replicating vertex-cut.

The engine-facing class `HybridLayout` builds an inner `VertexCutLayout`
over the presence sets (so `build_replica_sync_plan` and the flattening in
`ReplicaLayoutBase` apply unchanged) plus per-execution halo tables the
`ReplicaSyncBackend` consumes when ``halo_active``:

  halo_send [k, B, k, w]  p2p bucketed installments (same builder as the
                          edge-cut plan);
  halo_src  [k, Hbuf]     broadcast: flat index into the all_gathered
                          [k*nv | zero] table per canonical halo slot;
  halo_ring [k, k, Hbuf]  ring: per source-owner rotation, local slot to
                          read (pad nv -> the appended zero row; each
                          canonical slot has exactly ONE real source, so
                          the k-round sum is exact).

Canonical halo slots use the same installment-major `halo_slot` numbering
as the edge-cut p2p plan, so the owned-edge ELL ids are shared by all three
execution models.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.execution.pipeline_exchange import (
    bucketed_cap_widths,
    bucketed_send_table,
    halo_slot,
)
from repro.core.graph import Graph
from repro.core.partition.cost_models import (
    FEAT_BYTES,
    hybrid_device_bytes,
    hybrid_exchange_widths,
)
from repro.core.partition.edge_cut import PARTITIONERS
from repro.core.partition.layout_api import (
    LAYOUT_BUILDERS,
    ReplicaLayoutBase,
)
from repro.core.partition.vertex_cut import VertexCut, edge_endpoints
from repro.core.partition.vertex_layout import VertexCutLayout


def auto_hub_threshold(g: Graph, q: float = 95.0) -> float:
    """Default hub threshold: the q-th percentile of the in-degree
    distribution — on power-law graphs this tags the heavy tail whose
    fan-in makes edge-cut's hub-owner straggler, while keeping the >=95%
    low-degree mass halo-cheap."""
    deg = g.degree()
    if len(deg) == 0:
        return np.inf
    return float(np.percentile(deg, q))


@dataclasses.dataclass
class HybridCut:
    """The cut decision alone (layout-free) — what the property tier locks."""
    threshold: float
    hub: np.ndarray         # [V] bool — in_degree >= threshold
    masters: np.ndarray     # [V] int64 master partition (the edge-cut side)
    edge_owner: np.ndarray  # [E] int64 owner per CSR edge
    num_parts: int

    def as_vertex_cut(self) -> VertexCut:
        return VertexCut(self.edge_owner.astype(np.int32), self.num_parts,
                         self.masters.astype(np.int32))


def build_hybrid_cut(g: Graph, k: int, threshold: Optional[float] = None,
                     partition=None,
                     partitioner: str = "metis_like") -> HybridCut:
    """Classify vertices by the degree threshold and assign edge owners
    (see module docstring).  ``threshold=None`` -> `auto_hub_threshold`."""
    if threshold is None:
        threshold = auto_hub_threshold(g)
    part = partition or PARTITIONERS[partitioner](g, k)
    masters = np.asarray(part.assignment, np.int64)
    deg = g.degree()
    # np.inf/-inf thresholds compare correctly; hub set is EXACTLY >= thr
    hub = deg.astype(np.float64) >= threshold
    src, dst = edge_endpoints(g)
    owner = np.where(hub[dst], masters[src], masters[dst]).astype(np.int64) \
        if len(src) else np.zeros(0, np.int64)
    return HybridCut(threshold=float(threshold), hub=hub, masters=masters,
                     edge_owner=owner, num_parts=k)


class HybridLayout(ReplicaLayoutBase):
    family = "hybrid"

    @classmethod
    def validate(cls, cfg, partition=None) -> None:
        if cfg.batching != "full_graph":
            raise ValueError(
                "hybrid supports batching='full_graph' only "
                "(vertex-cut mini-batch sampling is a ROADMAP follow-up)")
        thr = getattr(cfg, "hub_threshold", None)
        if thr is not None and not thr >= 0:  # rejects negatives and NaN
            raise ValueError(
                "hub_threshold must be >= 0 (np.inf -> pure edge-cut, "
                "0 -> pure vertex-cut) or None for the auto percentile")

    def _build(self, partition):
        c, g, k = self.cfg, self.g, self.k
        self.part = (partition
                     or PARTITIONERS[c.partitioner](g, k))
        cut = self.cut = build_hybrid_cut(
            g, k, threshold=getattr(c, "hub_threshold", None),
            partition=self.part)
        self.vcut = cut.as_vertex_cut()
        V = g.num_vertices
        src, dst = edge_endpoints(g)
        owner, masters = cut.edge_owner, cut.masters
        # presence: every master replica; dst of each owned edge; hub srcs
        # (low srcs are NOT materialized remotely — they ride the halo)
        key_list = [masters * V + np.arange(V, dtype=np.int64)]
        if len(owner):
            key_list.append(owner * V + dst)
            hs = cut.hub[src]
            if hs.any():
                key_list.append((owner * V + src)[hs])
        keys = np.unique(np.concatenate(key_list))
        part_of, vid = keys // V, keys % V
        rep_count = np.bincount(vid, minlength=V)
        sizes = np.bincount(part_of, minlength=k)
        nv = max(int(sizes.max()), 1)
        vert_ids = np.full((k, nv), V, np.int64)
        slot_of = np.full((k, V), -1, np.int64)
        master_counts = np.zeros(k, np.int64)
        for d in range(k):
            vs = vid[part_of == d]  # sorted ascending (keys are sorted)
            master_counts[d] = int((masters[vs] == d).sum())
            vert_ids[d, : len(vs)] = vs
            slot_of[d, vs] = np.arange(len(vs))
        # owned-edge ELL rows: dst slot on the owner (dst always present)
        dslot = slot_of[owner, dst] if len(owner) else owner
        sslot = slot_of[owner, src] if len(owner) else owner
        absent = sslot < 0  # low-degree remote src -> halo
        cnt = np.zeros((k, nv), np.int64)
        if len(owner):
            np.add.at(cnt, (owner, dslot), 1)
        Kc = max(int(cnt.max()), 1)
        # halo need sets: need[d][s] = sorted home slots (on master s) that
        # owner d's ELL reads through the wire — same shape as the edge-cut
        # p2p plan, reused for all three execution models' tables
        need = [[np.zeros(0, np.int64) for _ in range(k)] for _ in range(k)]
        sm = masters[src] if len(owner) else owner
        if absent.any():
            for d in range(k):
                for s in range(k):
                    if s == d:
                        continue
                    sel = absent & (owner == d) & (sm == s)
                    if sel.any():
                        need[d][s] = np.unique(slot_of[s, src[sel]])
        self.halo_need = need
        self.halo_rows = sum(len(x) for row in need for x in row)
        self.halo_active = self.halo_rows > 0
        execution = c.execution
        buckets = c.p2p_buckets if execution == "p2p" else 1
        Hcap = max(1, max((len(x) for row in need for x in row), default=1))
        widths = bucketed_cap_widths(Hcap, buckets)
        B, w = len(widths), widths[0]
        Hbuf = B * k * w if self.halo_active else 0
        self.halo_widths = widths
        # ELL columns: local slot, or nv + canonical halo slot; pad/zero row
        # sits AFTER the halo block (ReplicaSyncBackend._halo_table order)
        pad_id = nv + Hbuf
        ids_owned = np.full((k, nv, Kc), pad_id, np.int32)
        mask_owned = np.zeros((k, nv, Kc), np.float32)
        ref_cols = np.full((k, nv, Kc), k * nv, np.int64)
        if len(owner):
            pos_lut = [dict() for _ in range(k)]
            for d in range(k):
                for s in range(k):
                    for t, li in enumerate(need[d][s]):
                        pos_lut[d][(s, int(li))] = t
            col = np.where(absent, 0, np.maximum(sslot, 0)).astype(np.int64)
            refc = np.where(absent, 0, owner * nv + np.maximum(sslot, 0))
            if absent.any():
                home = slot_of[sm, src]  # src present at its own master
                hp = np.zeros(len(owner), np.int64)
                for e in np.flatnonzero(absent):
                    t = pos_lut[int(owner[e])][(int(sm[e]), int(home[e]))]
                    hp[e] = nv + halo_slot(t, int(sm[e]), w, k, 0)
                col = np.where(absent, hp, col)
                refc = np.where(absent, sm * nv + home, refc)
            grp = owner * nv + dslot
            order = np.argsort(grp, kind="stable")
            gs = grp[order]
            run_id = np.cumsum(np.r_[0, (np.diff(gs) != 0).astype(np.int64)])
            first = np.r_[0, np.flatnonzero(np.diff(gs)) + 1]
            pos = np.arange(len(gs)) - first[run_id]
            ids_owned[owner[order], dslot[order], pos] = col[order]
            mask_owned[owner[order], dslot[order], pos] = 1.0
            ref_cols[owner[order], dslot[order], pos] = refc[order]
        # per-slot tables — identical construction to build_vertex_layout
        deg_g = np.maximum(g.degree(), 1).astype(np.float32)
        present = vert_ids < V
        safe = np.minimum(vert_ids, V - 1)
        deg = np.where(present, deg_g[safe], 1.0)[..., None].astype(np.float32)
        master_mask = (present & (masters[safe] == np.arange(k)[:, None])
                       ).astype(np.float32)
        # boundary = rows other devices read: replicated slots + halo sources
        bmask = present & (rep_count[safe] > 1)
        for s in range(k):
            lis = [need[d][s] for d in range(k) if len(need[d][s])]
            if lis:
                bmask[s, np.unique(np.concatenate(lis))] = True
        D = g.features.shape[1]
        X = np.where(present[..., None], g.features[safe],
                     0.0).astype(np.float32)
        y = np.where(present, g.labels[safe], 0).astype(np.int32)
        train = (g.train_mask[safe] if g.train_mask is not None
                 else np.zeros((k, nv), bool))
        test = (g.test_mask[safe] if g.test_mask is not None
                else np.zeros((k, nv), bool))
        train_w = (master_mask
                   * np.where(present, train, False)).astype(np.float32)
        test_w = (master_mask
                  * np.where(present, test, False)).astype(np.float32)
        self.layout = VertexCutLayout(
            k=k, nv=nv, Kc=Kc, Rm=max(int(rep_count.max()), 1),
            vert_ids=vert_ids, slot_of=slot_of, master_mask=master_mask,
            rep_count=rep_count, ids_owned=ids_owned, mask_owned=mask_owned,
            deg=deg, bmask=bmask, X=X, y=y, train_w=train_w, test_w=test_w,
            master_counts=master_counts)
        self._flatten_layout()
        # reference ELL: halo columns point at the source's HOME flat slot
        # (s*nv + home), present columns at their replica slot; pad -> Vp
        self.ids_global = np.where(mask_owned > 0, ref_cols,
                                   k * nv).reshape(self.Vp, Kc
                                                   ).astype(np.int64)
        self.sync_active = int(rep_count.max()) > 1 if V else False
        self.has_replicas = self.sync_active
        if self.sync_active:
            self._build_sync_plan(masters)
        else:
            self._vc_plan = {}
            self._vc_rows_per_layer = 0
            self._vc_p2p_caps = None
            self.squeeze_keys = ()
        # per-execution halo tables (see module docstring)
        self._halo_consts = {}
        if self.halo_active:
            if execution == "p2p":
                self._halo_consts["halo_send"] = jnp.asarray(
                    bucketed_send_table(
                        [[need[d][s] for d in range(k)] for s in range(k)],
                        k, widths))
            elif execution == "broadcast":
                halo_src = np.full((k, Hbuf), k * nv, np.int64)
                for d in range(k):
                    for s in range(k):
                        for t, li in enumerate(need[d][s]):
                            halo_src[d, halo_slot(t, s, w, k, 0)] = \
                                s * nv + li
                self._halo_consts["halo_src"] = jnp.asarray(halo_src)
            else:  # ring
                halo_ring = np.full((k, k, Hbuf), nv, np.int64)
                for d in range(k):
                    for s in range(k):
                        for t, li in enumerate(need[d][s]):
                            halo_ring[d, s, halo_slot(t, s, w, k, 0)] = li
                self._halo_consts["halo_ring"] = jnp.asarray(halo_ring)
            self.squeeze_keys = (self.squeeze_keys
                                 + tuple(self._halo_consts))
        # halo rows crossing the wire per exchange pass
        if not self.halo_active:
            self.halo_rows_exec = 0
        elif execution == "p2p":
            self.halo_rows_exec = self.halo_rows
        else:
            self.halo_rows_exec = k * (k - 1) * nv

    def exchange_consts(self) -> dict:
        consts = super().exchange_consts()
        consts.update(self._halo_consts)
        return consts

    def wire_fields_per_step(self, model, dims) -> dict:
        # == cost_models.hybrid_bytes_per_step(halo_rows_exec,
        #    _vc_rows_per_layer, dims, model), split per CommStats field
        halo_w, sync_w = hybrid_exchange_widths(model, dims)
        out = {}
        if self.halo_active:
            out["halo_bytes"] = (self.halo_rows_exec
                                 * int(sum(halo_w)) * FEAT_BYTES)
        if self.sync_active:
            out["replica_sync_bytes"] = (self._vc_rows_per_layer
                                         * int(sum(sync_w)) * FEAT_BYTES)
        return out

    def embed_grad_bytes(self, dims) -> int:
        # halo grad transpose (one width-D0 return pass) + the vertex-cut
        # grad-combine / master-delta pair over the replica rows
        rows = self.halo_rows_exec
        if self.sync_active:
            rows += 2 * self._vc_rows_per_layer
        return rows * int(dims[0]) * FEAT_BYTES

    def device_bytes_per_step(self, model, dims) -> np.ndarray:
        return hybrid_device_bytes(
            self.layout, self.cut.masters, self.halo_need,
            self.cfg.execution, dims, model=model,
            halo_active=self.halo_active, sync_active=self.sync_active)

    def telemetry_gauges(self, tel) -> None:
        super().telemetry_gauges(tel)
        recv = [sum(len(self.halo_need[d][s]) for s in range(self.k))
                for d in range(self.k)]
        for d in range(self.k):
            tel.gauge("layout.halo_rows", device=d).set(int(recv[d]))


LAYOUT_BUILDERS["hybrid"] = HybridLayout
