"""GNN cost models (survey §4.1): heuristic affinity scores (Eq. 3-5),
learning-based linear regression (ROC, Eq. 6-7), operator-based (CM-GCN,
Eq. 9-11).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.graph import Graph

# ---------------------------------------------------------------------------
# Heuristic affinity scores for streaming partition
# ---------------------------------------------------------------------------


def pagraph_score(candidate_in_nbrs: np.ndarray, part_train_sets: Sequence[set],
                  part_sizes: np.ndarray, avg_train: float) -> np.ndarray:
    """Eq. 3 (Lin et al. / PaGraph): |V_train^i ∩ IN(v)| * (avg - |V_train^i|)/|P_i|."""
    K = len(part_train_sets)
    scores = np.zeros(K)
    nbrs = set(candidate_in_nbrs.tolist())
    for i in range(K):
        inter = len(part_train_sets[i] & nbrs)
        denom = max(part_sizes[i], 1)
        scores[i] = inter * (avg_train - len(part_train_sets[i])) / denom
    return scores


def bgl_score(block_in_nbrs: np.ndarray, part_vertex_sets: Sequence[set],
              part_sizes: np.ndarray, part_train_counts: np.ndarray,
              avg_part: float, avg_train: float) -> np.ndarray:
    """Eq. 4 (Liu et al. / BGL): |P_i ∩ IN(B)| * (1-|P_i|/P_avg) * (1-train_i/train_avg)."""
    K = len(part_vertex_sets)
    nbrs = set(block_in_nbrs.tolist())
    scores = np.zeros(K)
    for i in range(K):
        inter = len(part_vertex_sets[i] & nbrs)
        scores[i] = (inter * (1.0 - part_sizes[i] / max(avg_part, 1.0))
                     * (1.0 - part_train_counts[i] / max(avg_train, 1.0)))
    return scores


def bytegnn_score(cross_edges: np.ndarray, part_sizes: np.ndarray,
                  train_counts: np.ndarray, valid_counts: np.ndarray,
                  test_counts: np.ndarray, avgs: tuple, alpha=0.5, beta=0.3,
                  gamma=0.2) -> np.ndarray:
    """Eq. 5 (Zheng et al. / ByteGNN)."""
    t_avg, v_avg, s_avg = avgs
    frac = cross_edges / np.maximum(part_sizes, 1)
    penalty = (1.0 - alpha * train_counts / max(t_avg, 1.0)
               - beta * valid_counts / max(v_avg, 1.0)
               - gamma * test_counts / max(s_avg, 1.0))
    return frac * penalty


# ---------------------------------------------------------------------------
# Partition-family communication models (§4.2): edge-cut halo volume vs
# vertex-cut replica-sync volume, per training step.  These are the standalone
# models the DistGNNEngine's CommStats accounting is cross-checked against.
# ---------------------------------------------------------------------------

FEAT_BYTES = 4


def model_exchange_widths(model: str, dims: Sequence[int],
                          family: str = "edge_cut") -> list:
    """Per-layer floats-per-exchanged-row for each GNN model (the survey's
    model-dependent communication volume, §3 x §4).

      gcn / sage / gin  the exchange ships the layer's INPUT rows — width
                        dims[l].  sage/gin's self-feature terms read the
                        RESIDENT block, so the model axis adds ZERO bytes
                        over gcn (asserted by the model property tier).
      gat               the exchange ships the TRANSFORMED rows Hw (width
                        dims[l+1]) plus ONE attention-coefficient column
                        (a_src . Hw) — the +1 "α term"; under vertex_cut the
                        segment-softmax needs a second, width-1 replica pass
                        (the max combine that exactifies the normalizer), so
                        +2 per layer there.
    """
    L = len(dims) - 1
    if model == "gat":
        extra = 2 if family == "vertex_cut" else 1
        return [int(dims[l + 1]) + extra for l in range(L)]
    return [int(d) for d in dims[:-1]]


def replica_sync_bytes_per_step(rep_counts: np.ndarray, k: int, nv: int,
                                execution: str, dims: Sequence[int],
                                feat_bytes: int = FEAT_BYTES,
                                model: str = "gcn") -> int:
    """Replication-factor-aware wire bytes of one vertex-cut train step.

    ``rep_counts`` [V] = replicas per vertex (incl. the forced master — see
    VertexCutLayout); ``dims`` = the GNN layer dims ([D_in, hidden..., C]):
    every layer's exchange ships rows of that layer's INPUT width, so one
    replica row crosses the wire at sum(dims[:-1]) floats per step.

      broadcast / ring  every device ships its whole nv-slot partial block to
                        the other k-1 devices per layer;
      p2p               master-based GAS: each non-master replica sends one
                        partial row and receives one aggregate row per layer
                        -> 2 * Σ_v (r(v) - 1) rows, bounded by the
                        replication factor rather than the halo size.
    """
    if execution in ("broadcast", "ring"):
        rows = k * (k - 1) * nv
    elif execution == "p2p":
        rows = 2 * int(np.maximum(np.asarray(rep_counts) - 1, 0).sum())
    else:
        raise ValueError(f"unknown execution {execution!r}")
    widths = model_exchange_widths(model, dims, "vertex_cut")
    return rows * int(sum(widths)) * feat_bytes


def edge_cut_halo_bytes_per_step(g: Graph, part, dims: Sequence[int],
                                 feat_bytes: int = FEAT_BYTES,
                                 model: str = "gcn") -> int:
    """Edge-cut p2p halo volume of one train step: every layer ships each
    partition's remote in-neighbor set (`Partition.boundary_vertices`) once,
    at that layer's model-dependent exchange width."""
    widths = model_exchange_widths(model, dims, "edge_cut")
    return part.communication_volume(g) * int(sum(widths)) * feat_bytes


def inference_bytes_per_sweep(execution: str, dims: Sequence[int], *,
                              model: str = "gcn", family: str = "edge_cut",
                              k: int = None, nb: int = None, g: Graph = None,
                              part=None, rep_counts: np.ndarray = None,
                              nv: int = None,
                              feat_bytes: int = FEAT_BYTES) -> int:
    """Wire bytes of ONE layer-wise full-graph inference sweep
    (`DistGNNEngine.infer_full_graph`): the forward-only half of a train
    step — every layer runs its exchange exactly once at that layer's
    model-dependent width, and nothing flows back (no gradient transpose,
    no embedding-delta re-broadcast).

      edge_cut broadcast/ring  every device gathers the other k-1 padded
                               blocks per layer: k*(k-1)*nb rows.
      edge_cut p2p             each layer ships each partition's remote
                               in-neighbor (halo) set once:
                               `part.communication_volume(g)` rows — the
                               engine's bucketed all_to_all need sets.
      vertex_cut               one replica-sync combine per layer — the same
                               rows-per-layer as a training forward, so the
                               sweep volume IS `replica_sync_bytes_per_step`
                               (gat pays its +2 max/α columns there).

    Cross-checked against CommStats.inference_bytes by the serving tier."""
    if family == "vertex_cut":
        return replica_sync_bytes_per_step(rep_counts, k, nv, execution,
                                           dims, feat_bytes, model)
    widths = model_exchange_widths(model, dims, "edge_cut")
    if execution in ("broadcast", "ring"):
        rows = k * (k - 1) * int(nb)
    elif execution == "p2p":
        rows = part.communication_volume(g)
    else:
        raise ValueError(f"unknown execution {execution!r}")
    return rows * int(sum(widths)) * feat_bytes


def embedding_grad_bytes_per_step(g: Graph, execution: str,
                                  dims: Sequence[int], *, k: int,
                                  family: str = "edge_cut", part=None,
                                  nb: int = None, replica_rows: int = None,
                                  feat_bytes: int = FEAT_BYTES) -> int:
    """Wire bytes per FULL-GRAPH train step for routing layer-0 embedding
    gradients back to their owner shards (cfg.trainable_features) — the
    transpose of one layer-0-width exchange pass at width dims[0].

      edge_cut broadcast/ring  the all_gather / ring-rotation transpose is a
                               reduce-scatter of the same table:
                               k*(k-1)*nb rows (nb = the padded block size).
      edge_cut p2p             each halo row's cotangent returns to its owner
                               once: `part.communication_volume(g)` rows —
                               the engine's bucketed all_to_all ships exactly
                               these (its need sets are the partition's
                               remote in-neighbor sets).
      vertex_cut               two replica-sync passes at width dims[0]: the
                               per-replica partial grads combine to the full
                               vertex grad, and the master-masked update's
                               delta broadcasts back so replicas never drift
                               -> 2 * replica_rows (= the plan's
                               rows_per_layer) rows.

    Cross-checked against DistGNNEngine's CommStats.embed_grad_bytes by the
    feature-store test tier."""
    D = int(dims[0])
    if family == "vertex_cut":
        return 2 * int(replica_rows) * D * feat_bytes
    if execution in ("broadcast", "ring"):
        rows = k * (k - 1) * int(nb)
    elif execution == "p2p":
        rows = part.communication_volume(g)
    else:
        raise ValueError(f"unknown execution {execution!r}")
    return rows * D * feat_bytes


def edge_cut_halo_device_bytes(g: Graph, part, dims: Sequence[int],
                               feat_bytes: int = FEAT_BYTES,
                               model: str = "gcn") -> np.ndarray:
    """[k] per-device halo bytes per step, counting BOTH directions (a row's
    owner sends it, its consumer receives it) — the max of this array is the
    critical-path (straggler) comm volume that sets the step time.  On skewed
    graphs a hub's owner ships its row to up to k-1 consumers, which is
    exactly the bottleneck vertex-cut's bounded replication removes."""
    from repro.core.partition.vertex_cut import edge_endpoints

    src, dst = edge_endpoints(g)
    a = part.assignment.astype(np.int64)
    k = part.num_parts
    pairs = np.unique(src * k + a[dst])  # distinct (vertex, consumer) pairs
    pv, pc = pairs // k, pairs % k
    rem = a[pv] != pc
    send = np.bincount(a[pv][rem], minlength=k)
    recv = np.bincount(pc[rem], minlength=k)
    widths = model_exchange_widths(model, dims, "edge_cut")
    return (send + recv) * int(sum(widths)) * feat_bytes


def replica_sync_device_bytes(layout, masters: np.ndarray,
                              dims: Sequence[int],
                              feat_bytes: int = FEAT_BYTES,
                              model: str = "gcn") -> np.ndarray:
    """[k] per-device replica-sync bytes per step (p2p GAS accounting),
    counting both directions like `edge_cut_halo_device_bytes`: a non-master
    replica slot sends one partial and receives one aggregate per layer; a
    master does the mirror image for every other replica of the vertices it
    masters.  Bounded per device by the replication factor — no hub-owner
    straggler."""
    V = layout.slot_of.shape[1]
    nonmaster = ((layout.vert_ids < V)
                 & (layout.master_mask < 0.5)).sum(1).astype(np.int64)
    rm1 = np.maximum(layout.rep_count - 1, 0)
    master_traffic = np.bincount(np.asarray(masters, np.int64), weights=rm1,
                                 minlength=layout.k).astype(np.int64)
    widths = model_exchange_widths(model, dims, "vertex_cut")
    return (2 * (nonmaster + master_traffic)
            * int(sum(widths)) * feat_bytes)


# ---------------------------------------------------------------------------
# Hybrid (PowerLyra-style degree-threshold) family (§4.2): low-degree
# vertices live edge-cut-local behind a halo exchange; hub vertices
# replicate with the vertex-cut replica-sync GAS combine.  One step pays
# BOTH wires, each over its own row population.
# ---------------------------------------------------------------------------


def hybrid_exchange_widths(model: str, dims: Sequence[int]) -> tuple:
    """(halo_widths, sync_widths) — per-layer floats-per-row for the two
    wire populations of the hybrid family.

    Halo rows ship complete source rows to the consuming owner, which then
    computes locally: gcn/sage/gin ship the layer INPUT (width dims[l]);
    gat ships the transformed Hw only (width dims[l+1]) — the SDDMM
    derives both logit halves locally from the full row, so no α column
    crosses the halo wire.  Sync rows are vertex-cut GAS partials and pay
    exactly the vertex_cut widths (gat: +2 for the α and max columns)."""
    L = len(dims) - 1
    if model == "gat":
        return ([int(dims[l + 1]) for l in range(L)],
                [int(dims[l + 1]) + 2 for l in range(L)])
    w = [int(d) for d in dims[:-1]]
    return (list(w), list(w))


def hybrid_bytes_per_step(halo_rows: int, sync_rows: int,
                          dims: Sequence[int], model: str = "gcn",
                          feat_bytes: int = FEAT_BYTES) -> int:
    """Wire bytes of one hybrid-family train step: ``halo_rows`` rows cross
    per halo exchange pass and ``sync_rows`` rows per replica-sync combine
    (each once per layer, at that wire's model-dependent width).  Either
    population may be 0 — threshold=inf degenerates to a pure edge-cut
    (sync_rows=0), threshold=0 to a pure src-replicating vertex-cut
    (halo_rows=0).  Cross-checked EXACTLY against engine CommStats by the
    hybrid engine tier."""
    halo_w, sync_w = hybrid_exchange_widths(model, dims)
    return (int(halo_rows) * int(sum(halo_w))
            + int(sync_rows) * int(sum(sync_w))) * feat_bytes


def hybrid_device_bytes(layout, masters: np.ndarray, need,
                        execution: str, dims: Sequence[int], *,
                        model: str = "gcn",
                        feat_bytes: int = FEAT_BYTES,
                        halo_active: bool = True,
                        sync_active: bool = True) -> np.ndarray:
    """[k] per-device hybrid bytes per step, both directions (mirrors
    `edge_cut_halo_device_bytes` + `replica_sync_device_bytes`); the max is
    the critical-path volume.  ``layout`` is the hybrid family's inner
    replica layout (a VertexCutLayout over the presence sets), ``need`` the
    [k][k] halo need lists (need[d][s] = home slots owner d fetches from
    master s).  Under p2p both terms are population-bounded; broadcast/ring
    pay the full (k-1)*nv block per active wire."""
    k, nv = layout.k, layout.nv
    halo_w, sync_w = hybrid_exchange_widths(model, dims)
    hw, sw = int(sum(halo_w)), int(sum(sync_w))
    out = np.zeros(k, np.int64)
    if halo_active:
        if execution == "p2p":
            send = np.zeros(k, np.int64)
            recv = np.zeros(k, np.int64)
            for d in range(k):
                for s in range(k):
                    n = len(need[d][s])
                    recv[d] += n
                    send[s] += n
            out += (send + recv) * hw * feat_bytes
        else:
            out += 2 * (k - 1) * nv * hw * feat_bytes
    if sync_active:
        if execution == "p2p":
            out += replica_sync_device_bytes(layout, masters, dims,
                                             feat_bytes, model)
        else:
            out += 2 * (k - 1) * nv * sw * feat_bytes
    return out


# ---------------------------------------------------------------------------
# Communication/compute overlap (§6-§7 pipelining)
# ---------------------------------------------------------------------------


def overlapped_step_time(comm_s: float, compute_s: float,
                         num_chunks: int) -> float:
    """Per-layer step time with the exchange split into ``num_chunks``
    feature chunks and the collective for chunk c+1 issued while chunk c's
    aggregation computes (pipeline_exchange.chunked_overlap).

    Monolithic (C=1) pays comm + compute serially.  Pipelined, the first
    chunk's collective and the last chunk's multiply can't hide, but the
    C-1 interior chunks overlap entirely:

        t(C) = (comm + compute)/C + max(comm, compute) * (C-1)/C

    which approaches max(comm, compute) as C grows — the §6.1 overlap
    ideal.  A LOWER bound for a measured step (per-chunk launch/collective
    setup overheads only add); the pipelined-epoch analog over the
    sample/extract/train lanes is
    `execution.minibatch_pipeline.pipelined_wall_model`, cross-checked
    against the measured lanes in the pipeline test tier."""
    C = max(1, int(num_chunks))
    comm_s, compute_s = float(comm_s), float(compute_s)
    if C == 1:
        return comm_s + compute_s
    return (comm_s + compute_s) / C + max(comm_s, compute_s) * (C - 1) / C


# ---------------------------------------------------------------------------
# Learning-based (ROC): t(l, G) = sum_i w_i x_i(G)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RocCostModel:
    """Linear model over the five ROC vertex features (Table 1)."""
    weights: Optional[np.ndarray] = None  # [5]
    word_size: int = 16  # elements per memory transaction

    def vertex_features(self, g: Graph, hidden_dim: int) -> np.ndarray:
        V = g.num_vertices
        deg = g.degree().astype(np.float64)
        x1 = np.ones(V)
        x2 = deg
        # x3: continuity of neighbors — fraction of consecutive neighbor ids
        x3 = np.zeros(V)
        for v in range(V):
            nb = np.sort(g.neighbors(v))
            if len(nb) > 1:
                x3[v] = np.mean(np.diff(nb) == 1)
        x4 = np.ceil(deg / self.word_size)  # mem transactions to load neighbor ids
        x5 = np.ceil(deg * hidden_dim / self.word_size)  # to load activations
        return np.stack([x1, x2, x3, x4, x5], axis=1)

    def fit(self, feats: np.ndarray, times: np.ndarray) -> "RocCostModel":
        w, *_ = np.linalg.lstsq(feats, times, rcond=None)
        self.weights = w
        return self

    def fit_from_measurements(self, g: Graph, hidden_dim: int, n_chunks: int = 16,
                              repeats: int = 3) -> "RocCostModel":
        """Measure real aggregation runtimes on vertex chunks and fit."""
        V = g.num_vertices
        H = np.random.default_rng(0).standard_normal((V, hidden_dim)).astype(np.float32)
        order = np.arange(V)
        chunks = np.array_split(order, n_chunks)
        feats_all = self.vertex_features(g, hidden_dim)
        X, y = [], []
        for ch in chunks:
            t0 = time.perf_counter()
            for _ in range(repeats):
                for v in ch:
                    nb = g.neighbors(v)
                    if len(nb):
                        H[v] = H[nb].sum(0)
            dt = (time.perf_counter() - t0) / repeats
            X.append(feats_all[ch].sum(0))
            y.append(dt)
        return self.fit(np.stack(X), np.asarray(y))

    def predict_subgraph(self, g: Graph, vertices: np.ndarray, hidden_dim: int) -> float:
        assert self.weights is not None, "fit first"
        feats = self.vertex_features(g, hidden_dim)[vertices].sum(0)
        return float(feats @ self.weights)


def flexgraph_cost(neighbor_counts: np.ndarray, feature_dims: np.ndarray) -> float:
    """Eq. 8 (Wang et al. / FlexGraph): f = sum_i n_i * m_i over neighbor types."""
    return float(np.sum(neighbor_counts * feature_dims))


# ---------------------------------------------------------------------------
# Operator-based (CM-GCN, Eq. 9-11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OperatorCostModel:
    alpha: float = 1.0  # aggregation per neighbor-element
    beta: float = 1.0  # linear transform
    gamma: float = 0.1  # activation
    lam: float = 0.5  # loss-gradient
    eta: float = 0.5  # gradient multiplications

    def forward_cost(self, deg_v: float, d_in: int, d_out: int) -> float:
        return self.alpha * deg_v * d_in + self.beta * d_out * d_in + self.gamma * d_out

    def backward_cost(self, deg_v: float, d_in: int, d_out: int, is_last: bool) -> float:
        if is_last:
            return (self.lam + self.eta) * d_out + (2 * self.beta + self.eta) * d_out * d_in
        return (self.alpha * deg_v * d_out + (self.beta + self.eta) * d_out * d_in
                + self.eta * d_out)

    def batch_cost(self, g: Graph, batch: np.ndarray, layer_dims: Sequence[int]) -> float:
        """Eq. 11: sum over the L-hop expansion of the batch."""
        L = len(layer_dims) - 1
        frontier = set(batch.tolist())
        total = 0.0
        deg = g.degree()
        for l in range(L, 0, -1):
            d_in, d_out = layer_dims[l - 1], layer_dims[l]
            for v in frontier:
                total += self.forward_cost(deg[v], d_in, d_out)
                total += self.backward_cost(deg[v], d_in, d_out, is_last=(l == L))
            nxt = set(frontier)
            for v in frontier:
                nxt.update(g.neighbors(v).tolist())
            frontier = nxt
        return total
