"""Owner-partitioned, id-addressed feature/embedding store (ROADMAP item 1).

The survey's "massive feature communication" challenge treats features as
fixed files; at production scale the feature plane is a sharded KV-store of
(often learnable) embedding rows.  `FeatureStore` is that abstraction for the
engine: one table of shape [k, rows, D] whose row (owner, slot) lives on
device `owner`, addressed by the flat store id

    sid = owner * rows + slot

which IS the engine's relabeled vertex space under edge_cut (device d owns
[d*nb, (d+1)*nb)) and its replica-slot space under vertex_cut (slot space
[d*nv, (d+1)*nv)) — so both partition families resolve feature rows through
the same addressing, and the exchange plans (broadcast / ring / p2p) need no
change: they already move rows of this table.

The mini-batch feature cache becomes a HOT-ROW OVERLAY on the store: each
device pins a capacity-bounded set of remote store rows.  With frozen
features the overlay is a build-time snapshot (exact forever); with trainable
rows it must be re-read from the live owner shards — `overlay_refresh_plan`
builds the static bucketed all_to_all plan the jitted step uses to do that
every step (and whose transpose routes cache-hit gradients back to the
owners).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

# numpy-only import on purpose: the process-pool sampling workers read
# `touched_rows_from_frontier` and must not pull jax into their import chain
from repro.core.execution.bucketing import (
    bucketed_cap_widths,
    bucketed_send_table,
    halo_slot,
)


class FeatureStore:
    """Owner-partitioned feature/embedding table with flat-id addressing.

    Host-side source of truth for the engine's feature plane: the engine
    reads `device_table()` once at build (and again via `update_rows` /
    `lookup` in tests and serving paths); the jitted step owns the device
    copy.  `rows` is the per-owner padded row count (nb for edge_cut, nv for
    vertex_cut); pad rows are zero and never addressed by real ids."""

    def __init__(self, table: np.ndarray):
        table = np.asarray(table, np.float32)
        if table.ndim != 3:
            raise ValueError(
                f"FeatureStore wants [k, rows, D]; got shape {table.shape}")
        self._table = table.copy()
        self.k, self.rows, self.dim = table.shape
        self._overlay_ids: Optional[List[np.ndarray]] = None
        self._overlay_cap = 0
        self._overlay_tab: Optional[np.ndarray] = None
        # set by DistGNNEngine.enable_telemetry: overlay hit/miss/refresh
        # counters land in the run's MetricRegistry (None = no accounting)
        self.telemetry = None

    def count_overlay(self, device: int, hits: int, misses: int) -> None:
        """Per-batch overlay accounting (the engine's extract stage knows
        which remote frontier rows the hot-row overlay served)."""
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.counter("store.overlay_hit", device=device).add(int(hits))
            tel.counter("store.overlay_miss", device=device).add(int(misses))

    @classmethod
    def from_flat(cls, flat: np.ndarray, k: int) -> "FeatureStore":
        flat = np.asarray(flat, np.float32)
        return cls(flat.reshape(k, flat.shape[0] // k, flat.shape[1]))

    # -- id addressing --------------------------------------------------
    def owner_of(self, ids) -> np.ndarray:
        return np.asarray(ids) // self.rows

    def slot_of(self, ids) -> np.ndarray:
        return np.asarray(ids) % self.rows

    @property
    def num_rows(self) -> int:
        return self.k * self.rows

    # -- reads / writes --------------------------------------------------
    def flat(self) -> np.ndarray:
        """[k*rows, D] flat view (copy-free reshape of the owner table)."""
        return self._table.reshape(self.k * self.rows, self.dim)

    def device_table(self):
        """The flat table as a jnp array — what the engine feeds the jitted
        step (sharded P(ax, None) so device d holds exactly its shard)."""
        import jax.numpy as jnp

        return jnp.asarray(self.flat())

    def lookup(self, ids) -> np.ndarray:
        """Rows by flat store id; a sentinel id == k*rows reads a zero row
        (the same pad convention as the engine's gather tables)."""
        ids = np.asarray(ids)
        flat = self.flat()
        out = np.zeros(ids.shape + (self.dim,), np.float32)
        real = (ids >= 0) & (ids < self.num_rows)
        out[real] = flat[ids[real]]
        return out

    def update_rows(self, ids, values) -> None:
        """Write rows by flat store id (e.g. after an embedding update);
        invalidates nothing by itself — overlay snapshots go stale until
        `refresh_overlay` (host) or the in-step refresh plan (device)."""
        self.flat()[np.asarray(ids)] = np.asarray(values, np.float32)

    # -- hot-row overlay (the mini-batch cache as a view of the store) ---
    def attach_overlay(self, ids_per_device: Sequence[np.ndarray],
                       capacity: int) -> None:
        """Pin per-device hot REMOTE store rows (from a sampling/cache.py
        policy ranking, relabeled to store ids).  `capacity` is the static
        padded slot count every device's overlay table gets."""
        if len(ids_per_device) != self.k:
            raise ValueError(f"want {self.k} id lists, got "
                             f"{len(ids_per_device)}")
        ids_per_device = [np.asarray(a, np.int64) for a in ids_per_device]
        for d, a in enumerate(ids_per_device):
            if len(a) > capacity:
                raise ValueError(f"device {d} overlay {len(a)} > capacity "
                                 f"{capacity}")
            if np.any(self.owner_of(a) == d):
                raise ValueError(f"device {d} overlay contains its own rows "
                                 "(local rows are already resident)")
        self._overlay_ids = ids_per_device
        self._overlay_cap = int(capacity)
        self.refresh_overlay()

    def overlay_table(self) -> np.ndarray:
        """[k, capacity, D] overlay snapshot (zeros past each device's real
        rows) — the engine's static cache table when features are frozen."""
        if self._overlay_tab is None:
            raise ValueError("no overlay attached")
        return self._overlay_tab

    def refresh_overlay(self) -> None:
        """Re-read the overlay snapshot from the current table (what the
        in-step refresh plan does on device every step)."""
        tab = np.zeros((self.k, self._overlay_cap, self.dim), np.float32)
        for d, a in enumerate(self._overlay_ids):
            tab[d, : len(a)] = self.lookup(a)
        self._overlay_tab = tab
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.counter("store.overlay_refresh").add(1)


def overlay_refresh_plan(ids_per_device: Sequence[np.ndarray], k: int,
                         rows: int, capacity: int, buckets: int = 1
                         ) -> Tuple[np.ndarray, np.ndarray, list]:
    """Static plan to re-gather every device's overlay rows from the LIVE
    owner shards inside the jitted step: returns (send_rows [k, B, k, w],
    tab_ids [k, capacity], widths).

    The read side mirrors the engine's p2p frontier fetch: device d builds
    table = concat([own_shard, bucketed_all_to_all(own_shard, send_rows),
    zero_row]) and takes tab_ids[d] — slot j < len(ids) yields overlay row j,
    the rest read the zero row (sentinel).  Because the plan is static, the
    refresh compiles into the one jitted step, and its transpose routes
    cache-hit gradients back to the owners' shards."""
    ids_per_device = [np.asarray(a, np.int64) for a in ids_per_device]
    need_lists = [[np.zeros(0, np.int64) for _ in range(k)]
                  for _ in range(k)]  # [src][dst]
    for d, a in enumerate(ids_per_device):
        owners = a // rows
        for s in range(k):
            if s != d:
                need_lists[s][d] = (a[owners == s] % rows)
    cap = max(1, max((len(x) for row in need_lists for x in row), default=1))
    widths = bucketed_cap_widths(cap, buckets)
    B, w = len(widths), widths[0]
    send_rows = bucketed_send_table(need_lists, k, widths)
    tab_ids = np.full((k, capacity), rows + B * k * w, np.int32)
    for d, a in enumerate(ids_per_device):
        pos = {s: 0 for s in range(k)}
        for j, sid in enumerate(a):
            s = int(sid // rows)
            tab_ids[d, j] = int(halo_slot(pos[s], s, w, k, rows))
            pos[s] += 1
    return send_rows, tab_ids, widths


def touched_rows_from_frontier(frontier_sids: np.ndarray, k: int, rows: int,
                               cap: int) -> np.ndarray:
    """Per-OWNER touched local-row lists from a batch's frontier store ids:
    frontier_sids [k, cap0] (sentinel k*rows for pads) -> ids [k, cap] int32
    where row s lists the distinct local rows of owner s read by ANY device
    this step, sorted (deterministic), sentinel `rows` past the end.

    This is the sparse-optimizer id set: a row is touched iff some device's
    frontier reads it (cache hit or miss — hits read the refreshed overlay,
    whose gradient still lands on the owner's shard)."""
    sids = np.asarray(frontier_sids).ravel()
    sids = sids[(sids >= 0) & (sids < k * rows)]
    out = np.full((k, cap), rows, np.int32)
    owners, slots = sids // rows, sids % rows
    for s in range(k):
        uniq = np.unique(slots[owners == s])
        assert len(uniq) <= cap, (
            f"touched-row cap overflow: owner {s} has {len(uniq)} touched "
            f"rows, cap={cap}")
        out[s, : len(uniq)] = uniq
    return out
