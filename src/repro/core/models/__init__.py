from repro.core.models.gnn import (
    accuracy,
    full_graph_forward,
    gnn_layer,
    init_gnn_params,
    minibatch_forward,
    padded_minibatch_forward,
    softmax_xent,
)
