"""GNN models (GCN, GraphSAGE, GAT, GIN) as pure functions over dense
normalized adjacency blocks (tests / small graphs) — the sparse local
aggregation for large graphs is the Pallas ELL kernel in repro.kernels.
"""
from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _dense(key, path, fan_in, fan_out):
    k = jax.random.fold_in(key, zlib.crc32(path.encode()))
    return jax.random.normal(k, (fan_in, fan_out), jnp.float32) / np.sqrt(fan_in)


def init_gnn_params(model: str, dims: Sequence[int], key) -> Dict:
    """dims = [in, hidden, ..., out]; one layer per consecutive pair."""
    layers = []
    for l, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        p = {}
        if model == "gcn":
            p["w"] = _dense(key, f"l{l}/w", di, do)
            p["b"] = jnp.zeros((do,), jnp.float32)
        elif model == "sage":
            p["w_self"] = _dense(key, f"l{l}/ws", di, do)
            p["w_nbr"] = _dense(key, f"l{l}/wn", di, do)
            p["b"] = jnp.zeros((do,), jnp.float32)
        elif model == "gat":
            p["w"] = _dense(key, f"l{l}/w", di, do)
            p["a_src"] = _dense(key, f"l{l}/as", do, 1)[:, 0]
            p["a_dst"] = _dense(key, f"l{l}/ad", do, 1)[:, 0]
        elif model == "gin":
            p["w1"] = _dense(key, f"l{l}/w1", di, do)
            p["w2"] = _dense(key, f"l{l}/w2", do, do)
            p["eps"] = jnp.zeros(())
        else:
            raise ValueError(model)
        layers.append(p)
    return {"layers": layers}


def gnn_layer(model: str, p: Dict, A: jnp.ndarray, H_src: jnp.ndarray,
              self_idx: Optional[jnp.ndarray] = None, *, last: bool = False,
              aggregate: Callable = None) -> jnp.ndarray:
    """One layer. A [n_dst, n_src] (normalized); H_src [n_src, d_in];
    self_idx maps dst rows into src rows (for self features)."""
    agg = aggregate if aggregate is not None else (lambda A_, H_: A_ @ H_)
    H_self = H_src if self_idx is None else H_src[self_idx]
    if model == "gcn":
        z = agg(A, H_src) @ p["w"] + p["b"]
    elif model == "sage":
        z = H_self @ p["w_self"] + agg(A, H_src) @ p["w_nbr"] + p["b"]
    elif model == "gat":
        Hw_src = H_src @ p["w"]
        Hw_dst = H_self @ p["w"]
        e = (Hw_dst @ p["a_dst"])[:, None] + (Hw_src @ p["a_src"])[None, :]
        e = jax.nn.leaky_relu(e, 0.2)
        mask = A > 0
        e = jnp.where(mask, e, -1e30)
        att = jax.nn.softmax(e, axis=1)
        att = jnp.where(mask, att, 0.0)
        # Rows whose neighbors are ALL masked (isolated vertices, padded
        # rows) fall back to the self-loop Hw_dst instead of silently
        # emitting zeros — the padded-engine contract, and what the
        # distributed ELL GAT path computes for degree-0 rows.
        has_nbr = mask.any(axis=1, keepdims=True)
        z = jnp.where(has_nbr, att @ Hw_src, Hw_dst)
    elif model == "gin":
        z = ((1 + p["eps"]) * H_self + agg(A, H_src))
        z = jax.nn.relu(z @ p["w1"]) @ p["w2"]
    else:
        raise ValueError(model)
    return z if last else jax.nn.relu(z)


def full_graph_forward(model: str, params: Dict, A: jnp.ndarray, X: jnp.ndarray,
                       aggregate: Callable = None) -> jnp.ndarray:
    H = X
    L = len(params["layers"])
    for l, p in enumerate(params["layers"]):
        H = gnn_layer(model, p, A, H, self_idx=None, last=(l == L - 1),
                      aggregate=aggregate)
    return H


def minibatch_forward(model: str, params: Dict, layer_adj: List[jnp.ndarray],
                      self_indices: List[jnp.ndarray], X: jnp.ndarray) -> jnp.ndarray:
    H = X
    L = len(params["layers"])
    for l, p in enumerate(params["layers"]):
        H = gnn_layer(model, p, layer_adj[l], H, self_idx=self_indices[l],
                      last=(l == L - 1))
    return H


def padded_minibatch_forward(params: Dict, layer_adj: Sequence[jnp.ndarray],
                             X: jnp.ndarray, *, model: str = "gcn",
                             self_idx: Optional[Sequence[jnp.ndarray]] = None
                             ) -> jnp.ndarray:
    """Model-aware forward over statically PADDED dense sampled blocks (the
    DistGNNEngine mini-batch contract), delegating each layer to `gnn_layer`:
    self-loops are folded into the row-normalized blocks, so GCN is
    H <- A_l @ H @ W + b; sage/gin/gat read their RESIDENT self features
    through ``self_idx`` (self_idx[l] maps layer-(l+1) rows into layer-l rows
    — pad rows point at slot 0, inert because no real row ever reads a pad
    row: pad rows/cols of A_l are zero and real self_idx entries point at
    real slots).  Required for every model except gcn."""
    if model != "gcn" and self_idx is None:
        raise ValueError(f"model={model!r} needs self_idx (resident self "
                         "features); only gcn folds self into the blocks")
    H = X
    L = len(params["layers"])
    for l, p in enumerate(params["layers"]):
        si = None if self_idx is None else self_idx[l]
        H = gnn_layer(model, p, layer_adj[l], H, self_idx=si,
                      last=(l == L - 1))
    return H


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = lse - ll
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss.mean()


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if mask is not None:
        return (correct * mask).sum() / jnp.maximum(mask.sum(), 1)
    return correct.mean()
