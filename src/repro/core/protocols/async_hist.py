"""Asynchronous protocols with historical embeddings (survey §7.2): the three
staleness models (epoch-fixed, epoch-adaptive, variation-based) as pure,
jittable state machines, plus PipeGCN-style embedding+gradient staleness.

SPMD adaptation (DESIGN.md §2): true racing asynchrony does not exist under
jit; the staleness BOUND (the convergence-relevant property) is preserved by a
deterministic refresh schedule. Refresh decisions are computed with masks
(no data-dependent control flow), so everything stays one compiled program.

State layout: hist [V, D] historical embeddings; age [K] per-partition epochs
since refresh. `boundary_mask` [V] marks vertices whose CONSUMERS are remote —
only those ever read stale values (local reads are always fresh), exactly the
GA-stage semantics of Table 3.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HistoricalState:
    hist: jnp.ndarray  # [V, D]
    age: jnp.ndarray  # [K] int32 epochs since each partition's last push
    bytes_pushed: jnp.ndarray  # [] running comm counter (rows refreshed * D * 4)

    @staticmethod
    def create(V: int, D: int, K: int) -> "HistoricalState":
        return HistoricalState(jnp.zeros((V, D), jnp.float32),
                               jnp.zeros((K,), jnp.int32), jnp.zeros((), jnp.float32))


def _mix(h_new, hist, part_refreshed, assignment, boundary_mask):
    """Rows of refreshed partitions read fresh; stale boundary rows read hist;
    non-boundary rows are always fresh (they never cross the wire)."""
    fresh_row = part_refreshed[assignment] | (~boundary_mask)
    return jnp.where(fresh_row[:, None], h_new, hist)


def epoch_fixed_refresh(state: HistoricalState, h_new: jnp.ndarray, step: jnp.ndarray,
                        assignment: jnp.ndarray, boundary_mask: jnp.ndarray,
                        staleness: int) -> Tuple[jnp.ndarray, HistoricalState]:
    """DistGNN/PipeGCN (Table 3, epoch-fixed): every partition pushes every
    `staleness` epochs — bound |e - ẽ| <= staleness by construction."""
    K = state.age.shape[0]
    refresh = (step % staleness) == 0
    part_refreshed = jnp.broadcast_to(refresh, (K,))
    h_used = _mix(h_new, state.hist, part_refreshed, assignment, boundary_mask)
    rows = jnp.where(refresh, boundary_mask.sum(), 0)
    hist2 = jnp.where(refresh, h_new, state.hist)
    return h_used, HistoricalState(
        hist2, jnp.where(part_refreshed, 0, state.age + 1),
        state.bytes_pushed + rows * h_new.shape[1] * 4.0)


def epoch_adaptive_refresh(state: HistoricalState, h_new: jnp.ndarray, step: jnp.ndarray,
                           assignment: jnp.ndarray, boundary_mask: jnp.ndarray,
                           staleness: int) -> Tuple[jnp.ndarray, HistoricalState]:
    """DIGEST (epoch-adaptive): partitions push round-robin, 1/staleness of
    them per epoch — each partition's age stays <= staleness, but DIFFERENT
    partitions have different staleness within one epoch."""
    K = state.age.shape[0]
    part_refreshed = (jnp.arange(K) % staleness) == (step % staleness)
    # safety: anything that would exceed the bound refreshes too
    part_refreshed = part_refreshed | (state.age >= staleness - 1)
    h_used = _mix(h_new, state.hist, part_refreshed, assignment, boundary_mask)
    row_refresh = part_refreshed[assignment] & boundary_mask
    hist2 = jnp.where(row_refresh[:, None], h_new, state.hist)
    return h_used, HistoricalState(
        hist2, jnp.where(part_refreshed, 0, state.age + 1),
        state.bytes_pushed + row_refresh.sum() * h_new.shape[1] * 4.0)


def variation_refresh(state: HistoricalState, h_new: jnp.ndarray, step: jnp.ndarray,
                      assignment: jnp.ndarray, boundary_mask: jnp.ndarray,
                      eps: float, hard_bound: int = 4) -> Tuple[jnp.ndarray, HistoricalState]:
    """SANCUS skip-broadcast (variation-based): a partition pushes only when
    its embeddings drifted more than eps (relative Frobenius) from the last
    pushed version; a hard epoch bound keeps staleness finite.  The default
    bound is small (4): drift can sit just under eps for many epochs while the
    stale boundary rows quietly stall convergence — a loose bound (16) loses
    ~0.1 test accuracy on the SBM benchmark versus sync."""
    K = state.age.shape[0]
    diff = jnp.square(h_new - state.hist).sum(-1)  # [V]
    base = jnp.square(state.hist).sum(-1) + 1e-12
    drift_v = diff / base
    # per-partition mean drift over boundary rows
    w = boundary_mask.astype(jnp.float32)
    num = jnp.zeros((K,)).at[assignment].add(drift_v * w)
    den = jnp.zeros((K,)).at[assignment].add(w) + 1e-9
    part_drift = num / den
    part_refreshed = (part_drift > eps) | (state.age >= hard_bound)
    h_used = _mix(h_new, state.hist, part_refreshed, assignment, boundary_mask)
    row_refresh = part_refreshed[assignment] & boundary_mask
    hist2 = jnp.where(row_refresh[:, None], h_new, state.hist)
    return h_used, HistoricalState(
        hist2, jnp.where(part_refreshed, 0, state.age + 1),
        state.bytes_pushed + row_refresh.sum() * h_new.shape[1] * 4.0)


STALENESS_MODELS = {
    "epoch_fixed": epoch_fixed_refresh,
    "epoch_adaptive": epoch_adaptive_refresh,
    "variation": variation_refresh,
}


def block_refresh(protocol: str, hist_b: jnp.ndarray, h_b: jnp.ndarray,
                  age: jnp.ndarray, step: jnp.ndarray, bmask_b: jnp.ndarray,
                  part_id: jnp.ndarray, *, staleness: int = 2,
                  eps: float = 0.05, hard_bound: int = 4
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Block-local (one partition's rows) form of the staleness models, for the
    SPMD engine: every refresh decision here depends only on this partition's
    own rows, age and id, so the same function runs per-device inside
    shard_map AND vmapped over blocks in the single-device oracle — which is
    exactly what makes the engine oracle-checkable under asynchrony.

    hist_b/h_b [nb, D]; age [] int32; bmask_b [nb] bool; part_id [] int32.
    Returns (h_used_b, hist2_b, age2, rows_pushed).
    """
    if protocol == "epoch_fixed":
        refreshed = (step % staleness) == 0
        fresh_row = refreshed | (~bmask_b)
        h_used = jnp.where(fresh_row[:, None], h_b, hist_b)
        hist2 = jnp.where(refreshed, h_b, hist_b)  # full-block push
        rows = jnp.where(refreshed, bmask_b.sum(), 0)
    elif protocol == "epoch_adaptive":
        refreshed = ((part_id % staleness) == (step % staleness)) | (
            age >= staleness - 1)
        fresh_row = refreshed | (~bmask_b)
        h_used = jnp.where(fresh_row[:, None], h_b, hist_b)
        row_refresh = refreshed & bmask_b
        hist2 = jnp.where(row_refresh[:, None], h_b, hist_b)
        rows = row_refresh.sum()
    elif protocol == "variation":
        w = bmask_b.astype(jnp.float32)
        diff = jnp.square(h_b - hist_b).sum(-1)
        base = jnp.square(hist_b).sum(-1) + 1e-12
        drift = (diff / base * w).sum() / (w.sum() + 1e-9)
        refreshed = (drift > eps) | (age >= hard_bound)
        fresh_row = refreshed | (~bmask_b)
        h_used = jnp.where(fresh_row[:, None], h_b, hist_b)
        row_refresh = refreshed & bmask_b
        hist2 = jnp.where(row_refresh[:, None], h_b, hist_b)
        rows = row_refresh.sum()
    else:
        raise ValueError(f"unknown protocol {protocol!r}")
    age2 = jnp.where(refreshed, 0, age + 1).astype(age.dtype)
    return h_used, hist2, age2, rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PipeGCNState:
    """PipeGCN: both boundary embeddings AND boundary gradients come from the
    previous epoch (staleness exactly 1); carried per layer."""
    hist_h: jnp.ndarray  # [L, V, D]
    hist_g: jnp.ndarray  # [L, V, D]

    @staticmethod
    def create(L: int, V: int, D: int) -> "PipeGCNState":
        return PipeGCNState(jnp.zeros((L, V, D), jnp.float32),
                            jnp.zeros((L, V, D), jnp.float32))


@jax.custom_vjp
def pipegcn_mix(h_new, hist_h, hist_g, bmask_f):
    """Forward: boundary rows read last epoch's embeddings. Backward: boundary
    rows receive last epoch's GRADIENTS (hist_g), and the FRESH boundary
    cotangent is emitted on the hist_g gradient channel so the caller can
    harvest it as next epoch's state — both PipeGCN staleness points (GA and
    gradient-GA, survey Table 3) in one primitive."""
    b = bmask_f[:, None]
    return h_new * (1.0 - b) + hist_h * b


def _pipegcn_mix_fwd(h_new, hist_h, hist_g, bmask_f):
    return pipegcn_mix(h_new, hist_h, hist_g, bmask_f), (hist_g, bmask_f)


def _pipegcn_mix_bwd(res, ct):
    hist_g, bmask_f = res
    b = bmask_f[:, None]
    d_h_new = ct * (1.0 - b) + hist_g * b  # stale gradient injected
    d_hist_h = jnp.zeros_like(ct)
    d_hist_g = ct * b  # fresh boundary cotangent -> next epoch's hist_g
    return d_h_new, d_hist_h, d_hist_g, jnp.zeros_like(bmask_f)


pipegcn_mix.defvjp(_pipegcn_mix_fwd, _pipegcn_mix_bwd)
