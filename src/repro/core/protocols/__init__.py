from repro.core.protocols.async_hist import (
    STALENESS_MODELS,
    HistoricalState,
    PipeGCNState,
    block_refresh,
    epoch_adaptive_refresh,
    epoch_fixed_refresh,
    variation_refresh,
)
from repro.core.protocols.sync import (
    PROTOCOL_COSTS,
    ProtocolCost,
    broadcast_cost,
    p2p_cost,
    pipeline_cost,
    remote_partial_aggregation_cost,
    shared_memory_cost,
)
