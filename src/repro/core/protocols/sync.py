"""Synchronous communication protocols (survey §7.1) for full-graph training:
broadcast, selective P2P, pipeline (ring-overlap) — and byte accounting per
protocol so the benchmark tables reproduce the survey's comparisons.

The actual collective programs live in execution/spmm_models (the protocol is
what the SpMM execution model invokes); this module provides the protocol-
level planning + cost model shared by benchmarks and training.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.graph import Graph
from repro.core.partition.edge_cut import Partition

FEAT_BYTES = 4


@dataclasses.dataclass
class ProtocolCost:
    protocol: str
    bytes_per_layer: int
    messages_per_layer: int


def broadcast_cost(g: Graph, part: Partition, hidden_dim: int) -> ProtocolCost:
    """Every worker broadcasts its full H block to all others (CAGNET 1D):
    bytes = (k-1) * |V_i| * D summed over i."""
    k = part.num_parts
    sizes = np.bincount(part.assignment, minlength=k)
    total = int(((k - 1) * sizes).sum()) * hidden_dim * FEAT_BYTES
    return ProtocolCost("broadcast", total, k * (k - 1))


def p2p_cost(g: Graph, part: Partition, hidden_dim: int) -> ProtocolCost:
    """Only boundary vertices cross the wire (ParallelGCN/DistGNN)."""
    total_rows = part.communication_volume(g)
    msgs = 0
    for i in range(part.num_parts):
        bnd = part.boundary_vertices(g, i)
        msgs += len(np.unique(part.assignment[bnd])) if len(bnd) else 0
    return ProtocolCost("p2p", total_rows * hidden_dim * FEAT_BYTES, msgs)


def pipeline_cost(g: Graph, part: Partition, hidden_dim: int,
                  num_chunks: int = 4) -> ProtocolCost:
    """Pipeline = P2P bytes, but in num_chunks stages whose communication
    overlaps the previous chunk's partial aggregation (G3/SAR): same volume,
    latency hidden — we report the volume and the stage count."""
    base = p2p_cost(g, part, hidden_dim)
    return ProtocolCost("pipeline", base.bytes_per_layer,
                        base.messages_per_layer * num_chunks)


def remote_partial_aggregation_cost(g: Graph, part: Partition,
                                    hidden_dim: int) -> ProtocolCost:
    """DeepGalois/DistGNN cd-0: aggregate remote chunks at the OWNER, ship one
    partial sum per (vertex, remote-worker) pair instead of every neighbor."""
    pairs = 0
    for v in range(g.num_vertices):
        owners = np.unique(part.assignment[g.neighbors(v)])
        pairs += max(0, len(owners) - 1)
    return ProtocolCost("remote_partial_agg", pairs * hidden_dim * FEAT_BYTES, pairs)


def shared_memory_cost(g: Graph, part: Partition, hidden_dim: int,
                       pcie_ratio: float = 0.25) -> ProtocolCost:
    """ROC/NeuGraph: all embeddings live in host memory; every layer streams
    each partition's working set over PCIe — bytes = full frontier, but no
    network. We report PCIe bytes scaled by relative bandwidth for comparison."""
    total = g.num_vertices * hidden_dim * FEAT_BYTES
    return ProtocolCost("shared_memory", int(total / max(pcie_ratio, 1e-9)),
                        part.num_parts)


PROTOCOL_COSTS = {
    "broadcast": broadcast_cost,
    "p2p": p2p_cost,
    "pipeline": pipeline_cost,
    "remote_partial_agg": remote_partial_aggregation_cost,
    "shared_memory": shared_memory_cost,
}
