"""Graph containers and deterministic synthetic graph generators.

CSR on the host (numpy) for partitioning/sampling; ELLPACK and dense forms for
device compute (the TPU adaptation: padded neighbor lists -> MXU-friendly
tiles, see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    indptr: np.ndarray  # [V+1] int64
    indices: np.ndarray  # [E] int32 (in-neighbors of each vertex)
    num_vertices: int
    features: Optional[np.ndarray] = None  # [V, D] float32
    labels: Optional[np.ndarray] = None  # [V] int32
    train_mask: Optional[np.ndarray] = None
    val_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def out_degree(self) -> np.ndarray:
        """In this container `indices` are in-neighbors; out-degree counts how
        often a vertex appears as someone's in-neighbor."""
        return np.bincount(self.indices, minlength=self.num_vertices).astype(np.int64)

    # -- device formats -----------------------------------------------------
    def to_dense_adj(self, normalized: bool = True) -> np.ndarray:
        V = self.num_vertices
        A = np.zeros((V, V), np.float32)
        for v in range(V):
            A[v, self.neighbors(v)] = 1.0
        if normalized:
            A = A + np.eye(V, dtype=np.float32)
            d = A.sum(1)
            dinv = 1.0 / np.sqrt(np.maximum(d, 1.0))
            A = dinv[:, None] * A * dinv[None, :]
        return A

    def to_ell(self, max_deg: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """ELLPACK: (neighbor_ids [V, K] int32 padded with V, mask [V, K])."""
        deg = self.degree()
        K = int(max_deg or deg.max() or 1)
        ids = np.full((self.num_vertices, K), self.num_vertices, np.int32)
        mask = np.zeros((self.num_vertices, K), bool)
        for v in range(self.num_vertices):
            nb = self.neighbors(v)[:K]
            ids[v, : len(nb)] = nb
            mask[v, : len(nb)] = True
        return ids, mask

    def subgraph(self, vertices: np.ndarray) -> Tuple["Graph", np.ndarray]:
        """Induced subgraph; returns (sub, mapping old->new (-1 outside))."""
        vertices = np.asarray(vertices)
        remap = np.full(self.num_vertices, -1, np.int64)
        remap[vertices] = np.arange(len(vertices))
        indptr = [0]
        idx = []
        for v in vertices:
            nb = self.neighbors(v)
            nb = remap[nb]
            nb = nb[nb >= 0]
            idx.append(nb)
            indptr.append(indptr[-1] + len(nb))
        sub = Graph(
            indptr=np.asarray(indptr, np.int64),
            indices=(np.concatenate(idx).astype(np.int32) if idx and indptr[-1] else
                     np.zeros((0,), np.int32)),
            num_vertices=len(vertices),
            features=None if self.features is None else self.features[vertices],
            labels=None if self.labels is None else self.labels[vertices],
            train_mask=None if self.train_mask is None else self.train_mask[vertices],
        )
        return sub, remap


def from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int, **kw) -> Graph:
    """Build CSR of in-neighbors: edge (u -> v) stores u in v's list."""
    order = np.argsort(dst, kind="stable")
    src, dst = np.asarray(src)[order], np.asarray(dst)[order]
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(indptr=indptr, indices=src.astype(np.int32),
                 num_vertices=num_vertices, **kw)


def _attach(g: Graph, feature_dim: int, num_classes: int, train_frac: float,
            rng: np.random.Generator) -> Graph:
    V = g.num_vertices
    # features correlated with labels so GNNs can actually learn
    labels = rng.integers(0, num_classes, V).astype(np.int32)
    centers = rng.standard_normal((num_classes, feature_dim)).astype(np.float32)
    g.features = (centers[labels] + 0.5 * rng.standard_normal((V, feature_dim))).astype(np.float32)
    g.labels = labels
    masks = rng.random(V)
    g.train_mask = masks < train_frac
    g.val_mask = (masks >= train_frac) & (masks < train_frac + 0.1)
    g.test_mask = masks >= train_frac + 0.1
    return g


def powerlaw_graph(num_vertices: int, avg_degree: int = 8, feature_dim: int = 32,
                   num_classes: int = 8, train_frac: float = 0.3, seed: int = 0) -> Graph:
    """Preferential-attachment-ish power-law graph (the degree skew that makes
    GNN workload balance hard — survey challenge #3)."""
    rng = np.random.default_rng(seed)
    m = max(avg_degree // 2, 1)
    # vectorized BA approximation: each new vertex attaches to m targets drawn
    # from the current edge-endpoint multiset (preferential) or uniform.
    targets = list(range(min(m + 1, num_vertices)))
    src, dst = [], []
    pool = list(targets)
    for v in range(len(targets), num_vertices):
        pool_arr = np.asarray(pool)
        pick = rng.choice(pool_arr, size=min(m, len(pool_arr)), replace=False)
        for u in np.unique(pick):
            src.append(int(u)), dst.append(v)
            src.append(v), dst.append(int(u))
            pool.extend([int(u), v])
    g = from_edges(np.asarray(src), np.asarray(dst), num_vertices)
    return _attach(g, feature_dim, num_classes, train_frac, rng)


def sbm_graph(num_vertices: int, num_blocks: int = 4, p_in: float = 0.05,
              p_out: float = 0.002, feature_dim: int = 32, num_classes: int = 0,
              train_frac: float = 0.3, seed: int = 0) -> Graph:
    """Stochastic block model — ground-truth communities for partition tests."""
    rng = np.random.default_rng(seed)
    block = rng.integers(0, num_blocks, num_vertices)
    src, dst = [], []
    # sample by block pair (vectorized bernoulli on index grids, sparse regime)
    for bi in range(num_blocks):
        vi = np.where(block == bi)[0]
        for bj in range(num_blocks):
            vj = np.where(block == bj)[0]
            p = p_in if bi == bj else p_out
            n_try = rng.binomial(len(vi) * len(vj), p)
            if n_try == 0:
                continue
            s = rng.choice(vi, n_try)
            d = rng.choice(vj, n_try)
            keep = s != d
            src.append(s[keep])
            dst.append(d[keep])
    src = np.concatenate(src) if src else np.zeros(0, np.int64)
    dst = np.concatenate(dst) if dst else np.zeros(0, np.int64)
    g = from_edges(src, dst, num_vertices)
    g = _attach(g, feature_dim, num_classes or num_blocks, train_frac, rng)
    g.labels = block.astype(np.int32)  # labels = communities
    return g


def er_graph(num_vertices: int, avg_degree: int = 8, feature_dim: int = 16,
             num_classes: int = 4, train_frac: float = 0.3, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    E = num_vertices * avg_degree
    src = rng.integers(0, num_vertices, E)
    dst = rng.integers(0, num_vertices, E)
    keep = src != dst
    g = from_edges(src[keep], dst[keep], num_vertices)
    return _attach(g, feature_dim, num_classes, train_frac, rng)
