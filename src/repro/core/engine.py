"""DistGNNEngine: the survey's four technique families composed into ONE
jitted shard_map training step.

  model (§3)       a selectable `model` axis — {gcn, sage, gat, gin} — the
                   GNN layer program every jitted path (full-graph and
                   mini-batch, edge-cut and vertex-cut, all execution
                   models) runs.  The survey's challenges are
                   model-dependent and the axis makes that concrete:
                   sage/gin's self-feature terms read the RESIDENT block
                   (zero extra wire bytes over gcn); gat's edge-wise
                   attention changes what crosses the wire — the exchange
                   ships TRANSFORMED rows plus a per-row attention
                   coefficient (a_src . Hw), per-edge logits ride the
                   Pallas SDDMM kernel over the ELL structure, and the
                   masked segment-softmax keeps pad slots inert; under
                   vertex_cut the softmax normalizer is exactified across
                   replicas by a two-pass (max, then sum) replica sync.
  partition (§4)   a selectable `partition_family` axis:
                     edge_cut   — a partitioner assigns VERTICES to devices;
                                  the engine relabels vertices so device d
                                  owns the contiguous padded block
                                  [d*nb, (d+1)*nb) — the partition plan IS
                                  the device layout.  Neighbor values cross
                                  the wire (halo exchange).
                     vertex_cut — a cut assigns EDGES to devices; vertices
                                  replicate (partition/vertex_layout.py turns
                                  the cut into per-device owned-edge ELL
                                  blocks + replica slot tables).  Each device
                                  computes PARTIAL aggregations over its
                                  owned edges; partials are combined across
                                  replicas by the replica-sync exchange
                                  (execution/replica_sync.py) — broadcast /
                                  ring / master-based two-phase p2p GAS —
                                  and the loss (hence the weight-gradient
                                  psum) is masked to each vertex's MASTER
                                  replica so nothing double-counts.  The
                                  wire volume is bounded by the replication
                                  factor, the §4.2 lever for skewed graphs.
                     hybrid     — the PowerLyra-style degree-threshold cut
                                  (partition/hybrid_cut.py): low-degree
                                  vertices stay edge-cut-local behind a
                                  halo exchange while hubs (degree >=
                                  `hub_threshold`, default auto p95)
                                  replicate with the replica-sync GAS —
                                  only the heavy tail pays the replication
                                  tax.  threshold=inf/0 degenerate to the
                                  pure families exactly.
                   The families live behind partition/layout_api.py
                   (`PartitionLayout` owns slot tables, exchange constants,
                   master masking, reference wiring, byte accounting) and
                   execution/exchange_api.py (`ExchangeBackend` owns the
                   per-layer aggregate/attention/combine dataflow); the
                   engine itself is family-free dispatch, and a new family
                   is one layout class + one backend + a registry entry.
  batch (§5)       a selectable `batching` axis:
                     full_graph — each device's partition block is its batch
                                  (PSGD-style ownership, loss masked to owned
                                  train vertices and globally psum-reduced);
                     node_wise / layer_wise / subgraph — sampled mini-batches:
                                  each device draws targets from its OWNED
                                  partition block, expands them host-side with
                                  the §5 samplers, and pads the layered blocks
                                  to static caps derived from the fanout
                                  config, so the jitted shard_map step
                                  compiles ONCE per fanout config (not per
                                  batch).  Input features for the sampled
                                  frontier are fetched through the same
                                  execution models as the full-graph path,
                                  short-circuited by a device-resident
                                  feature cache (sampling/cache.py policies);
                                  hit/miss bytes are counted against
                                  CommStats via the standalone
                                  feature_fetch_bytes cost model.
  execution (§6)   the local multiply is the Pallas ELL SpMM
                   (repro.kernels.ell_spmm, differentiable via transpose
                   scatter-add VJP); the neighbor exchange is a selectable
                   execution model:
                     broadcast — all_gather of the full H (CAGNET 1D),
                     ring      — ppermute rotation with per-source-block
                                 partial aggregation (SAR/chunk pipeline),
                     p2p       — halo exchange: only the boundary rows each
                                 destination actually needs cross the wire
                                 (all_to_all on a static partition plan,
                                 optionally split into power-of-two BUCKETED
                                 installments so the lowered send buffers
                                 stay small — cfg.p2p_buckets).
                   The exchange is PIPELINED two ways (§6-§7 overlap,
                   execution/pipeline_exchange.py): ``exchange_chunks`` > 1
                   feature-chunks the broadcast/p2p collectives so chunk
                   c+1's collective flies while chunk c feeds the ELL
                   multiply (peak gathered-table bytes O(V*D/chunks)), and
                   ``run_epoch_minibatch(schedule="pipelined")`` overlaps
                   host sampling/extraction with the device step through a
                   background prefetch worker (sampling/prefetch.py) —
                   bitwise-identical to the blocking path, faster on the
                   wall.
  protocol (§7)    sync (fresh embeddings every layer) or async historical
                   embeddings with a bounded-staleness model (epoch_fixed /
                   epoch_adaptive / variation), applied block-locally so the
                   SPMD step and the single-device oracle share the exact
                   same refresh math (protocols.async_hist.block_refresh).

Every configuration is oracle-checkable: `reference_step` runs the identical
math on one device (vmapping the per-block protocol over the block axis), so
multi-device runs must match it to float tolerance — the engine's contract,
enforced by tests/test_engine_distributed.py.  The mini-batch path has the
same contract: `reference_minibatch_step` consumes the exact same sampled,
padded batches (host sampling is deterministic in (seed, step, device)), so
every sampler x execution x cache combination must match it to <=1e-4 —
enforced by tests/test_engine_minibatch.py.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import interpret_default, shard_map
from repro.core.execution.exchange_api import make_backend
from repro.core.execution.pipeline_exchange import (
    bucketed_all_to_all,
    bucketed_cap_widths,
    chunked_overlap,
    zero_pad_row,
)
from repro.core.execution.replica_sync import (
    reference_combine,
    reference_combine_max,
)
from repro.core.feature_store import (
    FeatureStore,
    overlay_refresh_plan,
)
from repro.core.graph import Graph
from repro.core.models.gnn import init_gnn_params, padded_minibatch_forward
from repro.core.partition.edge_cut import Partition
from repro.core.partition.layout_api import (
    ENGINE_MIRROR_ATTRS,
    get_layout_builder,
)
from repro.core.protocols.async_hist import block_refresh
from repro.core.sampling.cache import CACHE_POLICIES, device_cache_ids
from repro.core.sampling.distributed import CommStats
from repro.core.sampling.host_batch import HostBatchBuilder
from repro.core.sampling.partition_batch import p2p_frontier_halo_cap
from repro.core.sampling.samplers import frontier_caps
from repro.core.telemetry import Telemetry
from repro.kernels.ell_spmm import ell_attend, ell_spmm
from repro.optim.sparse_optim import row_adamw_update, sparse_adamw_ids
from repro.kernels.ref import sddmm_ref
from repro.kernels.sddmm import sddmm_ell

EXECUTION_MODELS = ("broadcast", "ring", "p2p")
GNN_MODELS = ("gcn", "sage", "gat", "gin")
PROTOCOLS = ("sync", "epoch_fixed", "epoch_adaptive", "variation")
BATCHING_MODES = ("full_graph", "node_wise", "layer_wise", "subgraph")
PARTITION_FAMILIES = ("edge_cut", "vertex_cut", "hybrid")
ENGINE_CACHE_POLICIES = ("none",) + tuple(CACHE_POLICIES)


@dataclasses.dataclass
class EngineConfig:
    execution: str = "p2p"  # broadcast | ring | p2p
    protocol: str = "sync"  # sync | epoch_fixed | epoch_adaptive | variation
    model: str = "gcn"  # gcn | sage | gat | gin — the GNN layer program.
    #   sage/gin read their self features from the RESIDENT block (never on
    #   the wire); gat ships transformed rows + the per-row attention
    #   coefficient (a_src . Hw) through the exchange and runs a masked
    #   segment-softmax over the ELL slots (for vertex_cut: a two-pass
    #   max-then-sum replica sync so the normalizer is exact across replicas)
    partition_family: str = "edge_cut"  # edge_cut | vertex_cut | hybrid —
    #   each family is a partition/layout_api.py PartitionLayout paired with
    #   an execution/exchange_api.py backend (hybrid: PowerLyra-style
    #   degree-threshold cut, partition/hybrid_cut.py)
    partitioner: str = "metis_like"  # edge_cut/hybrid: any key of PARTITIONERS
    vertex_cut: str = "cartesian2d"  # vertex_cut: any key of VERTEX_CUTS
    hub_threshold: Optional[float] = None  # hybrid: vertices with in-degree
    #   >= threshold replicate (vertex-cut class); below it they stay
    #   edge-cut-local behind the halo.  None -> the 95th-percentile
    #   in-degree (partition/hybrid_cut.auto_hub_threshold); np.inf -> pure
    #   edge-cut dataflow, 0 -> pure (src-replicating) vertex-cut
    sorted_masters: bool = False  # vertex_cut: order each device's replica
    #   slots master-first (contiguous prefix), so master-masked host reads
    #   slice instead of scanning a boolean mask — a layout option the
    #   autotuner weighs; bitwise-equivalent training math
    batching: str = "full_graph"  # full_graph | node_wise | layer_wise | subgraph
    batch_size: int = 16  # per-device targets (node/layer-wise) or walk roots
    fanouts: Tuple[int, ...] = (4, 4)  # node_wise; len == num_layers
    layer_sizes: Tuple[int, ...] = (32, 32)  # layer_wise; len == num_layers
    walk_length: int = 4  # subgraph random walk
    cache_policy: str = "none"  # none | any key of sampling CACHE_POLICIES
    cache_capacity: int = 0  # remote feature rows resident per device
    exchange_chunks: int = 1  # feature-dim chunks: overlap collective c+1
    #   with the ELL multiply of chunk c (1 = monolithic exchange)
    p2p_buckets: int = 1  # power-of-two installments splitting the p2p
    #   all_to_all send caps (1 = single max-pairwise-need buffer); applies
    #   to the full-graph halo plan, the replica-sync plan, AND the
    #   mini-batch frontier fetch (per-batch occupancy rides a static
    #   bucket layout: row t of a pair's need list always lands in
    #   installment t // w, so shapes never change across batches)
    prefetch_depth: int = 2  # batches the pipelined epoch samples ahead
    prefetch_mode: str = "thread"  # thread | process — who runs the
    #   pipelined producer.  "thread": the in-process `PrefetchWorker`
    #   (overlap capacity-limited by the GIL).  "process": a
    #   `ProcPrefetchPool` of sampling processes feeding a shared-memory
    #   batch ring (sampling/proc_prefetch.py) — GIL-free, scales across
    #   cores, still bitwise-identical to the blocking schedules
    num_sample_workers: int = 2  # process-pool size for prefetch_mode=process
    trainable_features: bool = False  # layer-0 rows are LEARNABLE embeddings:
    #   the owner-sharded feature shard moves from the step's constants into
    #   its state and a row-sparse AdamW (optim/sparse_optim.py) updates ONLY
    #   the rows the step touched — all owned real rows under full_graph, the
    #   frontier's owner rows under mini-batch (master-masked under
    #   vertex_cut so replicas never double-update; the masters' deltas are
    #   re-broadcast through the replica sync so copies never drift).
    #   Requires protocol='sync' (historical embeddings of a moving layer-0
    #   table are a ROADMAP follow-up).
    embed_lr: float = 0.1  # sparse-AdamW hyperparams for the embedding rows
    embed_b1: float = 0.9
    embed_b2: float = 0.999
    embed_eps: float = 1e-8
    embed_weight_decay: float = 0.0
    hidden: int = 32
    num_layers: int = 2
    lr: float = 0.5
    staleness: int = 2
    eps_v: float = 0.05
    hard_bound: int = 4
    seed: int = 0
    use_pallas: bool = True  # False: pure-jnp gather (debug / tiny graphs)
    interpret: Optional[bool] = None  # Pallas interpret mode; None = auto


class DistGNNEngine:
    """Builds the device layout + exchange plan from (graph, mesh, config) and
    exposes a jitted distributed train step plus its single-device oracle."""

    def __init__(self, g: Graph, mesh: Optional[Mesh] = None,
                 cfg: Optional[EngineConfig] = None,
                 partition: Optional[Partition] = None):
        self.cfg = cfg = cfg or EngineConfig()
        if cfg.execution not in EXECUTION_MODELS:
            raise ValueError(f"execution must be one of {EXECUTION_MODELS}")
        if cfg.model not in GNN_MODELS:
            raise ValueError(f"model must be one of {GNN_MODELS}")
        if cfg.protocol not in PROTOCOLS:
            raise ValueError(f"protocol must be one of {PROTOCOLS}")
        if cfg.batching not in BATCHING_MODES:
            raise ValueError(f"batching must be one of {BATCHING_MODES}")
        if cfg.cache_policy not in ENGINE_CACHE_POLICIES:
            raise ValueError(
                f"cache_policy must be one of {ENGINE_CACHE_POLICIES}")
        if cfg.batching != "full_graph" and cfg.protocol != "sync":
            raise ValueError(
                "mini-batch training supports protocol='sync' only: the "
                "historical-embedding protocols are full-graph state")
        if cfg.trainable_features and cfg.protocol != "sync":
            raise ValueError(
                "trainable_features requires protocol='sync': the "
                "historical-embedding protocols cache layer outputs of a "
                "FROZEN layer-0 table; staleness bounds for a moving "
                "embedding table are a ROADMAP follow-up")
        if cfg.exchange_chunks < 1:
            raise ValueError("exchange_chunks must be >= 1")
        if cfg.p2p_buckets < 1:
            raise ValueError("p2p_buckets must be >= 1")
        if cfg.prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        if cfg.prefetch_mode not in ("thread", "process"):
            raise ValueError("prefetch_mode must be 'thread' or 'process'")
        if cfg.num_sample_workers < 1:
            raise ValueError("num_sample_workers must be >= 1")
        if cfg.partition_family not in PARTITION_FAMILIES:
            raise ValueError(
                f"partition_family must be one of {PARTITION_FAMILIES}")
        builder = get_layout_builder(cfg.partition_family)
        builder.validate(cfg, partition=partition)
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("w",))
        if len(mesh.axis_names) != 1:
            raise ValueError("DistGNNEngine wants a 1D mesh (one axis over "
                             f"all devices); got axes {mesh.axis_names}")
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.k = int(np.prod(mesh.devices.shape))
        self.g = g
        self.interpret = (interpret_default() if cfg.interpret is None
                          else cfg.interpret)
        # the partition family builds its layout (slot tables, exchange-plan
        # constants, masking, accounting) behind the PartitionLayout
        # interface; the engine mirrors the engine-facing attributes so
        # downstream code (mini-batch planner, drivers, tests) keeps reading
        # eng.<attr>, and dispatches the traced exchange to the family's
        # ExchangeBackend
        lay = self.playout = builder(g, self.k, cfg, partition=partition)
        for name in ENGINE_MIRROR_ATTRS:
            if hasattr(lay, name):
                setattr(self, name, getattr(lay, name))
        self.backend = make_backend(self)
        num_classes = int(g.labels.max()) + 1
        self.dims = ([g.features.shape[1]]
                     + [cfg.hidden] * (cfg.num_layers - 1) + [num_classes])
        # CommStats field -> wire bytes ONE full-graph step accrues (each
        # entry mirrors the family's standalone cost model exactly)
        self._wire_fields = lay.wire_fields_per_step(cfg.model, self.dims)
        if cfg.trainable_features and cfg.batching == "full_graph":
            # layer-0 gradient routing per step (the transpose of one
            # exchange pass at width dims[0]); mirrors the standalone
            # cost_models.embedding_grad_bytes_per_step exactly
            self._emb_bytes_per_step = lay.embed_grad_bytes(self.dims)
        self._step = None
        self._ref_step = None
        self._mb_step = None
        self._mb_ref_step = None
        self._infer_step = None
        self._ref_infer = None
        self.comm_stats = CommStats()
        # off by default: no-op spans/metrics until enable_telemetry()
        self.telemetry = Telemetry(enabled=False)
        if cfg.batching != "full_graph":
            self._build_minibatch_plan()

    # ------------------------------------------------------------------
    # shared layer math
    # ------------------------------------------------------------------

    def _ell(self, ids, mask, table):
        """sum_k mask[v,k] * table[ids[v,k]] — the Pallas ELL kernel (or its
        jnp oracle): the local multiply AND the replica-combine reduction."""
        if self.cfg.use_pallas:
            return ell_spmm(ids, mask, table, normalize=False,
                            interpret=self.interpret)
        return (mask[..., None] * jnp.take(table, ids, axis=0)).sum(1)

    def _ell_attend(self, ids, w, table):
        """sum_k w[v,k] * table[ids[v,k]] with gradients to BOTH w and table —
        the GAT aggregation (`_ell`'s VJP treats the mask as structure, but
        attention coefficients are a function of the params)."""
        if self.cfg.use_pallas:
            return ell_attend(ids, w, table, interpret=self.interpret)
        return (w[..., None] * jnp.take(table, ids, axis=0)).sum(1)

    def _sddmm(self, ids, mask, table, a_src, a_dst):
        """Masked GAT edge logits over the ELL structure (Pallas SDDMM or its
        jnp oracle); dst row v must be table row v (prefix contract)."""
        if self.cfg.use_pallas:
            return sddmm_ell(ids, mask, table, a_src, a_dst,
                             interpret=self.interpret)
        return sddmm_ref(ids, mask, table, a_src, a_dst)

    @staticmethod
    def _combine(model, p_l, nbr, h_self, last: bool):
        """Model-specific combine of the aggregated neighbor rows with the
        RESIDENT self rows — shared verbatim by the distributed step and the
        single-device oracle (gat has its own program: the aggregation
        itself is attention-weighted).  sage/gin read h_self straight from
        the local block, so the model axis adds ZERO exchange bytes over
        gcn — the §4 locality argument the cost models encode."""
        if model == "gcn":
            z = (nbr + h_self) @ p_l["w"] + p_l["b"]
        elif model == "sage":
            z = h_self @ p_l["w_self"] + nbr @ p_l["w_nbr"] + p_l["b"]
        elif model == "gin":
            z = jax.nn.relu(
                ((1.0 + p_l["eps"]) * h_self + nbr) @ p_l["w1"]) @ p_l["w2"]
        else:
            raise ValueError(model)
        return z if last else jax.nn.relu(z)

    @staticmethod
    def _gat_softmax(e_masked):
        """Masked segment-softmax pieces over ELL slots: (weights, den) from
        logits already masked to -1e30.  Rows with no real slots get
        den == 0 (the caller falls back to the self row — the same contract
        as the dense `gnn_layer` isolated-row fallback).  The stabilizer is
        stop_gradient'd: softmax is shift-invariant, so treating it as a
        constant gives the exact gradient without transposing the max."""
        m = jax.lax.stop_gradient(jnp.max(e_masked, axis=1, keepdims=True))
        pw = jnp.exp(e_masked - m) * (e_masked > -1e29)
        return pw, pw.sum(1, keepdims=True)

    def _protocol_kwargs(self):
        c = self.cfg
        return dict(staleness=c.staleness, eps=c.eps_v, hard_bound=c.hard_bound)

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------

    def init_state(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        params = init_gnn_params(self.cfg.model, self.dims, key)
        L = len(self.dims) - 1
        state = dict(
            params=params,
            step=jnp.zeros((), jnp.int32),
            hist=tuple(jnp.zeros((self.Vp, d), jnp.float32)
                       for d in self.dims[1:]),
            age=jnp.zeros((L, self.k), jnp.int32),
        )
        # Pre-place with the step's output shardings so feeding the state
        # back in reuses the ONE compiled executable (same contract as
        # init_minibatch_state; enforced by the vertex-cut recompile guard).
        from jax.sharding import NamedSharding
        ax = self.axis
        rep = NamedSharding(self.mesh, P())
        row = NamedSharding(self.mesh, P(ax))  # == P(ax, None) for 2D, but
        shardings = dict(                      # spelled how the step emits it
            params=jax.tree_util.tree_map(lambda _: rep, params),
            step=rep,
            hist=tuple(row for _ in range(L)),
            age=NamedSharding(self.mesh, P(None, ax)),
        )
        if self.cfg.trainable_features:
            # the embedding table (the store's device view) and its owner-
            # sharded sparse-AdamW moments live in the STATE, not the consts
            state["embed"] = self.X
            state["emb_m"] = jnp.zeros_like(self.X)
            state["emb_v"] = jnp.zeros_like(self.X)
            state["emb_t"] = jnp.zeros((self.Vp,), jnp.int32)
            shardings.update(embed=row, emb_m=row, emb_v=row, emb_t=row)
        return jax.device_put(state, shardings)

    # ------------------------------------------------------------------
    # distributed step
    # ------------------------------------------------------------------

    def _model_layer_local(self, p_l, H, consts_local, last: bool):
        """One model-aware layer of the distributed forward (device-local
        under shard_map), dispatched to the partition family's
        ExchangeBackend (execution/exchange_api.py): gat runs the backend's
        attention program; everyone else is the backend's
        exchange-aggregate + the shared `_combine`."""
        if self.cfg.model == "gat":
            return self.backend.gat_layer(p_l, H, consts_local, last)
        nbr = self.backend.aggregate(H, consts_local)
        return self._combine(self.cfg.model, p_l, nbr, H, last)

    def _forward_local(self, params, hist, age, step, consts_local, X=None):
        """Full local forward with protocol mixing; returns (logits_local,
        new_hist, new_age, rows_pushed).  ``X`` overrides the layer-0 rows
        (the trainable-embedding path differentiates through it)."""
        c = self.cfg
        ax = self.axis
        H = consts_local["X"] if X is None else X
        L = len(self.dims) - 1
        me = jax.lax.axis_index(ax)
        new_hist, new_age, pushed = [], [], jnp.zeros((), jnp.float32)
        for l, p_l in enumerate(params["layers"]):
            H = self._model_layer_local(p_l, H, consts_local,
                                        last=(l == L - 1))
            if c.protocol != "sync":
                h_used, h2, a2, rows = block_refresh(
                    c.protocol, hist[l], H, age[l][0], step,
                    consts_local["bmask"], me, **self._protocol_kwargs())
                H = h_used
                new_hist.append(h2)
                new_age.append(a2[None])
                pushed = pushed + rows.astype(jnp.float32)
            else:
                new_hist.append(hist[l])
                new_age.append(age[l])
        return H, tuple(new_hist), jnp.stack(new_age), pushed

    def _embed_hparams(self):
        c = self.cfg
        return dict(lr=c.embed_lr, b1=c.embed_b1, b2=c.embed_b2,
                    eps=c.embed_eps, weight_decay=c.embed_weight_decay)

    def _embed_update_full(self, emb, g_emb, state, cl):
        """Full-graph sparse-AdamW embedding update (device-local under
        shard_map): masked-dense over the owned shard — the touched set is
        static (every real owned row; vertex masters under vertex_cut), so
        the mask form costs exactly the touched rows in moment traffic and
        leaves untouched rows (pads / non-masters) bitwise unchanged.

        Replica families (vertex_cut / hybrid with an active sync): g_emb is
        each replica's PARTIAL gradient; the backend's combine_rows turns it
        into the full vertex gradient, the update applies at MASTER slots
        only (moments live at masters), and the masters' deltas are
        re-broadcast through the same sync — a sum with one nonzero
        contribution, so every replica adds the bitwise-same delta and the
        copies never drift.  combine_rows is the identity for single-replica
        families, so the code is family-agnostic."""
        touched = cl["emb_touched"]
        g_emb = self.backend.combine_rows(g_emb, cl)
        emb2, m2, v2, t2 = row_adamw_update(
            emb, g_emb, state["emb_m"], state["emb_v"], state["emb_t"],
            touched, **self._embed_hparams())
        if self.backend.has_replicas:
            delta = (emb2 - emb) * touched[:, None]
            delta_all = self.backend.combine_rows(delta, cl)
            emb2 = emb + delta_all
        return dict(embed=emb2, emb_m=m2, emb_v=v2, emb_t=t2)

    def make_step(self):
        """The jitted distributed train step: state -> (state, metrics)."""
        if self._step is not None:
            return self._step
        ax = self.axis
        c = self.cfg
        L = len(self.dims) - 1

        consts = dict(X=self.X, y=self.y, w=self.train_w, bmask=self.bmask,
                      deg=self.deg)
        consts.update(self.playout.exchange_consts())
        if c.trainable_features:
            # layer-0 rows come from state["embed"]; the touched mask is the
            # static full-graph batch (real owned rows / vertex masters)
            del consts["X"]
            consts["emb_touched"] = jnp.asarray(self.emb_touched)
        # every const shards its LEADING axis (device-stacked plan tables or
        # owner-partitioned rows) and replicates the rest — the layout
        # contract every family's tables are built to
        shard = {key: P(*((ax,) + (None,) * (jnp.ndim(a) - 1)))
                 for key, a in consts.items()}
        state_specs = dict(
            params=P(), step=P(),
            hist=tuple(P(ax, None) for _ in range(L)),
            age=P(None, ax))
        if c.trainable_features:
            state_specs.update(embed=P(ax, None), emb_m=P(ax, None),
                               emb_v=P(ax, None), emb_t=P(ax))

        def local_step(state, consts_local):
            params, step_i = state["params"], state["step"]
            hist, age = state["hist"], state["age"]
            # squeeze the device axis off per-device-stacked plan tables
            cl = dict(consts_local)
            for key in self.playout.squeeze_keys:
                cl[key] = cl[key][0]
            age_l = [age[l] for l in range(L)]

            # Differentiate the LOCAL loss numerator only: the psum-normalized
            # loss is assembled outside the grad.  Transposing a psum under
            # shard_map is version-dependent (0.4.x transposes psum->psum and
            # double-counts by k; the check_vma rework transposes to identity);
            # the collectives inside the forward (all_gather / all_to_all /
            # ppermute) have stable, well-defined transposes on all supported
            # versions, so grads of the local numerator are portable.
            def num_fn(p, X_l):
                logits, new_hist, new_age, pushed = self._forward_local(
                    p, hist, age_l, step_i, cl, X=X_l)
                lse = jax.scipy.special.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(
                    logits, cl["y"][:, None], axis=-1)[:, 0]
                num = ((lse - ll) * cl["w"]).sum()
                return num, (logits, new_hist, new_age, pushed)

            if c.trainable_features:
                # Differentiating w.r.t. the layer-0 rows rides the SAME
                # stable collective transposes: g_X arrives already summed
                # over every device that read the row (all_gather ->
                # reduce-scatter etc.), i.e. the owner's total gradient — no
                # psum, which would double-count it.
                (num, (logits, new_hist, new_age, pushed)), (grads, g_X) = (
                    jax.value_and_grad(num_fn, argnums=(0, 1), has_aux=True)(
                        params, state["embed"]))
            else:
                (num, (logits, new_hist, new_age, pushed)), grads = (
                    jax.value_and_grad(num_fn, has_aux=True)(
                        params, cl["X"]))
            den = jnp.maximum(jax.lax.psum(cl["w"].sum(), ax), 1.0)
            loss = jax.lax.psum(num, ax) / den
            grads = jax.tree_util.tree_map(
                lambda g_: jax.lax.psum(g_, ax) / den, grads)
            params2 = jax.tree_util.tree_map(
                lambda p_, g_: p_ - c.lr * g_, params, grads)
            state2 = dict(params=params2, step=step_i + 1,
                          hist=new_hist, age=new_age)
            if c.trainable_features:
                state2.update(self._embed_update_full(
                    state["embed"], g_X / den, state, cl))
            metrics = dict(loss=loss,
                           rows_pushed=jax.lax.psum(pushed, ax))
            return state2, metrics, logits

        smapped = shard_map(
            local_step, mesh=self.mesh,
            in_specs=(state_specs, shard),
            out_specs=(state_specs, dict(loss=P(), rows_pushed=P()),
                       P(ax, None)),
            check_vma=False)

        @jax.jit
        def step(state, consts_):
            new_state, metrics, logits = smapped(state, consts_)
            return new_state, metrics, logits

        self._consts = consts
        self._jit_step = step
        self._step = lambda state: step(state, self._consts)
        return self._step

    def lower_step(self, state=None):
        """Lower (without running) the distributed step — for dry-runs that
        record memory/collective artifacts at scale."""
        self.make_step()
        state = state if state is not None else self.init_state()
        return self._jit_step.lower(state, self._consts)

    # ------------------------------------------------------------------
    # single-device oracle
    # ------------------------------------------------------------------

    def _make_reference_layer(self):
        """Single-device reference layer math, shared by the oracle train
        step and reference inference: global ELL gather (for vertex_cut:
        per-replica partials + a scatter-add combine over the global vertex
        space).  Returns ``layer_ref(p_l, H, last)`` over the padded [Vp]
        space."""
        c = self.cfg
        k, nb, Vp = self.k, self.nb, self.Vp
        ids_g = jnp.asarray(self.ids_global.astype(np.int32))
        mask, deg = self.mask, self.deg
        # replica families expose their [k, n] slot->global-vertex table; a
        # non-None table switches the combine to the scatter-based reference
        ref_vids = self.playout.ref_vert_ids
        if ref_vids is not None:
            vert_ids_ref = jnp.asarray(ref_vids.astype(np.int32))  # pad = V
            Vg = self.g.num_vertices

        def gat_layer_ref(p_l, H, last):
            """The GAT layer on one device: identical formulas to the
            distributed path, with the replica combines replaced by their
            scatter-based references for replica families."""
            Hw = H @ p_l["w"]
            table = jnp.concatenate([Hw, jnp.zeros((1, Hw.shape[1]),
                                                   Hw.dtype)], 0)
            e = self._sddmm(ids_g, mask, table, p_l["a_src"], p_l["a_dst"])
            if ref_vids is not None:
                m_loc = jnp.maximum(jnp.max(e, axis=1, keepdims=True), 0.0)
                M = jax.lax.stop_gradient(reference_combine_max(
                    m_loc.reshape(k, nb, 1), vert_ids_ref, Vg
                ).reshape(Vp, 1))
                pw = jnp.exp(e - M) * (e > -1e29)
                part = jnp.concatenate(
                    [(pw[..., None] * jnp.take(table, ids_g, axis=0)).sum(1),
                     pw.sum(1, keepdims=True)], 1)
                comb = reference_combine(part.reshape(k, nb, -1),
                                         vert_ids_ref, Vg).reshape(Vp, -1)
                num, den = comb[:, :-1], comb[:, -1:]
            else:
                pw, den = self._gat_softmax(e)
                num = (pw[..., None] * jnp.take(table, ids_g, axis=0)).sum(1)
            z = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), Hw)
            return z if last else jax.nn.relu(z)

        def layer_ref(p_l, H, last):
            if c.model == "gat":
                return gat_layer_ref(p_l, H, last)
            table = jnp.concatenate(
                [H, jnp.zeros((1, H.shape[1]), H.dtype)], 0)
            gathered = (mask[..., None]
                        * jnp.take(table, ids_g, axis=0)).sum(1)
            if ref_vids is not None:
                gathered = reference_combine(
                    gathered.reshape(k, nb, -1), vert_ids_ref, Vg
                ).reshape(Vp, -1)
            return self._combine(c.model, p_l, gathered / deg, H, last=last)

        return layer_ref

    def make_reference_step(self):
        """Identical math on one device: the shared reference layer
        (`_make_reference_layer`) + the same block_refresh vmapped over the
        k blocks."""
        if self._ref_step is not None:
            return self._ref_step
        c = self.cfg
        k, nb, Vp = self.k, self.nb, self.Vp
        L = len(self.dims) - 1
        layer_ref = self._make_reference_layer()
        X, y, w, bmask = self.X, self.y, self.train_w, self.bmask
        ref_vids = self.playout.ref_vert_ids
        if ref_vids is not None:
            vert_ids_ref = jnp.asarray(ref_vids.astype(np.int32))  # pad = V
            Vg = self.g.num_vertices

        def forward(params, hist, age, step_i, X_in=None):
            H = X if X_in is None else X_in
            new_hist, new_age = [], []
            pushed = jnp.zeros((), jnp.float32)
            for l, p_l in enumerate(params["layers"]):
                H = layer_ref(p_l, H, last=(l == L - 1))
                if c.protocol != "sync":
                    h_blocks = H.reshape(k, nb, -1)
                    hist_blocks = hist[l].reshape(k, nb, -1)
                    bm_blocks = bmask.reshape(k, nb)
                    h_used, h2, a2, rows = jax.vmap(
                        lambda hb, histb, ab, pidb, bmb: block_refresh(
                            c.protocol, histb, hb, ab, step_i, bmb, pidb,
                            **self._protocol_kwargs()))(
                        h_blocks, hist_blocks, age[l], jnp.arange(k), bm_blocks)
                    H = h_used.reshape(Vp, -1)
                    new_hist.append(h2.reshape(Vp, -1))
                    new_age.append(a2)
                    pushed = pushed + rows.sum().astype(jnp.float32)
                else:
                    new_hist.append(hist[l])
                    new_age.append(age[l])
            return H, tuple(new_hist), jnp.stack(new_age), pushed

        if c.trainable_features:
            touched_ref = jnp.asarray(self.emb_touched)

        def ref_combine_rows(rows):
            """Replica combine in the flattened replica space — the oracle's
            counterpart of the replica-sync passes in _embed_update_full."""
            return reference_combine(rows.reshape(k, nb, -1), vert_ids_ref,
                                     Vg).reshape(Vp, -1)

        @jax.jit
        def ref_step(state):
            params, step_i = state["params"], state["step"]

            def loss_fn(p, X_in):
                logits, new_hist, new_age, pushed = forward(
                    p, state["hist"], state["age"], step_i, X_in)
                lse = jax.scipy.special.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
                loss = ((lse - ll) * w).sum() / jnp.maximum(w.sum(), 1.0)
                return loss, (logits, new_hist, new_age, pushed)

            if c.trainable_features:
                (loss, (logits, new_hist, new_age, pushed)), (grads, g_X) = (
                    jax.value_and_grad(loss_fn, argnums=(0, 1),
                                       has_aux=True)(params, state["embed"]))
            else:
                (loss, (logits, new_hist, new_age, pushed)), grads = (
                    jax.value_and_grad(loss_fn, has_aux=True)(params, X))
            params2 = jax.tree_util.tree_map(
                lambda p_, g_: p_ - c.lr * g_, params, grads)
            state2 = dict(params=params2, step=step_i + 1,
                          hist=new_hist, age=new_age)
            if c.trainable_features:
                emb = state["embed"]
                if self.playout.has_replicas:
                    g_X = ref_combine_rows(g_X)
                emb2, m2, v2, t2 = row_adamw_update(
                    emb, g_X, state["emb_m"], state["emb_v"],
                    state["emb_t"], touched_ref, **self._embed_hparams())
                if self.playout.has_replicas:
                    delta = (emb2 - emb) * touched_ref[:, None]
                    emb2 = emb + ref_combine_rows(delta)
                state2.update(embed=emb2, emb_m=m2, emb_v=v2, emb_t=t2)
            return state2, dict(loss=loss, rows_pushed=pushed), logits

        self._ref_step = ref_step
        return ref_step

    # ------------------------------------------------------------------
    # serving: layer-wise full-graph inference (the throughput tier)
    # ------------------------------------------------------------------

    def make_infer_step(self):
        """The jitted layer-wise full-graph inference sweep: compute layer l
        for ALL vertices before layer l+1 — the production answer to neighbor
        explosion (embeddings for every vertex in O(L) exchange sweeps, no
        fanout blow-up).  Reuses the training exchange per layer (the
        family's ExchangeBackend under `_model_layer_local`: chunked
        double-buffered broadcast/p2p, ring scan, replica sync);
        layer-0 rows arrive as an ARGUMENT so the sweep reads the live
        FeatureStore (or a trainable state's embed table) without retracing.

        Inference is protocol-free: it serves fresh activations, never the
        async history (stale serving reads are a ROADMAP item-4 follow-up).
        """
        if self._infer_step is not None:
            return self._infer_step
        ax = self.axis
        c = self.cfg
        L = len(self.dims) - 1

        consts = dict(deg=self.deg)
        consts.update(self.playout.exchange_consts())
        shard = {key: P(*((ax,) + (None,) * (jnp.ndim(a) - 1)))
                 for key, a in consts.items()}

        def local_infer(params, X_local, consts_local):
            # squeeze the device axis off per-device plans (as in local_step)
            cl = dict(consts_local)
            for key in self.playout.squeeze_keys:
                cl[key] = cl[key][0]
            H = X_local
            for l, p_l in enumerate(params["layers"]):
                H = self._model_layer_local(p_l, H, cl, last=(l == L - 1))
            return H

        smapped = shard_map(local_infer, mesh=self.mesh,
                            in_specs=(P(), P(ax, None), shard),
                            out_specs=P(ax, None), check_vma=False)

        @jax.jit
        def istep(params, X, consts_):
            return smapped(params, X, consts_)

        self._infer_consts = consts
        self._jit_infer = istep
        self._infer_step = lambda params, X: istep(params, X, consts)
        return self._infer_step

    def _layer0_table(self, state=None):
        """Layer-0 rows for inference: the trainable embed table when the
        features are learnable, else a LIVE read through the FeatureStore
        (rows published via `store.update_rows` / `publish_embeddings` flow
        into the next sweep — no dense re-materialization, no retrace)."""
        if self.cfg.trainable_features:
            if state is None or "embed" not in state:
                raise ValueError(
                    "trainable_features: inference reads layer-0 rows from "
                    "the train state's embed table — pass state=")
            return state["embed"]
        return self.store.device_table()

    def infer_full_graph(self, state=None, *, params=None, reference=False):
        """Owner-partitioned final-layer embeddings for EVERY vertex, [Vp, C]
        (edge_cut: the contiguous relabeled blocks; vertex_cut: replica slots,
        masters authoritative — `global_embeddings` maps either back to the
        original vertex ids).  One call = one O(L) layer-wise sweep; wire
        bytes are accounted into CommStats.inference_bytes and cross-checked
        against `cost_models.inference_bytes_per_sweep` by the serving tier.

        `reference=True` runs the bitwise-independent single-device oracle
        (shared `_make_reference_layer` math) instead of the jitted
        distributed sweep."""
        if params is None:
            if state is None or "params" not in state:
                raise ValueError("infer_full_graph needs params= or a train "
                                 "state with a 'params' entry")
            params = state["params"]
        X = self._layer0_table(state)
        if reference:
            if self._ref_infer is None:
                layer_ref = self._make_reference_layer()
                L = len(self.dims) - 1

                @jax.jit
                def ref_infer(p, X_in):
                    H = X_in
                    for l, p_l in enumerate(p["layers"]):
                        H = layer_ref(p_l, H, last=(l == L - 1))
                    return H

                self._ref_infer = ref_infer
            return self._ref_infer(params, X)
        with self.telemetry.span("infer_sweep"):
            out = self.make_infer_step()(params, X)
            with self._account_exchange("inference", None, None):
                self.comm_stats.inference_bytes += \
                    self.inference_bytes_per_sweep()
        return out

    def inference_bytes_per_sweep(self) -> int:
        """Wire bytes of one layer-wise sweep — the engine-side mirror of
        `cost_models.inference_bytes_per_sweep` (forward-only: one exchange
        per layer at that layer's model-dependent width, nothing back).
        Exactly the layout's per-step wire fields summed: a sweep runs the
        same L exchange passes a training forward runs."""
        return int(sum(self._wire_fields.values()))

    def global_embeddings(self, H) -> np.ndarray:
        """Map owner-partitioned padded embeddings [Vp, D] back to the
        ORIGINAL vertex ids, [V, D] (layout-specific: edge_cut inverts the
        contiguous relabel; replica families read each vertex's master
        replica row)."""
        return self.playout.global_embeddings(np.asarray(H))

    def publish_embeddings(self, state) -> None:
        """Serving handoff for trainable features: write the trained layer-0
        rows back into the FeatureStore (and refresh any attached overlay
        snapshot), so engines/serving tiers built on this store — including a
        non-trainable clone — read the TRAINED table.  Host-side, out of the
        jitted path."""
        emb = np.asarray(state["embed"], np.float32)
        if emb.shape != (self.store.num_rows, self.store.dim):
            raise ValueError(f"embed table {emb.shape} != store "
                             f"{(self.store.num_rows, self.store.dim)}")
        self.store.update_rows(np.arange(self.store.num_rows), emb)
        if self.store._overlay_ids is not None:
            self.store.refresh_overlay()
            if getattr(self, "_cache_table", None) is not None:
                self._cache_table = jnp.asarray(self.store.overlay_table())
        self.X = self.store.device_table()

    # ------------------------------------------------------------------
    # mini-batch path (§5 batch generation wired into the jitted step)
    # ------------------------------------------------------------------

    def _build_minibatch_plan(self):
        """Static mini-batch plan: frontier caps from the fanout config (ONE
        jit compile per config), plus the per-device resident feature cache
        (remote hot rows picked by a sampling/cache.py policy; exact, never
        stale — input features are constant during training)."""
        c, g, k = self.cfg, self.g, self.k
        L = c.num_layers
        self.caps = frontier_caps(
            c.batching, L, c.batch_size, fanouts=c.fanouts,
            layer_sizes=c.layer_sizes, walk_length=c.walk_length,
            num_vertices=g.num_vertices)
        # p2p halo slots per (dst, src) pair: bounded by the MEASURED halo —
        # the largest single-owner share of any destination's hops-hop
        # in-neighborhood — instead of the worst case caps[0] (every frontier
        # row remote from one owner), which blows the all_to_all buffer up by
        # orders of magnitude at scale (ROADMAP follow-up from PR 2)
        self.fcap = self.caps[0]
        if c.execution == "p2p":
            hops = c.walk_length if c.batching == "subgraph" else c.num_layers
            self.fcap = p2p_frontier_halo_cap(g, self.part, hops, self.caps[0])
            # power-of-two installments over the measured halo cap (the PR-4
            # bucketing, applied to the frontier fetch): row t of a pair's
            # per-batch need list always lands in installment t // w at
            # offset t % w, so bucket occupancy varies per batch but the
            # lowered all_to_all operands stay [k, w] — static shapes, ONE
            # compile, send buffers ~buckets x smaller than the single
            # monolithic fcap buffer
            self.fcap_widths = bucketed_cap_widths(self.fcap, c.p2p_buckets)
        D = g.features.shape[1]
        self.Ccap = Ccap = max(int(c.cache_capacity), 1)
        self.cache_old_ids = []
        self._cache_slot = []  # per device: old global id -> cache row
        self._cache_set = []
        for d in range(k):
            ids_d = device_cache_ids(g, self.part.assignment, d,
                                     c.cache_policy, c.cache_capacity)
            self.cache_old_ids.append(ids_d)
            self._cache_slot.append({int(v): j for j, v in enumerate(ids_d)})
            self._cache_set.append(frozenset(int(v) for v in ids_d))
        # the cache is a hot-row OVERLAY on the feature store: per-device
        # pinned remote store rows.  Frozen features: a build-time snapshot
        # (exact forever).  Trainable: the snapshot would go stale, so the
        # jitted step re-gathers the overlay rows from the LIVE owner shards
        # every step through a static bucketed all_to_all plan (whose
        # transpose routes cache-hit gradients back to the owners).
        overlay_sids = [self.new_of_old[ids_d].astype(np.int64)
                        for ids_d in self.cache_old_ids]
        self.store.attach_overlay(overlay_sids, Ccap)
        self._cache_table = jnp.asarray(self.store.overlay_table())
        self._has_overlay = any(len(a) for a in overlay_sids)
        if c.trainable_features:
            if self._has_overlay:
                ov_send, ov_tab, self._ov_widths = overlay_refresh_plan(
                    overlay_sids, k, self.nb, Ccap, buckets=c.p2p_buckets)
                self._ov_send = jnp.asarray(ov_send)
                self._ov_tab = jnp.asarray(ov_tab)
            # touched-row cap: per owner, at most every one of its rows, and
            # at most one per frontier slot across all k devices
            self.tcap = min(self.nb, k * self.caps[0])
        # The host-side sample+extract stages live in a PICKLABLE numpy-only
        # builder: the engine delegates to it in-process, and the process
        # prefetcher (prefetch_mode="process") ships a copy (graph swapped
        # for a shared-memory handle) to each sampling worker — one code
        # path, so pooled epochs are bitwise-identical by construction.
        self.host_builder = HostBatchBuilder(
            batching=c.batching, execution=c.execution, seed=c.seed,
            batch_size=c.batch_size, fanouts=tuple(c.fanouts),
            layer_sizes=tuple(c.layer_sizes), walk_length=c.walk_length,
            num_layers=L, trainable_features=c.trainable_features,
            k=k, nb=self.nb, caps=tuple(int(x) for x in self.caps),
            fcap=int(self.fcap),
            fcap_widths=(tuple(int(x) for x in self.fcap_widths)
                         if c.execution == "p2p" else None),
            Ccap=Ccap, tcap=int(getattr(self, "tcap", 0)), feature_dim=D,
            assignment=self.part.assignment, new_of_old=self.new_of_old,
            labels=np.asarray(g.labels),
            train_mask=(None if g.train_mask is None
                        else np.asarray(g.train_mask)),
            cache_slots=self._cache_slot, cache_sets=self._cache_set,
            overlay_rows=tuple(len(a) for a in self.cache_old_ids),
            graph=g)

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------

    def enable_telemetry(self, telemetry: Optional[Telemetry] = None
                         ) -> Telemetry:
        """Attach an ENABLED `core.telemetry.Telemetry` (or the one passed
        in) and return it.  Spans wrap the host-side stage boundaries only —
        nothing inside the jitted step changes — and every CommStats
        mutation from here on is mirrored into labeled ``comm.*`` counters
        plus instant ``exchange`` spans carrying the wire-byte delta (their
        sum equals ``CommStats.total()`` exactly for a fresh run).  Also
        seeds the imbalance report with the static per-device layout gauges
        (owned edges/vertices, replica rows) and threads the instance into
        the FeatureStore's overlay counters."""
        tel = telemetry if telemetry is not None else Telemetry()
        self.telemetry = tel
        self.store.telemetry = tel
        if not tel.enabled:
            return tel
        self.playout.telemetry_gauges(tel)
        return tel

    @contextlib.contextmanager
    def _account_exchange(self, stage: str, step, device):
        """Mirror the CommStats deltas accrued inside this block into
        labeled ``comm.<field>`` counters and one instant ``exchange`` span
        whose ``bytes`` label is the WIRE delta (cache hits excluded) — the
        invariant the trace contract asserts: summed exchange-span bytes ==
        ``CommStats.total()``."""
        tel = self.telemetry
        if not tel.enabled:
            yield
            return
        s = self.comm_stats
        before = {f.name: getattr(s, f.name)
                  for f in dataclasses.fields(CommStats)}
        wire0 = s.total()
        yield
        labels = {} if device is None else {"device": device}
        for name, v0 in before.items():
            dv = getattr(s, name) - v0
            if dv:
                tel.counter("comm." + name, **labels).add(dv)
        mark = dict(stage=stage, bytes=s.total() - wire0, **labels)
        if step is not None:
            mark["step"] = step
        tel.instant("exchange", **mark)

    def _sample_host(self, step_idx: int):
        """Host sampling stage, delegated to the picklable
        `sampling.host_batch.HostBatchBuilder` (the same object the
        process-pool prefetcher ships to its workers, so in-process and
        pooled epochs run literally the same code).  Deterministic in
        (seed, step, device) so the oracle — and any rerun, in any process —
        regenerates bitwise-identical batches."""
        return self.host_builder.sample(
            step_idx, span_factory=self.telemetry.span)

    def _make_batch(self, mbs, step=None) -> Dict:
        """Extract stage: the builder pads/relabels/builds the fetch plan in
        numpy; `_finish_batch` ingests the result (CommStats + telemetry
        accounting, jnp conversion) — the same ingest the process-pooled
        epoch runs on arrays arriving from shared memory."""
        arrays, meta = self.host_builder.extract(mbs, step=step)
        return self._finish_batch(arrays, meta, step=step)

    def _finish_batch(self, arrays, meta, step=None) -> Dict:
        """Ingest one extracted batch: apply the per-device CommStats byte
        deltas inside `_account_exchange` (identical counters/spans whether
        the batch was built inline or by a worker process), mirror frontier
        occupancy + overlay hit/miss telemetry, and convert the flat numpy
        arrays to the jnp batch the jitted step consumes."""
        c, L = self.cfg, self.cfg.num_layers
        tel = self.telemetry
        for d, dd in enumerate(meta["per_device"]):
            with self._account_exchange("extract", step, d):
                for name, dv in dd["stats"].items():
                    setattr(self.comm_stats, name,
                            getattr(self.comm_stats, name) + dv)
            if tel.enabled:
                tel.gauge("frontier_occupancy", device=d).set(dd["occupancy"])
                self.store.count_overlay(
                    d, hits=dd["cache_hits"],
                    misses=dd["remote"] - dd["cache_hits"])
        batch = dict(
            frontier=jnp.asarray(arrays["frontier"]),
            y=jnp.asarray(arrays["y"]), w=jnp.asarray(arrays["w"]),
            adj=tuple(jnp.asarray(arrays[f"adj{l}"]) for l in range(L)),
            self_idx=tuple(jnp.asarray(arrays[f"self_idx{l}"])
                           for l in range(L)),
            cache_ids=jnp.asarray(arrays["cache_ids"]))
        for key in ("bc_ids", "ring_ids", "send_rows", "tab_ids", "emb_ids"):
            if key in arrays:
                batch[key] = jnp.asarray(arrays[key])
        return batch

    def sample_minibatch(self, step_idx: int) -> Dict:
        """sample + extract: one static-shape device batch for `step_idx`."""
        tel = self.telemetry
        with tel.span("sample", step=step_idx):
            mbs = self._sample_host(step_idx)
        with tel.span("extract", step=step_idx):
            return self._make_batch(mbs, step=step_idx)

    def _check_minibatch_runnable(self):
        """Validate the config ONCE at epoch entry: the constructor already
        rejects mini-batch + async-history configs, but a config mutated
        after construction (or an engine driven past a stale reference)
        would otherwise die deep inside jit with an opaque shape error."""
        c = self.cfg
        if c.batching == "full_graph":
            raise ValueError(
                "batching='full_graph' has no mini-batch epoch; use train() "
                "/ make_step(), or rebuild the engine with a sampled "
                "batching mode (node_wise | layer_wise | subgraph)")
        if c.protocol != "sync":
            raise ValueError(
                f"mini-batch training supports protocol='sync' only, but "
                f"this engine's config now has protocol={c.protocol!r} "
                f"(changed after construction?).  The historical-embedding "
                f"protocols keep full-graph state that sampled batches "
                f"cannot refresh — rebuild the engine with protocol='sync', "
                f"or use batching='full_graph' to train with "
                f"{c.protocol!r}.")

    def init_minibatch_state(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        state = dict(params=init_gnn_params(self.cfg.model, self.dims, key),
                     step=jnp.zeros((), jnp.int32))
        # Pre-place replicated, matching the step's output sharding — so
        # feeding the state back in reuses the ONE compiled executable
        # (the recompile-count contract in tests/test_engine_minibatch.py).
        from jax.sharding import NamedSharding
        state = jax.device_put(state, NamedSharding(self.mesh, P()))
        if self.cfg.trainable_features:
            # layer-0 rows are parameters: the store table plus owner-sharded
            # sparse-AdamW moments and per-row step counts
            mat = NamedSharding(self.mesh, P(self.axis, None))
            row = NamedSharding(self.mesh, P(self.axis))
            state["embed"] = jax.device_put(self.X, mat)
            state["emb_m"] = jax.device_put(jnp.zeros_like(self.X), mat)
            state["emb_v"] = jax.device_put(jnp.zeros_like(self.X), mat)
            state["emb_t"] = jax.device_put(
                jnp.zeros((self.Vp,), jnp.int32), row)
        return state

    def _overlay_rows_live(self, X_local, cl):
        """Re-gather this device's overlay rows from the LIVE owner shards
        (trainable_features): the static bucketed all_to_all refresh plan —
        one extra exchange per step whose transpose routes cache-hit
        gradients back to the owners' embedding shards."""
        recv = bucketed_all_to_all(X_local, cl["ov_send"], self.axis, self.k)
        tab = jnp.concatenate([X_local, recv, zero_pad_row(X_local)], 0)
        return jnp.take(tab, cl["ov_tab"], axis=0)  # [Ccap, D]

    def _fetch_frontier(self, X_local, cache_rows, bl):
        """Device-local frontier feature fetch under shard_map: resident-cache
        reads plus the execution-model exchange for the misses.  Every valid
        frontier slot is covered by exactly one of the two (the other reads a
        zero row), so the sum is exact.  ``cache_rows`` is the [Ccap, D]
        overlay table (the static snapshot, or the live-refreshed rows under
        trainable_features), or None when no cache is configured.  The
        broadcast/p2p exchanges are feature-chunked like the full-graph
        backend aggregate when ``exchange_chunks`` > 1 (the frontier
        gather consumes chunk c while chunk c+1's collective flies)."""
        ax, k, nb = self.axis, self.k, self.nb
        C = self.cfg.exchange_chunks
        D = X_local.shape[1]
        if cache_rows is None:
            F = jnp.zeros((bl["cache_ids"].shape[0], D), X_local.dtype)
        else:
            ctab = jnp.concatenate(
                [cache_rows, zero_pad_row(cache_rows)], 0)
            F = jnp.take(ctab, bl["cache_ids"], axis=0)
        if self.cfg.execution == "broadcast":
            def exchange(hc):
                h_full = jax.lax.all_gather(hc, ax, axis=0, tiled=True)
                return jnp.concatenate([h_full, zero_pad_row(hc)], 0)

            return F + chunked_overlap(
                X_local, C, exchange,
                lambda tab: jnp.take(tab, bl["bc_ids"], axis=0))
        if self.cfg.execution == "ring":
            me = jax.lax.axis_index(ax)
            # the zero pad row is concatenated ONCE and rotates with the
            # block (every device appends zeros, so slot nb stays zero)
            tab0 = jnp.concatenate([X_local, zero_pad_row(X_local)], 0)

            def ring_step(carry, r):
                acc, tab_cur = carry
                owner = (me + r) % k
                ids_r = jnp.take(bl["ring_ids"], owner, axis=0)
                acc = acc + jnp.take(tab_cur, ids_r, axis=0)
                tab_nxt = jax.lax.ppermute(
                    tab_cur, ax, [(i, (i - 1) % k) for i in range(k)])
                return (acc, tab_nxt), None

            acc0 = jnp.zeros((bl["cache_ids"].shape[0], D), X_local.dtype)
            (acc, _), _ = jax.lax.scan(ring_step, (acc0, tab0),
                                       jnp.arange(k))
            return F + acc

        # p2p: ship only the rows each destination's misses actually need,
        # in the power-of-two bucketed installments (send operand [k, w]
        # per round instead of one monolithic [k, fcap] buffer)
        def exchange(hc):
            recv = bucketed_all_to_all(hc, bl["send_rows"], ax, k)
            return jnp.concatenate([hc, recv, zero_pad_row(hc)], 0)

        return F + chunked_overlap(
            X_local, C, exchange,
            lambda tab: jnp.take(tab, bl["tab_ids"], axis=0))

    def make_minibatch_step(self):
        """The jitted distributed mini-batch step: (state, batch) ->
        (state, metrics, target logits [k, cap_L, C]).  Batch arrays have
        static shapes from the fanout caps, so this compiles exactly once."""
        if self._mb_step is not None:
            return self._mb_step
        if self.cfg.batching == "full_graph":
            raise ValueError("batching='full_graph' has no mini-batch step; "
                             "use make_step()")
        ax, c, k, L = self.axis, self.cfg, self.k, self.cfg.num_layers

        if c.trainable_features:
            # the feature plane lives in STATE (store rows are parameters);
            # the cache snapshot is replaced by the live overlay refresh plan
            consts, cshard = {}, {}
            if self._has_overlay:
                consts["ov_send"] = self._ov_send
                consts["ov_tab"] = self._ov_tab
                cshard["ov_send"] = P(ax, None, None, None)
                cshard["ov_tab"] = P(ax, None)
        else:
            consts = dict(X=self.X, cache=self._cache_table)
            cshard = dict(X=P(ax, None), cache=P(ax, None, None))
        bspec = dict(frontier=P(ax, None), y=P(ax, None), w=P(ax, None),
                     adj=tuple(P(ax, None, None) for _ in range(L)),
                     self_idx=tuple(P(ax, None) for _ in range(L)),
                     cache_ids=P(ax, None))
        if c.execution == "broadcast":
            bspec["bc_ids"] = P(ax, None)
        elif c.execution == "ring":
            bspec["ring_ids"] = P(ax, None, None)
        else:
            bspec["send_rows"] = P(ax, None, None, None)
            bspec["tab_ids"] = P(ax, None)
        state_spec = dict(params=P(), step=P())
        if c.trainable_features:
            bspec["emb_ids"] = P(ax, None)
            state_spec.update(embed=P(ax, None), emb_m=P(ax, None),
                              emb_v=P(ax, None), emb_t=P(ax))
        nb = self.nb

        def local_step(state, consts_local, batch_local):
            params, step_i = state["params"], state["step"]
            bl = {key: (tuple(a[0] for a in v) if isinstance(v, tuple)
                        else v[0]) for key, v in batch_local.items()}
            if c.trainable_features:
                cl = {key: consts_local[key][0] for key in consts_local}

                # the fetch moves INSIDE the differentiated function: the
                # collectives' transposes route each frontier row's cotangent
                # back to its owner's embedding shard (all_gather ->
                # psum_scatter, ppermute -> inverse ppermute, all_to_all ->
                # reversed all_to_all), so g_X arrives pre-summed across
                # devices — the owner's TOTAL gradient, no extra psum
                def num_fn(p, X_l):
                    cache_rows = (self._overlay_rows_live(X_l, cl)
                                  if self._has_overlay else None)
                    F = self._fetch_frontier(X_l, cache_rows, bl)
                    logits = padded_minibatch_forward(
                        p, list(bl["adj"]), F, model=c.model,
                        self_idx=list(bl["self_idx"]))
                    lse = jax.scipy.special.logsumexp(logits, axis=-1)
                    ll = jnp.take_along_axis(
                        logits, bl["y"][:, None], axis=-1)[:, 0]
                    return ((lse - ll) * bl["w"]).sum(), logits

                (num, logits), (grads, g_X) = jax.value_and_grad(
                    num_fn, argnums=(0, 1), has_aux=True)(
                        params, state["embed"])
            else:
                X_l = consts_local["X"]
                cache_l = consts_local["cache"][0]
                F = self._fetch_frontier(X_l, cache_l, bl)
                # Differentiate the LOCAL loss numerator only (same rationale
                # as the full-graph step); the fetch above is outside the
                # grad, so the grad path is collective-free and portable.
                def num_fn(p):
                    logits = padded_minibatch_forward(
                        p, list(bl["adj"]), F, model=c.model,
                        self_idx=list(bl["self_idx"]))
                    lse = jax.scipy.special.logsumexp(logits, axis=-1)
                    ll = jnp.take_along_axis(
                        logits, bl["y"][:, None], axis=-1)[:, 0]
                    return ((lse - ll) * bl["w"]).sum(), logits

                (num, logits), grads = jax.value_and_grad(
                    num_fn, has_aux=True)(params)
            den = jnp.maximum(jax.lax.psum(bl["w"].sum(), ax), 1.0)
            loss = jax.lax.psum(num, ax) / den
            grads = jax.tree_util.tree_map(
                lambda g_: jax.lax.psum(g_, ax) / den, grads)
            params2 = jax.tree_util.tree_map(
                lambda p_, g_: p_ - c.lr * g_, params, grads)
            state2 = dict(params=params2, step=step_i + 1)
            if c.trainable_features:
                # scatter-update ONLY this owner's touched rows: emb_ids row
                # d (sorted distinct local rows any device's frontier read,
                # sentinel nb) against the pre-summed owner gradient
                ids = bl["emb_ids"]
                g_rows = jnp.take(
                    g_X, jnp.where(ids < nb, ids, 0), axis=0) / den
                emb2, m2, v2, t2 = sparse_adamw_ids(
                    state["embed"], state["emb_m"], state["emb_v"],
                    state["emb_t"], ids, g_rows, valid=ids < nb,
                    **self._embed_hparams())
                state2.update(embed=emb2, emb_m=m2, emb_v=v2, emb_t=t2)
            return state2, dict(loss=loss), logits[None]

        smapped = shard_map(
            local_step, mesh=self.mesh,
            in_specs=(state_spec, cshard, bspec),
            out_specs=(state_spec, dict(loss=P()), P(ax, None, None)),
            check_vma=False)

        @jax.jit
        def step(state, consts_, batch):
            return smapped(state, consts_, batch)

        self._mb_consts = consts
        self._jit_mb_step = step
        self._mb_step = lambda state, batch: step(state, self._mb_consts, batch)
        return self._mb_step

    def lower_minibatch_step(self, state=None, batch=None):
        """Lower (without running) the mini-batch step — dry-runs at scale."""
        self.make_minibatch_step()
        state = state if state is not None else self.init_minibatch_state()
        batch = batch if batch is not None else self.sample_minibatch(0)
        return self._jit_mb_step.lower(state, self._mb_consts, batch)

    def make_reference_minibatch_step(self):
        """Single-device oracle: the identical padded batches, features read
        straight from the global table, forward vmapped over the k device
        blocks — multi-device runs must match to float tolerance."""
        if self._mb_ref_step is not None:
            return self._mb_ref_step
        c = self.cfg
        k, nb = self.k, self.nb
        D = self.g.features.shape[1]
        zrow = jnp.zeros((1, D), self.X.dtype)
        table0 = jnp.concatenate([self.X, zrow], 0)

        def batch_loss(p, F, batch):
            logits = jax.vmap(
                lambda f, adjs, sidx: padded_minibatch_forward(
                    p, list(adjs), f, model=c.model, self_idx=list(sidx))
            )(F, batch["adj"], batch["self_idx"])
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, batch["y"][..., None], axis=-1)[..., 0]
            w = batch["w"]
            loss = ((lse - ll) * w).sum() / jnp.maximum(w.sum(), 1.0)
            return loss, logits

        if c.trainable_features:
            # dense [Vp, D] oracle embedding: fetch through the live table
            # inside the grad, then sparse-AdamW over the batch's global
            # touched ids — row (s, j) of emb_ids maps to flat id s*nb + j
            offsets = jnp.asarray(
                (np.arange(k) * nb)[:, None], jnp.int32)

            @jax.jit
            def ref_step(state, batch):
                params, step_i = state["params"], state["step"]

                def loss_fn(p, emb):
                    table = jnp.concatenate([emb, zrow], 0)
                    F = jnp.take(table, batch["frontier"], axis=0)
                    return batch_loss(p, F, batch)

                (loss, logits), (grads, g_E) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True)(
                        params, state["embed"])
                params2 = jax.tree_util.tree_map(
                    lambda p_, g_: p_ - c.lr * g_, params, grads)
                valid = (batch["emb_ids"] < nb).reshape(-1)
                ids = (offsets + batch["emb_ids"]).reshape(-1)
                g_rows = jnp.take(
                    g_E, jnp.where(valid, ids, 0), axis=0)
                emb2, m2, v2, t2 = sparse_adamw_ids(
                    state["embed"], state["emb_m"], state["emb_v"],
                    state["emb_t"], ids, g_rows, valid=valid,
                    **self._embed_hparams())
                return (dict(params=params2, step=step_i + 1, embed=emb2,
                             emb_m=m2, emb_v=v2, emb_t=t2),
                        dict(loss=loss), logits)
        else:
            @jax.jit
            def ref_step(state, batch):
                params, step_i = state["params"], state["step"]
                F = jnp.take(table0, batch["frontier"], axis=0)  # [k,cap0,D]

                (loss, logits), grads = jax.value_and_grad(
                    batch_loss, has_aux=True)(params, F, batch)
                params2 = jax.tree_util.tree_map(
                    lambda p_, g_: p_ - c.lr * g_, params, grads)
                return (dict(params=params2, step=step_i + 1),
                        dict(loss=loss), logits)

        self._mb_ref_step = ref_step
        return ref_step

    def _ensure_proc_pool(self, depth: int):
        """The engine's persistent sampling-process pool (prefetch_mode=
        'process'), built lazily and reused across epochs: graph CSR arrays
        go to shared memory once, workers run a pickled-then-forked copy of
        `self.host_builder` whose ``graph`` is the shm handle (attached
        read-only at worker init), finished batches come back through the
        shared-memory ring.  Rebuilt if depth/num_workers change."""
        from repro.core.sampling.proc_prefetch import (
            ProcPrefetchPool,
            share_graph,
        )
        key = (int(depth), int(self.cfg.num_sample_workers))
        pool = getattr(self, "_proc_pool", None)
        if pool is not None and pool.alive and self._proc_pool_key == key:
            return pool
        self.close_prefetch_pool()
        shared, arena = share_graph(self.host_builder._g())
        builder = dataclasses.replace(self.host_builder, graph=shared)
        self._proc_pool = ProcPrefetchPool(
            builder.produce, self.host_builder.array_layout(),
            depth=key[0], num_workers=key[1], telemetry=self.telemetry,
            shared_inputs=(arena,))
        self._proc_pool_key = key
        return self._proc_pool

    def close_prefetch_pool(self) -> None:
        """Stop the sampling processes and unlink their shared memory.
        Idempotent; safe to call with no pool built."""
        pool = getattr(self, "_proc_pool", None)
        if pool is not None:
            pool.close()
            self._proc_pool = None

    def run_epoch_minibatch(self, num_batches: int, schedule: str = "conventional",
                            state=None, reference: bool = False,
                            prefetch_depth: Optional[int] = None,
                            prefetch_mode: Optional[str] = None):
        """Drive the §6.1 mini-batch execution schedules (conventional /
        factored / operator_parallel / pipelined) with the engine's REAL
        stages: host sampling, padded-batch extraction (+fetch-plan build),
        and the jitted train step.  Returns (state, losses, StageTimes).

        ``schedule="pipelined"`` runs the double-buffered sampler for real: a
        background `PrefetchWorker` thread samples/extracts batch i+1
        (bounded ``prefetch_depth`` ahead, default cfg.prefetch_depth) while
        the trainer lane dispatches step i WITHOUT blocking on the device —
        losses are synced once at epoch end, so the jitted step, the
        host->device transfer, and host sampling genuinely overlap.  Batches
        stay deterministic in (seed, step, device): the pipelined epoch is
        bitwise-identical to the blocking schedules (state, losses, and
        CommStats), just faster on the wall.

        ``prefetch_mode`` (default cfg.prefetch_mode) picks the pipelined
        producer: "thread" shares this process's GIL; "process" runs
        sample+extract in a persistent `ProcPrefetchPool` of
        ``cfg.num_sample_workers`` worker processes over a shared-memory
        batch ring (sampling/proc_prefetch.py) — the GIL-free data plane,
        same bitwise guarantee.  The pool is reused across epochs; call
        `close_prefetch_pool()` when done (GC also reclaims it).

        A fresh run (state=None) resets self.comm_stats like train();
        passing a state in continues accumulating."""
        from repro.core.execution.minibatch_pipeline import (
            SCHEDULES,
            run_pipelined,
            run_pipelined_process,
        )
        self._check_minibatch_runnable()
        step = (self.make_reference_minibatch_step() if reference
                else self.make_minibatch_step())
        if state is None:
            self.comm_stats.reset()
        holder = dict(state=state if state is not None
                      else self.init_minibatch_state())
        pipelined = schedule == "pipelined"
        tel = self.telemetry
        losses: List = []

        def train_fn(mbs, batch):
            holder["state"], metrics, _ = step(holder["state"], batch)
            # pipelined lane: keep the dispatch async — float() here would
            # block the trainer on the device step and kill the overlap
            losses.append(metrics["loss"] if pipelined
                          else float(metrics["loss"]))
            tel.log_step(step=len(losses) - 1, schedule=schedule,
                         comm_total_bytes=self.comm_stats.total())

        batch_ids = list(range(num_batches))
        # items carry their step index so the extract stage can label its
        # exchange spans (train_fn never looks inside mbs)
        sample_fn = lambda i: (int(i), self._sample_host(int(i)))  # noqa: E731
        extract_fn = lambda si: self._make_batch(si[1], step=si[0])  # noqa: E731
        if pipelined:
            depth = (self.cfg.prefetch_depth if prefetch_depth is None
                     else prefetch_depth)
            mode = (self.cfg.prefetch_mode if prefetch_mode is None
                    else prefetch_mode)
            if mode not in ("thread", "process"):
                raise ValueError(
                    "prefetch_mode must be 'thread' or 'process'")
            if mode == "process":
                # GIL-free lane: workers already ran sample+extract; here we
                # fold their byte deltas into comm_stats, assemble the jnp
                # batch, and dispatch — still async, synced at epoch end
                def train_fn_proc(item, arrays, meta):
                    batch = self._finish_batch(arrays, meta, step=item)
                    train_fn(None, batch)

                times = run_pipelined_process(
                    batch_ids, self._ensure_proc_pool(depth), train_fn_proc,
                    finalize_fn=lambda: jax.block_until_ready(
                        holder["state"]),
                    telemetry=tel)
            else:
                times = run_pipelined(
                    batch_ids, sample_fn, extract_fn, train_fn,
                    prefetch_depth=depth,
                    finalize_fn=lambda: jax.block_until_ready(
                        holder["state"]),
                    telemetry=tel)
            losses = [float(l) for l in losses]
        else:
            times = SCHEDULES[schedule](
                batch_ids, sample_fn, extract_fn, train_fn, telemetry=tel)
        return holder["state"], losses, times

    def minibatch_accuracy(self, logits, batch) -> float:
        """Accuracy over the batch's weighted (owned train) targets."""
        correct = (jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32)
        w = batch["w"]
        return float((correct * w).sum() / jnp.maximum(w.sum(), 1.0))

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def train(self, epochs: int, reference: bool = False
              ) -> Tuple[List[float], jnp.ndarray]:
        """Run `epochs` steps; returns (losses, final logits) — logits are
        [Vp, C] for full-graph batching, [k, cap_L, C] target logits for the
        mini-batch modes.  Mini-batch runs reset and accumulate
        self.comm_stats (feature fetch bytes, cache hits)."""
        tel = self.telemetry
        if self.cfg.batching != "full_graph":
            self._check_minibatch_runnable()
            step = (self.make_reference_minibatch_step() if reference
                    else self.make_minibatch_step())
            state = self.init_minibatch_state()
            self.comm_stats.reset()
            losses: List[float] = []
            logits = None
            for i in range(epochs):
                batch = self.sample_minibatch(i)
                with tel.span("train", step=i):
                    state, metrics, logits = step(state, batch)
                    losses.append(float(metrics["loss"]))
                tel.log_step(step=i, loss=losses[-1],
                             comm_total_bytes=self.comm_stats.total())
            return losses, logits
        step = self.make_reference_step() if reference else self.make_step()
        state = self.init_state()
        if not reference and (self._wire_fields
                              or self.cfg.trainable_features):
            self.comm_stats.reset()
        losses = []
        logits = None
        for i in range(epochs):
            with tel.span("train", step=i):
                state, metrics, logits = step(state)
                losses.append(float(metrics["loss"]))
            if not reference:
                with self._account_exchange("full_graph", i, None):
                    for name, b in self._wire_fields.items():
                        setattr(self.comm_stats, name,
                                getattr(self.comm_stats, name) + b)
                    if self.cfg.trainable_features:
                        self.comm_stats.embed_grad_bytes += \
                            self._emb_bytes_per_step
                tel.log_step(step=i, loss=losses[-1],
                             comm_total_bytes=self.comm_stats.total())
        return losses, logits

    def accuracy(self, logits, split: str = "test") -> float:
        w = self.test_w if split == "test" else self.train_w
        correct = (jnp.argmax(logits, -1) == self.y).astype(jnp.float32)
        return float((correct * w).sum() / jnp.maximum(w.sum(), 1.0))
