"""Version-portable jax compatibility shims (shard_map + Pallas drift).

Every module in this repo that needs ``shard_map`` must import it from here —
never from ``jax`` or ``jax.experimental`` directly.  The shim absorbs the two
API moves that otherwise fork the codebase per jax version:

* **Location**: ``shard_map`` lives at ``jax.experimental.shard_map`` up to
  ~0.4.x / 0.5.x and is re-exported as ``jax.shard_map`` from jax>=0.6
  (experimental alias ``jax.shard_map`` already appears in some 0.4.35+
  builds).  Importing the missing one raises ``ImportError`` /
  ``AttributeError`` depending on the path — we probe both.
* **Replication-check kwarg**: the ``check_rep`` kwarg (<=0.5) was renamed
  ``check_vma`` (>=0.6, varying-manual-axes rework).  Callers here use either
  spelling; the shim rewrites it to whatever the installed jax accepts.

Supported / tested versions:

* jax 0.4.3x (CI floor; 0.4.37 is the pinned container toolchain):
  ``jax.experimental.shard_map.shard_map`` with ``check_rep``; Pallas
  interpret-mode ``pl.load`` requires ``Slice``/array indices (no bare ints —
  use :func:`pallas_block_slice` / ``pl.dslice`` everywhere).
* jax >=0.6 (forward-compat path, exercised via the kwarg-rewrite branch):
  ``jax.shard_map`` with ``check_vma``.

Extending to a new jax release: if ``shard_map``'s signature gains/renames a
kwarg, add the rename to ``_KWARG_ALIASES`` below; nothing else in the repo
should need to change.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any

import jax

# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

_raw_shard_map = getattr(jax, "shard_map", None)
if _raw_shard_map is None:  # jax <= 0.5.x keeps it in experimental
    from jax.experimental.shard_map import shard_map as _raw_shard_map  # type: ignore

# Either spelling of the replication-check kwarg is accepted by callers; the
# installed jax accepts exactly one of them.
_KWARG_ALIASES = [("check_vma", "check_rep")]


@functools.lru_cache(maxsize=None)
def _accepted_kwargs() -> frozenset:
    try:
        return frozenset(inspect.signature(_raw_shard_map).parameters)
    except (TypeError, ValueError):  # pragma: no cover - exotic wrappers
        return frozenset()


def shard_map(f=None, /, **kwargs: Any):
    """Drop-in ``shard_map`` accepting both ``check_rep`` and ``check_vma``.

    Usage is keyword-style, as everywhere in this repo::

        fn = shard_map(local_fn, mesh=mesh, in_specs=..., out_specs=...,
                       check_vma=False)
    """
    accepted = _accepted_kwargs()
    for a, b in _KWARG_ALIASES:
        for src, dst in ((a, b), (b, a)):
            if src in kwargs and src not in accepted and dst in accepted:
                kwargs[dst] = kwargs.pop(src)
        # neither spelling supported: drop it rather than crash (the check is
        # a debugging aid, not a semantics change)
        for name in (a, b):
            if name in kwargs and name not in accepted:
                kwargs.pop(name)
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return _raw_shard_map(f, **kwargs)


# ---------------------------------------------------------------------------
# Pallas drift
# ---------------------------------------------------------------------------


def pallas_block_slice(start: int, size: int):
    """``pl.dslice`` indirection point.

    jax 0.4.3x interpret-mode ``pl.load`` discharge requires every index to be
    a ``Slice`` or an array — a bare python int (``ref[(0, ...)]``-style)
    crashes with ``'int' object has no attribute 'shape'``.  Kernels index the
    leading block dim with ``pallas_block_slice(i, 1)`` and squeeze instead.
    """
    from jax.experimental import pallas as pl

    return pl.dslice(start, size)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on jax>=0.5 but a
    one-element *list* of dicts on 0.4.x (one per device-program).  Normalize
    to a plain dict (empty when unavailable)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # pragma: no cover - backend without cost model
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def peak_memory_in_bytes(memory_stats) -> int:
    """``CompiledMemoryStats.peak_memory_in_bytes`` only exists on newer jax;
    0.4.x exposes argument/temp/output sizes.  Fall back to their sum (an
    upper-ish proxy for the peak) when the field is absent."""
    peak = getattr(memory_stats, "peak_memory_in_bytes", None)
    if peak is not None:
        return int(peak)
    return int(memory_stats.argument_size_in_bytes
               + memory_stats.temp_size_in_bytes
               + memory_stats.output_size_in_bytes)


def interpret_default() -> bool:
    """Whether Pallas kernels should run in interpret mode by default: True on
    anything that is not a real TPU backend (CPU/GPU hosts, forced-host-device
    test meshes).

    Deliberately includes GPU: the repo's kernels are TPU-styled and their
    Triton lowering is untested, so interpret mode (which traces to plain XLA
    ops under jit — correct, just not kernel-fused) is the safe default there.
    Callers that have validated a GPU lowering can pass ``interpret=False``
    explicitly (e.g. ``EngineConfig(interpret=False)``)."""
    try:
        return jax.default_backend() != "tpu"
    except Exception:  # pragma: no cover
        return True
