from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
    sgdm,
)
from repro.optim.sparse_optim import (
    row_adamw_update,
    sparse_adamw,
    sparse_adamw_ids,
)

__all__ = [
    "Optimizer",
    "adafactor",
    "adamw",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "make_optimizer",
    "row_adamw_update",
    "sgdm",
    "sparse_adamw",
    "sparse_adamw_ids",
]
