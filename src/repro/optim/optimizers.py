"""Self-contained functional optimizers (no optax dependency).

An Optimizer is a pair of pure functions:
  init(params)                  -> opt_state (pytree)
  update(grads, state, params, step) -> (updates, new_state)
plus ``state_logical_axes(param_axes)`` so optimizer state shards like its
parameter (critical for FSDP: Adam moments inherit the param sharding;
Adafactor's factored moments inherit the corresponding row/col axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: (x * scale).astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Any]  # (grads, state, params, step)
    state_logical_axes: Callable[[Any], Any]


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step):
        step1 = step + 1
        lr = lr_fn(step)
        bc1 = 1 - b1 ** step1.astype(jnp.float32)
        bc2 = 1 - b2 ** step1.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mh, vh = m2 / bc1, v2 / bc2
            u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m2, v2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v}

    def axes(param_axes, abstract_params=None):
        return {"m": param_axes, "v": param_axes}

    return Optimizer(init, update, axes)


def adafactor(lr_fn, decay=0.8, eps=1e-30, weight_decay=0.0, min_dim_factored=128) -> Optimizer:
    """Factored second-moment (Shazeer & Stern). Params with >=2 dims whose
    trailing two dims are both >= min_dim_factored get factored row/col stats;
    everything else falls back to a full second moment."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and p.shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return jax.tree_util.tree_map(one, params)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                    vr.mean(-1)[..., None, None], eps)
                u = g * jax.lax.rsqrt(denom + eps)
                s2 = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                s2 = {"v": v}
            # update clipping (RMS<=1) per Adafactor
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), s2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_state = tdef.unflatten([o[1] for o in outs])
        return updates, new_state

    def axes(param_axes, abstract_params=None):
        assert abstract_params is not None, "adafactor axes need abstract params"

        def one(ax, p):
            if _factored(p):
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": ax}

        return jax.tree_util.tree_map(one, param_axes, abstract_params,
                                      is_leaf=lambda t: isinstance(t, tuple))

    return Optimizer(init, update, axes)


def sgdm(lr_fn, momentum=0.9, weight_decay=0.0) -> Optimizer:
    def init(params):
        return {"m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m2 = momentum * m + g
            return (-lr * m2).astype(p.dtype), m2

        out = jax.tree_util.tree_map(upd, grads, state["m"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m}

    def axes(param_axes, abstract_params=None):
        return {"m": param_axes}

    return Optimizer(init, update, axes)


def _optimizer_factories():
    """Name -> factory registry (a function so sparse_optim can import this
    module without a cycle)."""
    from repro.optim.sparse_optim import sparse_adamw

    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm,
            "sparse_adamw": sparse_adamw}


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    factories = _optimizer_factories()
    if name not in factories:
        raise ValueError(
            f"unknown optimizer {name!r}: valid names are "
            f"{sorted(factories)}")
    return factories[name](lr_fn, **kw)
