"""Row-sparse AdamW for trainable embedding tables (ROADMAP item 1).

At production scale most vertex "features" are learnable embeddings, which
makes the feature plane part of the optimizer: a step only sees gradients for
the rows it touched (the ELL / frontier-fetch VJPs emit exactly row-sparse
cotangents), so the optimizer must update ONLY those rows — dense Adam would
decay every row's moments every step and pay O(V) FLOPs per step.

The core is `row_adamw_update`: AdamW over the rows of one table with a
per-row TOUCHED mask and per-row step counts for bias correction (a row's
bc uses how often *that row* has been updated, not the global step — the only
definition under which "sparse update == dense AdamW restricted to the
touched rows" holds across steps with different touched sets).  Untouched
rows — params, both moments, and the step counts — are bitwise unchanged.

Two consumers:
  * `sparse_adamw_ids` — gather -> row-AdamW -> scatter over an explicit
    touched-id list (the engine's mini-batch path; ids come from the frontier
    plan).  Scatter uses a dead-row redirect (invalid ids write past the
    table, then the pad row is sliced off) so it is deterministic and the
    untouched rows are never written at all.
  * `sparse_adamw` — the `Optimizer`-shaped wrapper registered in
    `make_optimizer`: rows whose gradient is entirely zero are untouched
    (lazy semantics); with dense nonzero gradients it IS adamw with the same
    hyperparameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


def _row_mask(mask, ndim):
    """Broadcast a [N] row mask over a [N, ...] table."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def row_adamw_update(p, g, m, v, t, touched, *, lr, b1=0.9, b2=0.999,
                     eps=1e-8, weight_decay=0.0):
    """Masked-dense row AdamW: p/g/m/v [N, ...], t [N] int32 per-row update
    counts, touched [N] (bool/float).  Returns (p2, m2, v2, t2) where every
    untouched row of all four buffers is bitwise the input row.  Bias
    correction is per-row: row r's bc term uses t2[r] = t[r] + touched[r],
    so a row updated for the i-th time behaves exactly like dense AdamW at
    global step i restricted to that row."""
    tch = jnp.asarray(touched).astype(bool)
    rm = _row_mask(tch, p.ndim)
    g32 = g.astype(jnp.float32)
    t2 = t + tch.astype(t.dtype)
    tf = t2.astype(jnp.float32)
    # untouched rows may still have t2 == 0; guard the division (the where
    # below discards the guarded lanes anyway)
    bc1 = jnp.maximum(1.0 - b1 ** tf, 1e-30)
    bc2 = jnp.maximum(1.0 - b2 ** tf, 1e-30)
    m2 = b1 * m + (1 - b1) * g32
    v2 = b2 * v + (1 - b2) * jnp.square(g32)
    mh = m2 / _row_mask(bc1, p.ndim)
    vh = v2 / _row_mask(bc2, p.ndim)
    u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
    p2 = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
    return (jnp.where(rm, p2, p), jnp.where(rm, m2, m),
            jnp.where(rm, v2, v), t2)


def sparse_adamw_ids(table, m, v, t, ids, grads, *, lr, b1=0.9, b2=0.999,
                     eps=1e-8, weight_decay=0.0, valid=None, dedup=False):
    """Sparse row AdamW over an explicit touched-id list: gather the R rows,
    run `row_adamw_update`, scatter back.  table/m/v [N, D], t [N]; ids [R]
    int row indices; grads [R, D] the gradient rows aligned with `ids`.

    ``valid`` [R] masks real entries (default: 0 <= ids < N, so a sentinel id
    >= N marks padding).  With ``dedup=True`` duplicate valid ids are summed
    onto their FIRST occurrence and the later occurrences deactivated (an
    R x R combine — meant for small R); otherwise valid ids must be unique.

    Untouched rows are never written: the scatter targets exactly the applied
    ids (invalid/duplicate entries redirect to a dead pad row that is sliced
    off), so FLOPs and moment traffic are O(R * D), and untouched rows of all
    four buffers are bitwise unchanged."""
    N = table.shape[0]
    ids = jnp.asarray(ids)
    if valid is None:
        valid = (ids >= 0) & (ids < N)
    valid = jnp.asarray(valid).astype(bool)
    g = grads.astype(jnp.float32) * _row_mask(valid, grads.ndim)
    if dedup:
        R = ids.shape[0]
        eq = (ids[:, None] == ids[None, :]) & valid[:, None] & valid[None, :]
        first = jnp.argmax(eq, axis=1)  # first j with the same id (valid)
        is_first = first == jnp.arange(R)
        g = (eq.astype(g.dtype) @ g.reshape(R, -1)).reshape(g.shape)
        apply = valid & is_first
    else:
        apply = valid
    safe = jnp.where(valid, ids, 0)
    p2, m2, v2, t2 = row_adamw_update(
        jnp.take(table, safe, axis=0), g, jnp.take(m, safe, axis=0),
        jnp.take(v, safe, axis=0), jnp.take(t, safe, axis=0), apply,
        lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    ids_eff = jnp.where(apply, ids, N)  # dead row past the table

    def scatter(buf, rows):
        pad = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
        return jnp.concatenate([buf, pad], 0).at[ids_eff].set(rows)[:N]

    return scatter(table, p2), scatter(m, m2), scatter(v, v2), scatter(t, t2)


def sparse_adamw(lr_fn, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0) -> Optimizer:
    """Lazy row-sparse AdamW as a generic `Optimizer`: per leaf, a leading-
    axis row whose gradient is entirely zero is UNTOUCHED — its params, both
    moments, and its per-row step count stay put (the state carries a
    [rows]-shaped int32 count per leaf for the per-row bias correction).
    With dense nonzero gradients every row updates every step and the
    trajectory is `adamw`'s with the same hyperparameters (note the defaults
    differ: embeddings want b2=0.999 / weight_decay=0)."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        counts = lambda p: jnp.zeros(p.shape[:1], jnp.int32)  # noqa: E731
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "t": jax.tree_util.tree_map(counts, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, m, v, t, p):
            g32 = g.astype(jnp.float32)
            touched = jnp.any(g32 != 0,
                              axis=tuple(range(1, g32.ndim)))
            p2, m2, v2, t2 = row_adamw_update(
                p, g32, m, v, t, touched, lr=lr, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay)
            return (p2 - p).astype(p.dtype), m2, v2, t2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     state["t"], params)
        pick = lambda i: jax.tree_util.tree_map(  # noqa: E731
            lambda tup: tup[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "t": pick(3)}

    def axes(param_axes, abstract_params=None):
        row = lambda ax: tuple(ax[:1])  # noqa: E731
        return {"m": param_axes, "v": param_axes,
                "t": jax.tree_util.tree_map(
                    row, param_axes, is_leaf=lambda x: isinstance(x, tuple))}

    return Optimizer(init, update, axes)
